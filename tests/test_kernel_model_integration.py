"""End-to-end parity: model forward with Pallas kernels routed in
(interpret mode on CPU) vs the pure-jnp paths. Covers the serving/forward
path (kernels are forward-path drop-ins; training keeps the jnp paths,
whose HLO the dry-run measures)."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.launch import specs as SP
from repro.models import model as MDL


def _prefill_logits(cfg, seed=0):
    params = MDL.init(cfg, jax.random.PRNGKey(seed))
    batch = {
        k: v for k, v in SP.make_train_batch(cfg, 2, 64, seed=seed).items()
        if k in ("tokens", "patch_embeds", "frames")
    }
    return np.asarray(MDL.prefill(cfg, params, batch), np.float32)


@pytest.mark.parametrize(
    "arch,flags",
    [
        ("llama3.2-1b", {"use_flash_kernel": True}),
        ("mamba2-780m", {"use_ssd_kernel": True}),
        ("moonshot-v1-16b-a3b", {"use_gmm_kernel": True}),
        ("jamba-1.5-large-398b",
         {"use_flash_kernel": True, "use_ssd_kernel": True,
          "use_gmm_kernel": True}),
    ],
)
def test_forward_parity_with_kernels(arch, flags):
    base = dataclasses.replace(ARCHS[arch].reduced(), remat=False)
    with_k = dataclasses.replace(base, **flags)
    ref = _prefill_logits(base)
    got = _prefill_logits(with_k)
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
