"""Sharded (beyond-paper §Perf) vs global MoE dispatch parity tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as MOE


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = MOE.init_moe(key, 64, 128, 8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 64))
    return params, x


def test_sharded_equals_global_unconstrained_capacity(setup):
    """With capacity that never truncates, group-local dispatch is exactly
    the same function as global dispatch."""
    params, x = setup
    y0, a0 = MOE.moe_ffn(params, x, top_k=2, dispatch="global",
                         capacity_factor=8.0)
    y1, a1 = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded",
                         force_groups=4, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-6)


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_sharded_groups_consistent(setup, groups):
    """Any group count gives the same result at unconstrained capacity."""
    params, x = setup
    ref, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded",
                         force_groups=1, capacity_factor=8.0)
    got, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded",
                         force_groups=groups, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=1e-4, atol=1e-5)


def test_sharded_capacity_drops_are_bounded(setup):
    """At tight capacity the two dispatches may drop different tokens, but
    outputs stay highly correlated (same routing, same experts)."""
    params, x = setup
    y0, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="global")
    y1, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded", force_groups=4)
    c = np.corrcoef(np.asarray(y0).ravel(), np.asarray(y1).ravel())[0, 1]
    assert c > 0.9, c


def test_sharded_fallback_when_indivisible(setup):
    """Group counts that don't divide the token count fall back to global."""
    params, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 11, 64))  # T=33
    y0, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="global")
    y1, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded", force_groups=4)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5,
                               atol=1e-6)


def test_gather_ffn_matches_buffered(setup):
    """Decode-time expert-gather FFN == buffered FFN at full capacity."""
    params, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 64))  # decode batch
    y0, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="global",
                        capacity_factor=8.0)
    y1, _ = MOE.moe_ffn_gather(params, x, top_k=2)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_gather_ffn_bf16_combine_close(setup):
    """bf16 combine path stays within bf16 tolerance of the f32 path."""
    params, x = setup
    y0, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded",
                        force_groups=4, combine_dtype="f32",
                        capacity_factor=8.0)
    y1, _ = MOE.moe_ffn(params, x, top_k=2, dispatch="sharded",
                        force_groups=4, combine_dtype="bf16",
                        capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=3e-2, atol=3e-2)


def test_grad_flows_through_sharded_dispatch(setup):
    params, x = setup

    def loss(p):
        y, aux = MOE.moe_ffn(p, x, top_k=2, dispatch="sharded", force_groups=4)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(params)
    norms = [float(jnp.linalg.norm(l)) for l in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert any(n > 0 for n in norms)
