"""Regenerate the algorithm-conformance golden file.

Runs every seed algorithm x both engines x {sparse, dense} gradient paths
on a small deterministic XML workload and records per-mega-batch losses
plus merged-parameter fingerprints (per-leaf mean and L2 norm).

The committed ``algorithms_seed.json`` was produced by the PRE-refactor
trainer (the five-way ``if algo == ...`` branching at git tag of PR 2), so
``tests/test_algorithms.py`` proves the pluggable-strategy refactor is
numerically identical to the seed behavior. Regenerate only when the
*intended* numerics change (and say so in the PR):

    PYTHONPATH=src python tests/golden/generate.py

This module is also the **single source of the case definition**: the
conformance suite imports ``DATASET_KW``/``MODEL_CFG``/``CASE_KW``/
``build_case_trainer``/``fingerprint`` from here, so the recorded and the
replayed runs cannot drift apart.

Algorithms added after the refactor (e.g. ``delayed_sync``) are covered by
cross-engine/cross-path differential tests instead of goldens; only the
five seed algorithms are recorded here.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model

SEED_ALGOS = ("adaptive", "elastic", "sync", "crossbow", "single")
ENGINES = ("scan", "legacy_loop")
N_MEGA = 2
OUT = os.path.join(os.path.dirname(__file__), "algorithms_seed.json")

# the deterministic case every golden was recorded with
DATASET_KW = dict(n_samples=1536, n_features=512, n_classes=64, avg_nnz=24,
                  seed=0)
MODEL_CFG = XMLMLPConfig(n_features=512, n_classes=64, hidden=48)
CASE_KW = dict(b_max=32, mega_batch=6, provider_seed=3, base_lr=0.5, seed=3)


def make_case_dataset():
    full = make_xml_dataset(**DATASET_KW)
    return train_test_split(full, 0.15)[0]


def build_case_trainer(algo: str, engine: str, sparse: bool, ds,
                       placement: str = "vmap") -> ElasticTrainer:
    """``placement`` is not part of the recorded goldens (they predate it);
    the conformance suite passes 'sharded' to replay the same case through
    the shard_map executor and compare against the vmap run."""
    from repro.core import algorithms

    R = algorithms.get(algo).resolve_n_replicas(4)
    prov = SparseProvider.make(ds, seed=CASE_KW["provider_seed"])
    cfg = ElasticConfig.from_bmax(
        CASE_KW["b_max"], algorithm=algo, n_replicas=R,
        mega_batch=CASE_KW["mega_batch"], placement=placement,
    )
    return ElasticTrainer(
        make_model(MODEL_CFG), prov, cfg, base_lr=CASE_KW["base_lr"],
        seed=CASE_KW["seed"], engine=engine, sparse_grads=sparse,
    )


def fingerprint(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf, np.float64)
        out[key] = {"mean": float(arr.mean()), "l2": float(np.linalg.norm(arr))}
    return out


def run_case(algo: str, engine: str, sparse: bool) -> dict:
    tr = build_case_trainer(algo, engine, sparse, make_case_dataset())
    state = tr.init_state()
    losses, accs, us = [], [], []
    for _ in range(N_MEGA):
        state, info = tr.run_megabatch(state)
        losses.append(float(info["train_loss"]))
        accs.append(float(info["train_accuracy"]))
        us.append(info["u"])
    merged = state.global_model
    if merged is None:  # algorithms that keep no separate global copy
        merged = jax.tree_util.tree_map(lambda l: l[0], state.replicas)
    return {
        "train_loss": losses,
        "train_accuracy": accs,
        "u": us,
        "b": np.asarray(state.b, np.float64).tolist(),
        "lr": np.asarray(state.lr, np.float64).tolist(),
        "global": fingerprint(merged),
        "replicas": fingerprint(state.replicas),
    }


def main():
    golden = {"n_megabatches": N_MEGA, "cases": {}}
    for algo in SEED_ALGOS:
        for engine in ENGINES:
            for sparse in (True, False):
                key = f"{algo}|{engine}|{'sparse' if sparse else 'dense'}"
                print("running", key)
                golden["cases"][key] = run_case(algo, engine, sparse)
    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print("wrote", OUT, f"({len(golden['cases'])} cases)")


if __name__ == "__main__":
    main()
