"""End-to-end launcher smoke tests (CPU, reduced configs)."""
from __future__ import annotations

import numpy as np

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_launcher_xml():
    state, mlog = train_mod.main([
        "--workload", "xml", "--algorithm", "adaptive", "--replicas", "2",
        "--megabatches", "2", "--mega-batch", "4", "--b-max", "16",
        "--samples", "512", "--features", "256", "--classes", "64",
        "--avg-nnz", "16", "--hidden", "32", "--lr", "1.0",
    ])
    assert len(mlog.records) == 2
    assert np.isfinite(mlog.records[-1]["train_loss"])


def test_train_launcher_lm_reduced():
    state, mlog = train_mod.main([
        "--workload", "lm", "--arch", "llama3.2-1b", "--reduced",
        "--algorithm", "elastic", "--replicas", "2", "--megabatches", "1",
        "--mega-batch", "2", "--b-max", "4", "--seq-len", "32",
    ])
    assert len(mlog.records) == 1
    assert np.isfinite(mlog.records[-1]["train_loss"])


def test_train_launcher_sharded_placement():
    """--placement sharded through the public launcher (in-process: size-1
    replica mesh; the 4-shard layout runs in the multi-device CI job)."""
    state, mlog = train_mod.main([
        "--workload", "xml", "--algorithm", "adaptive", "--replicas", "2",
        "--placement", "sharded", "--megabatches", "2", "--mega-batch", "4",
        "--b-max", "16", "--samples", "512", "--features", "256",
        "--classes", "64", "--avg-nnz", "16", "--hidden", "32", "--lr", "1.0",
    ])
    assert len(mlog.records) == 2
    assert np.isfinite(mlog.records[-1]["train_loss"])


def test_train_launcher_measured_speed():
    """--speed measured wires the MeasuredSpeedModel feedback loop."""
    state, mlog = train_mod.main([
        "--workload", "xml", "--algorithm", "delayed_sync", "--replicas", "2",
        "--speed", "measured", "--megabatches", "2", "--mega-batch", "4",
        "--b-max", "16", "--samples", "512", "--features", "256",
        "--classes", "64", "--avg-nnz", "16", "--hidden", "32", "--lr", "1.0",
    ])
    assert len(mlog.records) == 2
    assert np.isfinite(mlog.records[-1]["train_loss"])


def test_serve_launcher_reduced():
    toks = serve_mod.main([
        "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
        "--context", "4", "--gen", "3",
    ])
    assert toks.shape == (2, 3)


def test_serve_launcher_sliding_window():
    toks = serve_mod.main([
        "--arch", "llama3.2-1b", "--reduced", "--batch", "1",
        "--context", "6", "--gen", "2", "--window", "4",
    ])
    assert toks.shape == (1, 2)
