"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model<=256, <=4 experts) and run one forward/train step on CPU,
asserting output shapes and absence of NaNs. Decode steps likewise.
"""
import jax
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.configs.base import ElasticConfig
from repro.core.trainer import ElasticTrainer
from repro.data.providers import TokenProvider
from repro.launch import specs as SP
from repro.models import model as MDL
from repro.optim.sgd import SGDConfig, sgd_update

ARCH_IDS = list(ARCHS.keys())


@pytest.fixture(scope="module")
def params_cache():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = ARCHS[name].reduced()
            cache[name] = (cfg, MDL.init(cfg, jax.random.PRNGKey(0)))
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_IDS)
def test_reduced_config_limits(name):
    r = ARCHS[name].reduced()
    assert r.n_layers <= 2
    assert r.d_model <= 512
    assert r.n_experts <= 4


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(params_cache, name):
    cfg, params = params_cache(name)
    b, s = 2, 64
    batch = SP.make_train_batch(cfg, b, s, seed=1)
    loss, aux = jax.jit(lambda p, bt: MDL.loss_fn(cfg, p, bt))(params, batch)
    assert np.isfinite(float(loss)), f"{name}: NaN loss"
    assert float(aux["n_valid"]) == b
    assert np.isfinite(float(aux["accuracy"]))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_one_train_step_updates_params(params_cache, name):
    cfg, params = params_cache(name)
    batch = SP.make_train_batch(cfg, 2, 64, seed=2)

    def loss(p):
        return MDL.loss_fn(cfg, p, batch)[0]

    grads = jax.jit(jax.grad(loss))(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), f"{name}: NaN grad"
    new_params, _ = sgd_update(params, grads, 0.01, SGDConfig())
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(
            jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params)
        )
    )
    assert moved, f"{name}: step was a no-op"


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_step_shapes(params_cache, name):
    cfg, params = params_cache(name)
    b, ctx = 2, 128
    tokens, cache = SP.make_decode_inputs(cfg, b, ctx)
    logits, new_cache = jax.jit(
        lambda p, c, t: MDL.decode_step(cfg, p, c, t)
    )(params, cache, tokens)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{name}: NaN decode logits"
    assert int(new_cache["cur_len"]) == ctx


@pytest.mark.parametrize(
    "name", [n for n in ARCH_IDS if ARCHS[n].arch_type != "ssm"]
)
def test_windowed_decode(params_cache, name):
    """long_500k carve-in: sliding-window decode lowers and is finite."""
    cfg, params = params_cache(name)
    w = cfg.long_context_window
    tokens, cache = SP.make_decode_inputs(cfg, 1, 512, window=w)
    logits, _ = jax.jit(
        lambda p, c, t: MDL.decode_step(cfg, p, c, t, window=w)
    )(params, cache, tokens)
    assert np.isfinite(np.asarray(logits)).all()
    # cache buffers are bounded by the window
    for leaf in jax.tree_util.tree_leaves(cache["blocks"]):
        assert leaf.shape[2] <= max(w, 512) if leaf.ndim >= 3 else True


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "mamba2-780m", "moonshot-v1-16b-a3b"])
def test_elastic_training_on_arch(name):
    """The paper's trainer composes with the assigned archs end-to-end."""
    cfg = ARCHS[name].reduced()
    model = MDL.make_model(cfg)
    prov = TokenProvider.make(cfg.vocab_size, seq_len=32)
    ecfg = ElasticConfig.from_bmax(8, algorithm="adaptive", n_replicas=2, mega_batch=4)
    tr = ElasticTrainer(model, prov, ecfg, base_lr=0.1)
    state, mlog = tr.run(2)
    assert np.isfinite(mlog.column("train_loss")).all()
    assert len(mlog.records) == 2
