"""Sparse-gradient path tests (DESIGN.md §3).

Three layers of differential coverage:

* kernel: ``jax.grad`` through the ``ops.spmm`` custom VJP (Pallas forward +
  sorted scatter-add backward, interpret mode on CPU) vs ``jax.grad``
  through the pure-jnp ``_sparse_input_ref`` gather — swept over shapes x
  dtypes x block_k, with duplicate indices inside one sample and
  fully-masked samples;
* model: ``loss_and_sparse_grad`` (row-sparse d w1, no autodiff over the
  input layer) vs dense ``jax.value_and_grad(loss_fn)``;
* trainer: sparse path vs dense oracle for all 5 algorithms under both
  engines, and masked (bucket-padding) rounds stay exact no-ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ElasticConfig
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.kernels.spmm.ops import spmm, spmm_grad_w
from repro.kernels.spmm.ref import spmm_grad_w_ref
from repro.models.xml_mlp import (
    XMLMLPConfig,
    loss_and_sparse_grad,
    loss_fn,
    make_model,
)
from repro.optim.row_sparse import RowSparseGrad, is_row_sparse
from repro.optim.sgd import SGDConfig

RNG = np.random.default_rng(7)
ALGOS = ["adaptive", "elastic", "sync", "crossbow", "single"]


def _f32(x):
    return np.asarray(x, np.float32)


def _tol(dtype):
    # bf16 grads are quantized on both sides with different summation
    # orders: allow a couple of ulp at the observed magnitudes
    return dict(rtol=5e-2, atol=1.5e-1) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-5
    )


def _batch(b, k, nf, duplicate=False, mask_sample=None, rng=None):
    rng = rng if rng is not None else np.random.default_rng(b * 1000 + k)
    fi = rng.integers(0, nf, (b, k)).astype(np.int32)
    if duplicate and k >= 2:  # same row twice in one sample
        fi[0, 1] = fi[0, 0]
    fv = rng.normal(size=(b, k)).astype(np.float32)
    fm = rng.random((b, k)) > 0.3
    if mask_sample is not None:
        fm[mask_sample] = False
    return jnp.asarray(fi), jnp.asarray(fv), jnp.asarray(fm)


# --------------------------------------------------------------------------
# kernel-level: custom VJP vs autodiff of the gather reference
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,k,nf,h", [(4, 16, 512, 128), (8, 7, 300, 512), (2, 33, 1024, 200)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block_k", [1, 8])
def test_grad_equivalence_sweep(b, k, nf, h, dtype, block_k):
    rng = np.random.default_rng(nf + h + block_k)
    fi, fv, fm = _batch(b, k, nf, duplicate=True, mask_sample=min(1, b - 1),
                        rng=rng)
    w = jnp.asarray(rng.normal(size=(nf, h)), dtype)
    co = jnp.asarray(rng.normal(size=(b, h)), jnp.float32)

    def f_kernel(v, w):
        return jnp.sum(spmm(fi, v, fm, w, block_k=block_k).astype(jnp.float32) * co)

    def f_ref(v, w):
        rows = w[fi].astype(jnp.float32)
        scale = (v * fm).astype(jnp.float32)[..., None]
        return jnp.sum(jnp.sum(rows * scale, axis=1) * co)

    gv_k, gw_k = jax.grad(f_kernel, (0, 1))(fv, w)
    gv_r, gw_r = jax.grad(f_ref, (0, 1))(fv, w)
    np.testing.assert_allclose(_f32(gw_k), _f32(gw_r), **_tol(dtype))
    np.testing.assert_allclose(_f32(gv_k), _f32(gv_r), **_tol(dtype))


@pytest.mark.parametrize("block_h", [128, 512])
def test_grad_w_standalone_vs_ref(block_h):
    b, k, nf, h = 4, 9, 200, 160
    fi, fv, fm = _batch(b, k, nf, duplicate=True)
    dh = jnp.asarray(RNG.normal(size=(b, h)), jnp.float32)
    got = spmm_grad_w(fi, fv, fm, dh, nf, block_h=block_h)
    want = spmm_grad_w_ref(fi, fv, fm, dh, nf)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=1e-5, atol=1e-5)


def test_grad_w_all_masked_is_zero():
    b, k, nf, h = 3, 5, 64, 128
    fi = jnp.zeros((b, k), jnp.int32)
    fv = jnp.ones((b, k), jnp.float32)
    fm = jnp.zeros((b, k), bool)
    dh = jnp.asarray(RNG.normal(size=(b, h)), jnp.float32)
    np.testing.assert_allclose(_f32(spmm_grad_w(fi, fv, fm, dh, nf)), 0.0)


def test_grad_heavily_duplicated_rows():
    """All nnz of all samples hit the same two rows — the worst write-conflict
    case the sorted formulation must serialize correctly."""
    b, k, nf, h = 4, 12, 50, 256
    fi = jnp.asarray(RNG.integers(0, 2, (b, k)), jnp.int32)
    fv = jnp.asarray(RNG.normal(size=(b, k)), jnp.float32)
    fm = jnp.ones((b, k), bool)
    dh = jnp.asarray(RNG.normal(size=(b, h)), jnp.float32)
    got = spmm_grad_w(fi, fv, fm, dh, nf)
    want = spmm_grad_w_ref(fi, fv, fm, dh, nf)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=1e-4, atol=1e-4)
    assert np.all(_f32(got)[2:] == 0.0)  # untouched rows stay zero


# --------------------------------------------------------------------------
# model-level: row-sparse grads vs dense autodiff
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def xml_data():
    full = make_xml_dataset(
        n_samples=1024, n_features=512, n_classes=64, avg_nnz=24, seed=0
    )
    return train_test_split(full, 0.15)


def _model_batch(xml_data, b_slots=16, seed=0):
    ds, _ = xml_data
    prov = SparseProvider.make(ds, seed=seed)
    payload = prov.fetch(b_slots - 2, b_slots)  # 2 masked samples
    return {k: jnp.asarray(v) for k, v in prov.stack([payload]).items()}


def test_sparse_grad_matches_dense_autodiff(xml_data):
    cfg = XMLMLPConfig(n_features=512, n_classes=64, hidden=48)
    params = make_model(cfg)["init"](jax.random.PRNGKey(0))
    batch = {k: v[0] for k, v in _model_batch(xml_data).items()}

    (loss_s, aux_s), grads = loss_and_sparse_grad(cfg, params, batch)
    (loss_d, aux_d), dense = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)

    np.testing.assert_allclose(float(loss_s), float(loss_d), rtol=1e-6)
    np.testing.assert_allclose(float(aux_s["n_valid"]), float(aux_d["n_valid"]))
    assert is_row_sparse(grads["w1"])
    np.testing.assert_allclose(
        _f32(grads["w1"].densify()), _f32(dense["w1"]), rtol=1e-5, atol=1e-6
    )
    for k in ("b1", "w2", "b2"):
        np.testing.assert_allclose(_f32(grads[k]), _f32(dense[k]),
                                   rtol=1e-5, atol=1e-6)


def test_kernel_routed_model_grads_match_ref(xml_data):
    """use_spmm_kernel=True (forced; interpret mode on CPU) runs the whole
    loss through the Pallas forward + custom VJP and must match the jnp
    input layer, dense grads and sparse grads alike."""
    cfg_k = XMLMLPConfig(n_features=512, n_classes=64, hidden=48,
                         use_spmm_kernel=True)
    cfg_r = XMLMLPConfig(n_features=512, n_classes=64, hidden=48,
                         use_spmm_kernel=False)
    params = make_model(cfg_r)["init"](jax.random.PRNGKey(1))
    batch = {k: v[0] for k, v in _model_batch(xml_data, b_slots=8).items()}

    (l_k, _), g_k = jax.value_and_grad(
        lambda p: loss_fn(cfg_k, p, batch), has_aux=True
    )(params)
    (l_r, _), g_r = jax.value_and_grad(
        lambda p: loss_fn(cfg_r, p, batch), has_aux=True
    )(params)
    np.testing.assert_allclose(float(l_k), float(l_r), rtol=1e-5)
    for k in g_r:
        np.testing.assert_allclose(_f32(g_k[k]), _f32(g_r[k]),
                                   rtol=1e-4, atol=1e-5)

    (_, _), gs = loss_and_sparse_grad(cfg_k, params, batch)
    np.testing.assert_allclose(
        _f32(gs["w1"].densify()), _f32(g_r["w1"]), rtol=1e-4, atol=1e-5
    )


def test_sparse_grad_vmaps_over_replicas(xml_data):
    """RowSparseGrad must survive vmap (static shapes, registered pytree)."""
    cfg = XMLMLPConfig(n_features=512, n_classes=64, hidden=48)
    params = make_model(cfg)["init"](jax.random.PRNGKey(0))
    import repro.utils.tree as tu

    R = 3
    reps = tu.tree_broadcast_replicas(params, R)
    batch = _model_batch(xml_data)
    batch = {k: jnp.broadcast_to(v[0][None], (R,) + v[0].shape) for k, v in batch.items()}
    (loss, _), grads = jax.vmap(
        lambda p, b: loss_and_sparse_grad(cfg, p, b)
    )(reps, batch)
    assert loss.shape == (R,)
    assert grads["w1"].rows.shape[0] == R
    assert grads["w1"].vals.shape[0] == R
    d = grads["w1"].densify()
    assert d.shape == (R, 512, 48)
    np.testing.assert_allclose(_f32(d[0]), _f32(d[1]), rtol=1e-6)


# --------------------------------------------------------------------------
# trainer-level: sparse path vs dense oracle, both engines, all algorithms
# --------------------------------------------------------------------------


def _run(algo, xml_data, engine, sparse, n_mega=2, seed=3, bucket=True):
    ds, _ = xml_data
    R = 1 if algo == "single" else 4
    prov = SparseProvider.make(ds, seed=seed)
    cfg = ElasticConfig.from_bmax(32, algorithm=algo, n_replicas=R, mega_batch=5)
    tr = ElasticTrainer(
        make_model(XMLMLPConfig(n_features=512, n_classes=64, hidden=48)),
        prov, cfg, base_lr=0.5, seed=seed, engine=engine,
        sparse_grads=sparse,
    )
    tr.round_bucket = bucket
    state = tr.init_state()
    infos = []
    for _ in range(n_mega):
        state, info = tr.run_megabatch(state)
        infos.append(info)
    return state, infos


def _assert_tree_close(a, b, **tol):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **tol)


@pytest.mark.parametrize("engine", ["scan", "legacy_loop"])
@pytest.mark.parametrize("algo", ALGOS)
def test_sparse_matches_dense_oracle(algo, engine, xml_data):
    st_s, inf_s = _run(algo, xml_data, engine, sparse=True)
    st_d, inf_d = _run(algo, xml_data, engine, sparse=False)
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_s],
        [i["train_loss"] for i in inf_d],
        rtol=2e-4, atol=1e-5,
    )
    _assert_tree_close(st_s.replicas, st_d.replicas, rtol=1e-4, atol=1e-5)
    if st_s.global_model is not None:
        _assert_tree_close(st_s.global_model, st_d.global_model,
                           rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", ALGOS)
def test_masked_round_noop_scan_engine(algo, xml_data):
    """Bucket-padding (fully-masked) rounds must be exact no-ops on the
    sparse path under the scan engine, for every algorithm."""
    st_pad, inf_pad = _run(algo, xml_data, "scan", sparse=True, n_mega=1,
                           bucket=True)
    st_raw, inf_raw = _run(algo, xml_data, "scan", sparse=True, n_mega=1,
                           bucket=False)
    np.testing.assert_allclose(
        inf_pad[0]["train_loss"], inf_raw[0]["train_loss"], rtol=1e-5, atol=1e-6
    )
    _assert_tree_close(st_pad.replicas, st_raw.replicas, rtol=1e-5, atol=1e-6)


def test_sparse_update_mask_freezes_replica_rows(xml_data):
    """A zero update-mask entry must freeze the replica's w1 exactly, even
    though the scatter touches its rows."""
    from repro.optim.sgd import sgd_update

    NF, H, S, R = 40, 6, 10, 2
    p = {"w1": jnp.asarray(RNG.normal(size=(R, NF, H)), jnp.float32)}
    rows = jnp.asarray(RNG.integers(0, NF, (R, S)), jnp.int32)
    vals = jnp.asarray(RNG.normal(size=(R, S, H)), jnp.float32)
    g = {"w1": RowSparseGrad(rows, vals, NF)}
    mask = jnp.asarray([0.0, 1.0])
    new, _ = sgd_update(p, g, 0.5, SGDConfig(), update_mask=mask,
                        replica_dim=True)
    np.testing.assert_array_equal(np.asarray(new["w1"][0]),
                                  np.asarray(p["w1"][0]))
    assert not np.array_equal(np.asarray(new["w1"][1]), np.asarray(p["w1"][1]))
