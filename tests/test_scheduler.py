"""Scheduler invariants: sample conservation, availability-driven dispatch,
virtual clock semantics."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import CostModel, SpeedModel, VirtualClock
from repro.core.scheduler import DynamicScheduler


def make_sched(R=4, seed=0, jitter=0.0, max_gap=0.32):
    cfg = ElasticConfig(n_replicas=R)
    speed = SpeedModel(R, seed=seed, jitter=jitter, max_gap=max_gap)
    return DynamicScheduler(cfg, CostModel(speed))


class TestDynamicScheduler:
    def test_sample_conservation(self):
        s = make_sched()
        plan = s.plan_megabatch(np.array([64, 64, 64, 64]), 1000)
        assert sum(d.n_samples for d in plan.dispatches) == 1000

    def test_update_counts_match_dispatches(self):
        s = make_sched()
        plan = s.plan_megabatch(np.array([32, 64, 96, 128]), 2048)
        counts = np.zeros(4, np.int64)
        for d in plan.dispatches:
            counts[d.replica] += 1
        np.testing.assert_array_equal(counts, plan.u)

    def test_faster_replicas_do_more_updates(self):
        """With equal batch sizes, the fastest replica must accumulate the
        most dispatches over a long mega-batch (paper's Fig. 4 premise)."""
        s = make_sched(jitter=0.0)
        plan = s.plan_megabatch(np.full(4, 64), 64 * 200)
        speed = s.cost.speed.factors  # lower factor = faster
        assert plan.u[np.argmin(speed)] >= plan.u[np.argmax(speed)]

    def test_batch_scaling_equalizes_updates(self):
        """Paper's steady state: batch sizes chosen so that per-batch time is
        equal across replicas equalize update counts."""
        s = make_sched(jitter=0.0)
        speed = s.cost.speed.factors
        cm = s.cost
        # equal step time: overhead + work_cost*b_i = K / speed_i
        K = speed.max() * (cm.overhead + cm.work_cost * 128)
        b = np.maximum(1, np.round((K / speed - cm.overhead) / cm.work_cost)).astype(int)
        plan = s.plan_megabatch(b, int(b.sum()) * 50)
        assert plan.u.max() - plan.u.min() <= max(2, plan.u.max() // 20)

    def test_barrier_clock(self):
        s = make_sched()
        plan = s.plan_megabatch(np.full(4, 64), 64 * 20)
        # after the barrier every replica clock equals the max end time
        assert np.all(s.clock.t == s.clock.t[0])
        assert plan.barrier_time >= max(d.end_t for d in plan.dispatches) - 1e-12

    def test_round_ordering_within_replica(self):
        s = make_sched()
        plan = s.plan_megabatch(np.full(4, 32), 32 * 40)
        per_rep: dict = {}
        for d in plan.dispatches:
            per_rep.setdefault(d.replica, []).append(d)
        for ds in per_rep.values():
            rounds = [d.round for d in ds]
            assert rounds == list(range(len(ds)))
            starts = [d.start_t for d in ds]
            assert starts == sorted(starts)

    @given(
        R=st.integers(2, 6),
        mega=st.integers(100, 5000),
        b0=st.integers(8, 128),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_conservation_and_rounds(self, R, mega, b0):
        s = make_sched(R=R, jitter=0.05)
        plan = s.plan_megabatch(np.full(R, b0), mega)
        assert sum(d.n_samples for d in plan.dispatches) == mega
        assert plan.n_rounds == plan.u.max()
        sizes = plan.per_round_sizes(R)
        assert sizes.sum() == mega
        # each dispatch size <= its replica batch size
        for d in plan.dispatches:
            assert d.n_samples <= b0

    def test_static_plan_equal_shares(self):
        s = make_sched()
        plan = s.plan_static(64, 5)
        np.testing.assert_array_equal(plan.u, [5, 5, 5, 5])
        assert plan.samples == 64 * 5 * 4


class TestVirtualClock:
    def test_earliest_and_barrier(self):
        c = VirtualClock(3)
        c.advance(0, 5.0)
        c.advance(1, 1.0)
        assert c.earliest() == 2
        assert c.barrier() == 5.0
        assert np.all(c.t == 5.0)


class TestSpeedModel:
    def test_gap_matches_paper(self):
        sm = SpeedModel(4, max_gap=0.32, jitter=0.0, seed=1)
        assert sm.factors.max() / sm.factors.min() <= 1.32 + 1e-9
        assert sm.factors.max() / sm.factors.min() >= 1.31

    def test_single_replica_uniform(self):
        sm = SpeedModel(1)
        assert sm.factors[0] == 1.0

    def test_long_drift_keeps_fastest_at_one(self):
        """Regression: ``advance`` used to clip drifted factors to
        ``[1, 1+2*max_gap]`` without renormalizing, so a random walk could
        only ever slow replicas relative to the fastest and the whole fleet
        monotonically inflated virtual time. Relative speeds are the
        contract (heterogeneity.py docstring): the fastest factor must stay
        pinned at 1.0 under arbitrarily long drift."""
        sm = SpeedModel(4, max_gap=0.32, jitter=0.0, drift=0.05, seed=7)
        for step in range(500):
            sm.advance()
            assert sm.factors.min() == 1.0, f"fleet inflated at step {step}"
            assert sm.factors.max() <= 1.0 + 2 * sm.max_gap + 1e-12

    def test_drift_gap_can_shrink_and_grow(self):
        """With the renormalization the *relative* gap random-walks in both
        directions instead of ratcheting up to the clip ceiling."""
        sm = SpeedModel(4, max_gap=0.32, jitter=0.0, drift=0.05, seed=7)
        gaps = []
        for _ in range(300):
            sm.advance()
            gaps.append(sm.factors.max())
        diffs = np.diff(gaps)
        assert (diffs > 0).any() and (diffs < 0).any()
