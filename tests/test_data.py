"""Data pipeline: synthetic XML stats, libSVM roundtrip, batcher/provider
invariants (hypothesis where useful)."""
import os

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data.batcher import SampleStream, SparseBatcher
from repro.data.libsvm import read_libsvm, write_libsvm
from repro.data.providers import SparseProvider, TokenProvider
from repro.data.sparse import subset, train_test_split
from repro.data.xml_synth import make_paper_like, make_xml_dataset


@pytest.fixture(scope="module")
def ds():
    return make_xml_dataset(n_samples=256, n_features=512, n_classes=64, avg_nnz=24, seed=0)


class TestSynth:
    def test_shapes_and_stats(self, ds):
        assert ds.n_samples == 256
        assert ds.avg_nnz() > 10
        assert ds.avg_labels() >= 1
        # nnz varies across samples (the paper's heterogeneity source)
        nnz = np.diff(ds.indptr)
        assert nnz.std() > 2

    def test_primary_label_first(self, ds):
        for i in range(20):
            _, _, lab = ds.sample(i)
            assert len(lab) >= 1

    def test_paper_like_descriptors(self):
        d = make_paper_like("amazon-670k", scale=0.002, n_samples=64)
        assert d.n_classes >= 64
        d2 = make_paper_like("delicious-200k", scale=0.002, n_samples=64)
        assert d2.avg_nnz() > d.avg_nnz()  # delicious is denser (302 vs 76)

    def test_split_preserves_structure(self, ds):
        tr, te = train_test_split(ds, 0.25, seed=1)
        assert tr.n_samples + te.n_samples == ds.n_samples
        assert tr.n_features == ds.n_features


class TestLibSVM:
    def test_roundtrip(self, tmp_path, ds):
        small = subset(ds, np.arange(32))
        path = os.path.join(tmp_path, "d.svm")
        write_libsvm(small, path)
        back = read_libsvm(path)
        assert back.n_samples == 32
        assert back.n_features == ds.n_features
        for i in range(32):
            ai, av, al = small.sample(i)
            bi, bv, bl = back.sample(i)
            np.testing.assert_array_equal(ai, bi)
            np.testing.assert_allclose(av, bv, rtol=1e-4)
            np.testing.assert_array_equal(al, bl)


class TestBatcher:
    def test_stream_covers_epoch(self):
        s = SampleStream(100, seed=0)
        ids = s.take(100)
        assert sorted(ids.tolist()) == list(range(100))

    def test_stream_reshuffles(self):
        s = SampleStream(50, seed=0)
        e1 = s.take(50)
        e2 = s.take(50)
        assert sorted(e2.tolist()) == list(range(50))
        assert not np.array_equal(e1, e2)

    @given(take=st.integers(1, 64), slots=st.integers(64, 128))
    @settings(max_examples=20, deadline=None)
    def test_padded_batch_masks(self, ds, take, slots):
        b = SparseBatcher(ds, seed=1)
        batch = b.next_batch(take, slots)
        assert batch.feat_idx.shape[0] == slots
        assert batch.n_valid == take
        # masked rows are all zero
        assert not batch.feat_mask[take:].any()
        assert not batch.sample_mask[take:].any()

    def test_pack_truncates_to_max_nnz(self, ds):
        b = SparseBatcher(ds, max_nnz=8, seed=0)
        batch = b.next_batch(4, 4)
        assert batch.feat_idx.shape[1] == 8
        assert batch.feat_mask.sum(axis=1).max() <= 8


class TestProviders:
    def test_sparse_provider_work_units(self, ds):
        p = SparseProvider.make(ds)
        payload = p.fetch(16, 32)
        assert p.work_units(payload) == payload.total_nnz
        stacked = p.stack([payload, p.empty(32)])
        assert stacked["feat_idx"].shape[0] == 2
        assert stacked["sample_mask"][1].sum() == 0

    def test_token_provider(self):
        p = TokenProvider.make(vocab_size=97, seq_len=16)
        payload = p.fetch(3, 8)
        assert payload["tokens"].shape == (8, 16)
        assert payload["sample_mask"].sum() == 3
        assert p.work_units(payload) == 3 * 16
        assert payload["tokens"].max() < 97

    def test_token_bigram_structure(self):
        """The synthetic corpus must be more predictable than uniform."""
        p = TokenProvider.make(vocab_size=64, seq_len=128, seed=0)
        toks = p.stream.sample(64, 128)
        # successor entropy given a token should be far below log2(64)
        follows = {}
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                follows.setdefault(int(a), []).append(int(b))
        top1 = np.mean([
            max(np.bincount(v)) / len(v) for v in follows.values() if len(v) >= 20
        ])
        assert top1 > 0.1  # uniform would be ~1/64
