"""Sharded-execution integration: run the elastic train round + Algorithm-2
merge on a REAL (2, 2) mesh with 4 virtual CPU devices, and numerically
compare against the single-device path. Run in a subprocess because the
virtual device count must be fixed before jax initializes.
"""
from __future__ import annotations

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.archs import ARCHS
    from repro.launch import specs as SP
    from repro.launch.steps import make_merge_step, make_train_round
    from repro.sharding.annotate import sharding_context
    from repro.sharding.rules import (
        MeshAxes, param_specs, to_named, train_batch_specs,
    )
    from repro.models import model as MDL

    cfg = ARCHS["llama3.2-1b"].reduced()
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    ax = MeshAxes(cfg, mesh)
    R, B, S = 2, 4, 32

    params = MDL.init(cfg, jax.random.PRNGKey(0))
    replicas = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (R,) + l.shape), params
    )
    batch = SP.make_train_batch(cfg, B, S, seed=1)
    rbatch = {k: jnp.stack([v, v]) for k, v in batch.items()}
    lr = jnp.full((R,), 0.1, jnp.float32)
    mask = jnp.ones((R,), jnp.float32)

    step = make_train_round(cfg)
    merge = make_merge_step(cfg, keep_global=False)

    # ---- single device reference ----
    ref_replicas, ref_m = jax.jit(step)(replicas, rbatch, lr, mask)
    ref_merged = jax.jit(merge)(ref_replicas, jnp.asarray([0.5, 0.5]))

    # ---- sharded ----
    with sharding_context(mesh, ax.activation_rules()):
        rep_sh = to_named(param_specs(cfg, replicas, mesh, with_replica_dim=True), mesh)
        b_sh = to_named(train_batch_specs(cfg, rbatch, mesh), mesh)
        v_sh = NamedSharding(mesh, P(ax.replica))
        jstep = jax.jit(step, in_shardings=(rep_sh, b_sh, v_sh, v_sh),
                        out_shardings=(rep_sh, None))
        got_replicas, got_m = jstep(
            jax.device_put(replicas, rep_sh), jax.device_put(rbatch, b_sh),
            jax.device_put(lr, v_sh), jax.device_put(mask, v_sh),
        )
        jmerge = jax.jit(merge, in_shardings=(rep_sh, v_sh),
                         out_shardings=rep_sh)
        got_merged = jmerge(got_replicas,
                            jax.device_put(jnp.asarray([0.5, 0.5]), v_sh))

    np.testing.assert_allclose(
        np.asarray(ref_m["loss"]), np.asarray(got_m["loss"]), rtol=2e-3
    )
    for a, b in zip(jax.tree_util.tree_leaves(ref_merged),
                    jax.tree_util.tree_leaves(got_merged)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=3e-2, atol=3e-3,
        )
    print("SHARDED_INTEGRATION_OK devices=", jax.device_count())
""")


@pytest.mark.slow
def test_sharded_train_round_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SHARDED_INTEGRATION_OK" in r.stdout
