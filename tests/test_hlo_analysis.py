"""HLO text analyzer: trip-count roll-up + collective accounting against
hand-built HLO and real compiled programs with known analytic costs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (
    Costs, analyze, shape_numel_bytes,
)


def test_shape_numel_bytes():
    assert shape_numel_bytes("f32[4,8]{1,0}") == (32, 128)
    assert shape_numel_bytes("bf16[2,3]{1,0}") == (6, 12)
    n, b = shape_numel_bytes("(f32[4]{0}, s32[2]{0})")
    assert n == 6 and b == 24


def test_scan_trip_count_rollup():
    """Fwd+bwd of a 10-step scan of DxD matmuls: analytic = 2D^3 * 10 * 2
    (forward dot + dL/dx dot; weights are not differentiated)."""
    d = 128

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    c = jax.jit(jax.value_and_grad(f)).lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((10, d, d), jnp.float32),
    ).compile()
    rolled = analyze(c.as_text())
    analytic = 2 * d ** 3 * 10 * 2
    assert abs(rolled.flops - analytic) / analytic < 0.05, (
        rolled.flops, analytic
    )


def test_unrolled_matmul_flops():
    d = 256
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
    ).compile()
    rolled = analyze(c.as_text())
    assert abs(rolled.flops - 2 * d ** 3) / (2 * d ** 3) < 0.01


def test_collective_bytes_counted():
    """psum over 8 virtual devices shows up as all-reduce bytes."""
    hlo = """
HloModule test

ENTRY %main.1 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  ROOT %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
}
"""
    r = analyze(hlo)
    assert r.collective_bytes["all-reduce"] == 128 * 256 * 4
    assert r.collective_counts["all-reduce"] == 1


def test_costs_accumulate():
    a, b = Costs(flops=1.0), Costs(flops=2.0)
    b.collective_bytes["all-to-all"] = 5.0
    a.add(b, mult=3.0)
    assert a.flops == 7.0
    assert a.collective_bytes["all-to-all"] == 15.0
    assert a.total_collective_bytes == 15.0
