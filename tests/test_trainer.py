"""Integration tests: the full elastic training loop on the paper's workload
(synthetic XML data + 3-layer sparse MLP) for all five algorithms."""
import numpy as np
import pytest

from repro.configs.base import ElasticConfig
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model


@pytest.fixture(scope="module")
def xml_data():
    full = make_xml_dataset(
        n_samples=3072, n_features=1024, n_classes=128, avg_nnz=32, seed=0
    )
    return train_test_split(full, 0.15)


@pytest.fixture(scope="module")
def model():
    return make_model(XMLMLPConfig(n_features=1024, n_classes=128, hidden=128))


def run(algo, xml_data, model, R=4, mbs=8, mega=30, seed=3, **kw):
    ds, test = xml_data
    prov = SparseProvider.make(ds, seed=seed)
    cfg = ElasticConfig.from_bmax(
        64, algorithm=algo, n_replicas=R, mega_batch=mega, **kw
    )
    tr = ElasticTrainer(model, prov, cfg, base_lr=1.0, seed=seed)
    tb = prov.test_batches(test, cfg.b_max)
    return tr.run(mbs, test_batches=tb)


@pytest.mark.parametrize("algo", ["adaptive", "elastic", "sync", "crossbow"])
def test_algorithm_learns(xml_data, model, algo):
    state, mlog = run(algo, xml_data, model)
    accs = mlog.column("accuracy")
    assert accs[-1] > 0.35, f"{algo} failed to learn: {accs}"
    losses = mlog.column("train_loss")
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_single_replica_learns(xml_data, model):
    state, mlog = run("single", xml_data, model, R=1, mbs=6)
    assert mlog.column("accuracy")[-1] > 0.2


def test_adaptive_batch_sizes_diverge(xml_data, model):
    """With heterogeneous replicas the batch sizes must adapt away from the
    initial value (paper Fig. 12a)."""
    state, mlog = run("adaptive", xml_data, model, mbs=6, mega=40)
    final_b = np.asarray(mlog.records[-1]["b"])
    assert final_b.min() < 64.0  # somebody got scaled down
    assert np.all(final_b >= 8.0)  # b_min respected
    assert np.all(final_b <= 64.0)  # b_max respected


def test_adaptive_updates_equalize(xml_data, model):
    """Batch scaling should push update counts toward equality over time."""
    state, mlog = run("adaptive", xml_data, model, mbs=10, mega=40)
    spreads = [max(r["u"]) - min(r["u"]) for r in mlog.records]
    early = np.mean(spreads[:3])
    late = np.mean(spreads[-3:])
    assert late <= early + 1  # must not grow

def test_adaptive_beats_elastic_time_to_accuracy(xml_data, model):
    """The paper's headline claim (Fig. 6): adaptive reaches a fixed accuracy
    in less (virtual) time than static elastic averaging under GPU
    heterogeneity."""
    _, mlog_a = run("adaptive", xml_data, model, mbs=10, mega=40, seed=5)
    _, mlog_e = run("elastic", xml_data, model, mbs=10, mega=40, seed=5)
    target = 0.45
    tta_a = mlog_a.time_to_accuracy(target)
    tta_e = mlog_e.time_to_accuracy(target)
    assert tta_a is not None, "adaptive never reached the target"
    if tta_e is not None:
        assert tta_a <= tta_e * 1.15  # allow small-noise slack


def test_elastic_equals_adaptive_on_single_gpu(xml_data, model):
    """Paper §5.2: on one GPU Adaptive and Elastic are the same algorithm."""
    _, ma = run("adaptive", xml_data, model, R=1, mbs=4, seed=7)
    _, me = run("elastic", xml_data, model, R=1, mbs=4, seed=7)
    np.testing.assert_allclose(
        ma.column("train_loss"), me.column("train_loss"), rtol=1e-4
    )


def test_sync_replicas_stay_identical(xml_data, model):
    """Gradient aggregation keeps all replicas bitwise-identical."""
    ds, _ = xml_data
    prov = SparseProvider.make(ds)
    cfg = ElasticConfig.from_bmax(64, algorithm="sync", n_replicas=4, mega_batch=8)
    tr = ElasticTrainer(model, prov, cfg, base_lr=0.5)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    import jax

    for leaf in jax.tree_util.tree_leaves(state.replicas):
        arr = np.asarray(leaf)
        for r in range(1, arr.shape[0]):
            np.testing.assert_allclose(arr[0], arr[r], rtol=1e-5, atol=1e-6)


def test_merge_resets_replicas_to_global(xml_data, model):
    ds, _ = xml_data
    prov = SparseProvider.make(ds)
    cfg = ElasticConfig.from_bmax(64, algorithm="adaptive", n_replicas=4, mega_batch=8)
    tr = ElasticTrainer(model, prov, cfg, base_lr=0.5)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    import jax

    g = state.global_model
    for gl, rl in zip(
        jax.tree_util.tree_leaves(g), jax.tree_util.tree_leaves(state.replicas)
    ):
        for r in range(np.asarray(rl).shape[0]):
            np.testing.assert_allclose(np.asarray(rl)[r], np.asarray(gl), rtol=1e-6)


def test_metrics_log_contents(xml_data, model):
    _, mlog = run("adaptive", xml_data, model, mbs=3)
    rec = mlog.records[-1]
    for key in ("u", "b", "lr", "alphas", "pert_active", "virtual_time", "accuracy"):
        assert key in rec
    assert len(rec["u"]) == 4
    assert abs(sum(rec["alphas"]) - 1.0) < 0.25  # perturbation may denormalize


def test_evaluate_cache_tracks_swapped_test_set(xml_data, model):
    """Regression: the staged-eval cache was keyed by list identity (PR 3),
    so rebuilding or mutating the test list between calls served stale
    device batches. The content fingerprint (length + first/last payload
    ids) must restage when the set changes — including an in-place mutation
    of the *same* list object — while repeated calls with the unchanged
    set still hit the cache."""
    ds, test = xml_data
    prov = SparseProvider.make(ds)
    cfg = ElasticConfig.from_bmax(64, algorithm="adaptive", n_replicas=2,
                                  mega_batch=4)
    tr = ElasticTrainer(model, prov, cfg, base_lr=0.5)
    state = tr.init_state()
    batches_a = prov.test_batches(test, cfg.b_max, max_samples=256)
    batches_b = prov.test_batches(ds, cfg.b_max, max_samples=256)

    ev_a = tr.evaluate(state.global_model, batches_a)
    staged = tr._eval_batches
    assert tr.evaluate(state.global_model, batches_a) == ev_a
    assert tr._eval_batches is staged            # unchanged set: cache hit

    # a different list object with different payloads restages
    ev_b = tr.evaluate(state.global_model, batches_b)
    assert tr._eval_batches is not staged
    assert ev_b != ev_a                           # results track the new set

    # mutating the SAME list object in place must also invalidate
    shared = list(batches_b)
    ev_shared = tr.evaluate(state.global_model, shared)
    assert ev_shared == ev_b
    shared[:] = batches_a
    ev_swapped = tr.evaluate(state.global_model, shared)
    assert ev_swapped == ev_a, "stale staged batches served after mutation"
