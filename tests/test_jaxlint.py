"""tools/jaxlint test suite (ISSUE 8).

Layers:

* fixture pairs — every JL rule flags its bad fixture and passes its good
  twin (the fixtures are the rules' executable specification);
* suppression / baseline — inline ``# jaxlint: disable=`` directives,
  file-level directives, and the baseline round-trip
  (write → reload → subtract);
* self-check — the real repo lints clean with the *shipped* baseline, and
  that baseline is empty (ISSUE 8 policy: exceptions are inline, with
  reasons);
* RetraceSentinel — zero count on cached calls, a raise on a deliberately
  shape-polymorphic re-jit, count-only mode.
"""
from __future__ import annotations

import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:  # `python -m pytest` from the root adds it;
    sys.path.insert(0, REPO_ROOT)  # direct pytest invocations may not

from tools.jaxlint import engine, rules  # noqa: E402
from tools.jaxlint.__main__ import DEFAULT_BASELINE, main  # noqa: E402

FIXTURES = os.path.join(REPO_ROOT, "tools", "jaxlint", "fixtures")
ALL_RULES = sorted(rules.RULES)


def lint_fixture(path: str, rule: str) -> engine.LintResult:
    return engine.lint([path], root=FIXTURES, select=[rule])


# path-scoped rules: their fixtures must *live* under a matching module
# path, so they ship as directories instead of flat files
_DIR_FIXTURE_KINDS = {
    ("JL006", "good"), ("JL102", "bad"), ("JL102", "good"),
    ("JL105", "bad"), ("JL105", "good"),
}


def fixture_path(rule: str, kind: str) -> str:
    if (rule, kind) in _DIR_FIXTURE_KINDS:
        return os.path.join(FIXTURES, f"{rule.lower()}_{kind}")
    return os.path.join(FIXTURES, f"{rule}_{kind}.py")


# --------------------------------------------------------------------------
# fixture pairs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rule", ALL_RULES)
def test_bad_fixture_flags(rule):
    result = lint_fixture(fixture_path(rule, "bad"), rule)
    assert not result.errors
    assert result.findings, f"{rule} bad fixture produced no findings"
    assert {f.rule for f in result.findings} == {rule}


@pytest.mark.parametrize("rule", ALL_RULES)
def test_good_fixture_passes(rule):
    result = lint_fixture(fixture_path(rule, "good"), rule)
    assert not result.errors
    assert result.findings == [], (
        f"{rule} good fixture flagged: "
        + "; ".join(f.render() for f in result.findings)
    )


def test_expected_bad_finding_counts():
    """Pin the per-fixture finding counts: a rule that silently stops
    seeing one of its violation shapes should fail loudly here."""
    expected = {"JL001": 4, "JL002": 3, "JL003": 1, "JL004": 3,
                "JL005": 2, "JL006": 2, "JL007": 5,
                "JL101": 3, "JL102": 2, "JL103": 2, "JL104": 4,
                "JL105": 2, "JL106": 2}
    got = {
        rule: len(lint_fixture(fixture_path(rule, "bad"), rule).findings)
        for rule in ALL_RULES
    }
    assert got == expected


# --------------------------------------------------------------------------
# suppression + baseline
# --------------------------------------------------------------------------

_VIOLATION = "import jax\n\n\ndef f(x, t):\n    jax.debug.callback(t, x)\n    return x\n"


def test_inline_suppression(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATION)
    r = engine.lint([str(p)], root=str(tmp_path), select=["JL006"])
    assert len(r.findings) == 1 and not r.suppressed

    p.write_text(_VIOLATION.replace(
        "jax.debug.callback(t, x)",
        "jax.debug.callback(t, x)  # jaxlint: disable=JL006 — test reason",
    ))
    r = engine.lint([str(p)], root=str(tmp_path), select=["JL006"])
    assert not r.findings and len(r.suppressed) == 1


def test_file_level_suppression(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("# jaxlint: disable-file=JL006\n" + _VIOLATION)
    r = engine.lint([str(p)], root=str(tmp_path), select=["JL006"])
    assert not r.findings and len(r.suppressed) == 1


def test_suppression_is_rule_specific(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATION.replace(
        "jax.debug.callback(t, x)",
        "jax.debug.callback(t, x)  # jaxlint: disable=JL001",
    ))
    r = engine.lint([str(p)], root=str(tmp_path), select=["JL006"])
    assert len(r.findings) == 1  # disabling JL001 must not silence JL006


def test_baseline_round_trip(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATION)
    first = engine.lint([str(p)], root=str(tmp_path), select=["JL006"])
    assert len(first.findings) == 1

    bl = tmp_path / "baseline.txt"
    engine.write_baseline(str(bl), first.findings)
    entries = engine.load_baseline(str(bl))
    assert len(entries) == 1

    second = engine.lint(
        [str(p)], root=str(tmp_path), select=["JL006"], baseline=entries
    )
    assert not second.findings and len(second.baselined) == 1

    # the fingerprint is line-number independent: shifting the file down
    # must not resurrect the baselined finding
    p.write_text("\n\n" + _VIOLATION)
    third = engine.lint(
        [str(p)], root=str(tmp_path), select=["JL006"], baseline=entries
    )
    assert not third.findings and len(third.baselined) == 1


def test_cli_exit_codes(tmp_path, capsys):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATION)
    bl = tmp_path / "empty_baseline.txt"
    bl.write_text("")
    assert main([str(p), "--root", str(tmp_path),
                 "--baseline", str(bl)]) == 1
    out = capsys.readouterr().out
    assert "JL006" in out

    p.write_text("x = 1\n")
    assert main([str(p), "--root", str(tmp_path),
                 "--baseline", str(bl)]) == 0


# --------------------------------------------------------------------------
# rule families + new CLI surface (ISSUE 10)
# --------------------------------------------------------------------------


def test_rule_families_partition_the_registry():
    fams = {rule: engine.rule_family(rule) for rule in ALL_RULES}
    assert set(fams.values()) == set(engine.FAMILIES)
    assert all(
        f == ("concurrency" if int(r[2:]) >= 100 else "jit")
        for r, f in fams.items()
    )


def test_family_selection_filters_rules(tmp_path):
    # one JL006 (jit) violation + one JL103 (concurrency) violation
    p = tmp_path / "mod.py"
    p.write_text(
        "import threading\n\nimport jax\n\n\n"
        "def f(x, t):\n"
        "    jax.debug.callback(t, x)\n"
        "    worker = threading.Thread(target=f)\n"
        "    worker.start()\n"
        "    return x\n"
    )
    both = engine.lint([str(p)], root=str(tmp_path))
    jit = engine.lint([str(p)], root=str(tmp_path), family="jit")
    conc = engine.lint([str(p)], root=str(tmp_path), family="concurrency")
    assert {f.rule for f in jit.findings} == {"JL006"}
    assert {f.rule for f in conc.findings} == {"JL103"}
    assert {f.rule for f in both.findings} == {"JL006", "JL103"}


def test_cli_explain_prints_contract_and_fixtures(capsys):
    assert main(["--explain", "JL104"]) == 0
    out = capsys.readouterr().out
    assert "JL104" in out and "concurrency" in out
    assert "good fixture" in out and "bad fixture" in out

    assert main(["--explain", "JL999"]) == 2


def test_cli_github_format(tmp_path, capsys):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATION)
    bl = tmp_path / "empty_baseline.txt"
    bl.write_text("")
    assert main([str(p), "--root", str(tmp_path), "--baseline", str(bl),
                 "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert out.startswith("::error file=")
    assert "title=jaxlint JL006" in out


def test_cli_internal_error_exit_code(tmp_path, monkeypatch, capsys):
    """A crashing rule must exit 3 (broken linter), never 0 (clean)."""

    class Broken:
        code = "JL999"
        summary = "always crashes"
        family = "jit"

        def run(self, project):
            raise RuntimeError("boom")

    monkeypatch.setitem(rules.RULES, "JL999", Broken)
    p = tmp_path / "mod.py"
    p.write_text("x = 1\n")
    assert main([str(p), "--root", str(tmp_path),
                 "--baseline", str(tmp_path / "none.txt")]) == 3
    err = capsys.readouterr().err
    assert "JL999" in err and "crashed" in err


def test_concurrency_family_repo_sweep_is_clean():
    """The new family's own self-check: src/benchmarks/scripts carry no
    active JL1xx findings (fixes landed in this PR; the one accepted
    exception is inline-disabled with a reason)."""
    result = engine.lint(
        ["src", "benchmarks", "scripts"],
        root=REPO_ROOT,
        baseline=engine.load_baseline(DEFAULT_BASELINE),
        family="concurrency",
    )
    assert not result.errors and not result.internal_errors
    assert result.findings == [], "\n".join(
        f.render() for f in result.findings
    )
    # the JL104 lease-publish disable is load-bearing: it must exist
    assert any(s.rule == "JL104" for s in result.suppressed)


# --------------------------------------------------------------------------
# self-check: the repo itself
# --------------------------------------------------------------------------


def test_repo_lints_clean():
    result = engine.lint(
        ["src", "benchmarks", "scripts"],
        root=REPO_ROOT,
        baseline=engine.load_baseline(DEFAULT_BASELINE),
    )
    assert not result.errors, result.errors
    assert result.findings == [], "\n".join(
        f.render() for f in result.findings
    )
    assert result.n_files > 50  # the sweep actually saw the codebase


def test_shipped_baseline_is_empty():
    assert engine.load_baseline(DEFAULT_BASELINE) == set(), (
        "ISSUE 8 policy: accepted exceptions take inline disables with "
        "reasons, not baseline entries"
    )


def test_traced_surface_covers_known_modules():
    """The call graph must keep reaching the known traced closure — an
    import-resolution regression would silently turn JL001/JL002 into
    no-ops (every function 'unreachable', nothing checked)."""
    project = engine.load_project(["src"], REPO_ROOT)
    traced = {f.qualname for f in project.callgraph.traced_functions()}
    for expected in (
        "repro.core.trainer:ElasticTrainer._build_jits.round_body",
        "repro.core.trainer:ElasticTrainer._build_jits.make_megabatch_fn.megabatch_fn",
        "repro.optim.sgd:sgd_update",
        "repro.utils.tree:tree_map",
        "repro.core.algorithms.sync:mean_grads",
        "repro.core.algorithms.crossbow:crossbow_correct",
    ):
        assert expected in traced, f"{expected} fell out of the traced set"


# --------------------------------------------------------------------------
# RetraceSentinel
# --------------------------------------------------------------------------


def test_sentinel_counts_and_budget():
    import jax
    import jax.numpy as jnp

    from tools.jaxlint.sentinel import (
        RetraceBudgetExceeded,
        RetraceSentinel,
    )

    f = jax.jit(lambda x: x * 2 + 1)
    x4 = jnp.ones(4)
    f(x4)  # warmup compiles outside any sentinel

    with RetraceSentinel(budget=0) as s:
        f(x4)
        f(x4)
    assert s.count == 0

    # deliberately shape-polymorphic re-jit: new shape -> fresh program
    with pytest.raises(RetraceBudgetExceeded, match="budget 0"):
        with RetraceSentinel(budget=0, label="poly"):
            f(jnp.ones(8))

    with RetraceSentinel(budget=None) as s:  # count-only mode never raises
        f(jnp.ones(16))
    assert s.count >= 1


def test_sentinel_does_not_mask_body_exception():
    from tools.jaxlint.sentinel import RetraceSentinel

    with pytest.raises(ValueError, match="inner"):
        with RetraceSentinel(budget=0):
            raise ValueError("inner")


def test_sentinel_rejects_negative_budget():
    from tools.jaxlint.sentinel import RetraceSentinel

    with pytest.raises(ValueError):
        RetraceSentinel(budget=-1)
