"""Unit + property tests for Algorithms 1 & 2 (the paper's core math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import ElasticConfig
from repro.core import adaptive_sgd as asgd

CFG = ElasticConfig(b_min=32, b_max=256, beta=16.0, pert_thr=0.1, delta=0.1)


# ---------------------------------------------------------------- Algorithm 1
class TestBatchSizeScaling:
    def test_faster_replica_gets_larger_batch(self):
        b = np.array([128.0, 128.0])
        lr = np.array([0.1, 0.1])
        u = np.array([10, 6])
        nb, nlr = asgd.batch_size_scaling(b, lr, u, CFG)
        assert nb[0] == 128 + 16 * 2  # beta * (u0 - mean)
        assert nb[1] == 128 - 16 * 2
        # linear scaling rule
        assert nlr[0] == pytest.approx(0.1 * nb[0] / 128)
        assert nlr[1] == pytest.approx(0.1 * nb[1] / 128)

    def test_equal_updates_no_change(self):
        b = np.array([100.0, 100.0, 100.0])
        lr = np.array([0.1, 0.1, 0.1])
        nb, nlr = asgd.batch_size_scaling(b, lr, np.array([5, 5, 5]), CFG)
        np.testing.assert_array_equal(nb, b)
        np.testing.assert_array_equal(nlr, lr)

    def test_bounds_respected(self):
        # at b_max already: increase would exceed -> unchanged (line 3 guard)
        b = np.array([256.0, 64.0])
        lr = np.array([0.2, 0.05])
        nb, _ = asgd.batch_size_scaling(b, lr, np.array([20, 2]), CFG)
        assert nb[0] == 256.0
        # decrease below b_min blocked (line 6 guard)
        b = np.array([256.0, 33.0])
        nb, _ = asgd.batch_size_scaling(b, lr, np.array([20, 2]), CFG)
        assert nb[1] == 33.0

    @given(
        u=st.lists(st.integers(1, 50), min_size=2, max_size=8),
        b0=st.integers(32, 256),
    )
    @settings(max_examples=50, deadline=None)
    def test_property_bounds_and_lr_coupling(self, u, b0):
        R = len(u)
        b = np.full(R, float(b0))
        lr = np.full(R, 0.1)
        nb, nlr = asgd.batch_size_scaling(b, lr, np.array(u), CFG)
        # batch sizes stay within [b_min, b_max] whenever they changed
        changed = nb != b
        assert np.all(nb[changed] >= CFG.b_min - 1e-9)
        assert np.all(nb[changed] <= CFG.b_max + 1e-9)
        # lr/b ratio is invariant (linear-scaling rule)
        np.testing.assert_allclose(nlr / nb, lr / b, rtol=1e-9)

    @given(u=st.lists(st.integers(1, 50), min_size=2, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_property_change_direction(self, u):
        """Direction invariant of Algorithm 1: faster replicas (u_i > mean)
        never shrink their batch; slower ones never grow it. (Note the
        bound checks BLOCK out-of-range changes rather than clamping —
        paper lines 3/6 — so magnitude is not monotone in u.)"""
        R = len(u)
        b = np.full(R, 128.0)
        lr = np.full(R, 0.1)
        uu = np.array(u, float)
        nb, _ = asgd.batch_size_scaling(b, lr, uu, CFG)
        mu = uu.mean()
        assert np.all(nb[uu > mu] >= 128.0 - 1e-9)
        assert np.all(nb[uu < mu] <= 128.0 + 1e-9)
        assert np.all(nb[uu == mu] == 128.0)


# ---------------------------------------------------------------- Algorithm 2
class TestNormalizedMerging:
    def test_weights_from_batch_when_updates_equal(self):
        a = asgd.merge_weights(np.array([5, 5]), np.array([100.0, 300.0]))
        np.testing.assert_allclose(a, [0.25, 0.75])

    def test_weights_from_updates_when_different(self):
        a = asgd.merge_weights(np.array([6, 2]), np.array([100.0, 300.0]))
        np.testing.assert_allclose(a, [0.75, 0.25])

    def test_weights_sum_to_one(self):
        for u, b in [([3, 3, 3], [10, 20, 30]), ([1, 2, 3], [10, 10, 10])]:
            a = asgd.merge_weights(np.array(u), np.array(b, float))
            assert a.sum() == pytest.approx(1.0)

    def test_perturbation_applied_when_regularized(self):
        alphas = np.array([0.5, 0.5])
        a, active = asgd.apply_perturbation(
            alphas, np.array([8, 4]), np.array([0.01, 0.02]), CFG
        )
        assert active
        assert a[0] == pytest.approx(0.55)  # (1+delta) * 0.5
        assert a[1] == pytest.approx(0.45)

    def test_perturbation_blocked_when_unregularized(self):
        alphas = np.array([0.5, 0.5])
        a, active = asgd.apply_perturbation(
            alphas, np.array([8, 4]), np.array([0.01, 0.5]), CFG
        )
        assert not active
        np.testing.assert_array_equal(a, alphas)

    def test_perturbation_noop_when_updates_equal(self):
        # argmax == argmin impossible branch: r == s when all equal
        alphas = np.array([0.5, 0.5])
        a, active = asgd.apply_perturbation(
            alphas, np.array([4, 4]), np.array([0.01, 0.01]), CFG
        )
        assert not active

    def test_merge_momentum(self):
        replicas = {"w": jnp.stack([jnp.ones(4) * 2, jnp.ones(4) * 4])}
        g = {"w": jnp.ones(4) * 3.0}
        gp = {"w": jnp.ones(4) * 1.0}
        out = asgd.normalized_merge(replicas, jnp.array([0.5, 0.5]), g, gp, 0.9)
        # 0.5*2 + 0.5*4 + 0.9*(3-1) = 3 + 1.8
        np.testing.assert_allclose(np.asarray(out["w"]), 4.8, rtol=1e-6)

    def test_merge_memory_lean_mode(self):
        replicas = {"w": jnp.stack([jnp.ones(4) * 2, jnp.ones(4) * 4])}
        out = asgd.normalized_merge(replicas, jnp.array([0.25, 0.75]), None, None, 0.9)
        np.testing.assert_allclose(np.asarray(out["w"]), 3.5, rtol=1e-6)

    def test_merge_kernel_path_matches_jnp(self):
        """The weighted_merge Pallas routing (accelerator path; interpret
        mode here) must agree with the jnp oracle, with and without the
        momentum term."""
        rng = np.random.default_rng(0)
        replicas = {
            "w": jnp.asarray(rng.normal(size=(3, 8, 16)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3, 16)), jnp.float32),
        }
        alphas = jnp.asarray([0.2, 0.3, 0.5], jnp.float32)
        g = {k: v[0] * 1.5 for k, v in replicas.items()}
        gp = {k: v[1] * 0.5 for k, v in replicas.items()}
        for args in ((None, None, 0.0), (g, gp, 0.9)):
            want = asgd.normalized_merge(replicas, alphas, *args, use_kernel=False)
            got = asgd.normalized_merge(replicas, alphas, *args, use_kernel=True)
            for lw, lg in zip(
                jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(got)
            ):
                np.testing.assert_allclose(
                    np.asarray(lg), np.asarray(lw), rtol=1e-5, atol=1e-6
                )

    @given(
        alphas=st.lists(st.floats(0.01, 1.0), min_size=2, max_size=6),
        vals=st.lists(st.floats(-10, 10), min_size=2, max_size=6),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_merge_is_convex_combination(self, alphas, vals):
        n = min(len(alphas), len(vals))
        a = np.array(alphas[:n]); a = a / a.sum()
        replicas = {"w": jnp.asarray(np.array(vals[:n]))[:, None] * jnp.ones((n, 3))}
        merged = asgd.normalized_merge(replicas, jnp.asarray(a), None, None, 0.0)
        out = np.asarray(merged["w"])
        assert out.min() >= min(vals[:n]) - 1e-4
        assert out.max() <= max(vals[:n]) + 1e-4

    def test_replica_regularization_shape(self):
        replicas = {"a": jnp.ones((3, 5, 5)), "b": jnp.zeros((3, 7))}
        norms = asgd.replica_regularization(replicas)
        assert norms.shape == (3,)
        np.testing.assert_allclose(norms, 5.0 / 32, rtol=1e-6)  # sqrt(25)/32
