"""Optional-hypothesis shim for the property-based tests.

The container image does not always ship ``hypothesis`` (it is a dev extra,
see requirements-dev.txt). Importing through this module keeps the example-
based tests in a file collectable and green while marking every ``@given``
test as skipped when hypothesis is missing.

Usage (replaces the direct hypothesis imports):

    from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

import pytest

try:
    import hypothesis.strategies as st  # noqa: F401  (re-export)
    from hypothesis import given, settings  # noqa: F401  (re-export)

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # hypothesis not installed: stub + skip
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every strategy factory
        returns a placeholder (the test is skipped before it is called)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install -r requirements-dev.txt)"
        )(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
