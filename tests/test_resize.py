"""Elastic replica membership (DESIGN.md §6): R changes between mega-batches.

Layers:

* state-carry semantics — momentum rows survive a grow/shrink, joiners
  start at zero momentum / the merged global, CROSSBOW survivors keep their
  diverged parameters (``resize_policy='preserve'``);
* speed-model carry — measured EMAs and simulated factors survive for
  survivors, joiners start at the homogeneous prior;
* re-planning — scheduler/virtual-clock widths follow R, joiners enter at
  the barrier;
* zero-recompile contract — resizing back to a previously-seen population
  shape adds no compiled variants (``compile_cache_size``);
* bit-identity — a constant ``resize_schedule`` ({0: R}) reproduces the
  unscheduled run exactly, for every registered algorithm;
* convergence — a grow-then-shrink schedule stays within 5% of the fixed-R
  run's final loss (the acceptance bar for ``--elastic-schedule``);
* multi-device parity — vmap and sharded placements agree across resizes on
  a real 8-virtual-device mesh (subprocess, same pattern as
  tests/test_sharded_placement.py), including the sharded zero-recompile
  check.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax.tree_util as jtu
import numpy as np
import pytest

from golden.generate import build_case_trainer, make_case_dataset
from tools.jaxlint.sentinel import RetraceSentinel
from repro.configs.base import ElasticConfig
from repro.core import algorithms
from repro.core.heterogeneity import (
    CostModel,
    MeasuredSpeedModel,
    SpeedModel,
    VirtualClock,
)
from repro.core.scheduler import DynamicScheduler
from repro.core.trainer import ElasticTrainer
from repro.launch.train import parse_elastic_schedule
from repro.optim.sgd import SGDConfig


@pytest.fixture(scope="module")
def case_ds():
    return make_case_dataset()


def leaves_np(tree):
    return [np.asarray(l) for l in jtu.tree_leaves(tree)]


# --------------------------------------------------------------------------
# schedule parsing (the launcher's --elastic-schedule)
# --------------------------------------------------------------------------


def test_parse_elastic_schedule():
    assert parse_elastic_schedule("0:4,20:6,40:3") == {0: 4, 20: 6, 40: 3}
    assert parse_elastic_schedule(" 5:2 ") == {5: 2}
    assert parse_elastic_schedule("1:2,1:3") == {1: 3}  # last wins


@pytest.mark.parametrize("bad", ["", "x", "1", "1:", ":2", "1:0", "-1:2"])
def test_parse_elastic_schedule_rejects(bad):
    with pytest.raises(ValueError):
        parse_elastic_schedule(bad)


# --------------------------------------------------------------------------
# re-planning: clock / scheduler / speed models follow R
# --------------------------------------------------------------------------


def test_virtual_clock_resize_carries_survivors_joiners_at_barrier():
    c = VirtualClock(3)
    c.t[:] = [5.0, 3.0, 4.0]
    c.resize(5)
    np.testing.assert_allclose(c.t, [5.0, 3.0, 4.0, 5.0, 5.0])
    c.resize(2)
    np.testing.assert_allclose(c.t, [5.0, 3.0])


def test_scheduler_resize_plans_new_population():
    cfg = ElasticConfig(n_replicas=2)
    sched = DynamicScheduler(cfg, CostModel(SpeedModel(2, seed=0)))
    sched.plan_megabatch(np.full(2, 32), 32 * 4)
    sched.cost.speed.resize(4)
    sched.resize(ElasticConfig(n_replicas=4))
    plan = sched.plan_megabatch(np.full(4, 32), 32 * 8)
    assert len(plan.u) == 4
    assert plan.u.sum() > 0
    assert sched.clock.t.shape == (4,)


def test_speed_model_resize_prior_and_renorm():
    sm = SpeedModel(4, max_gap=0.32, jitter=0.0, seed=1)
    old = sm.factors.copy()
    sm.resize(6)
    np.testing.assert_allclose(sm.factors[:4], old)
    np.testing.assert_allclose(sm.factors[4:], 1.0)  # homogeneous prior
    # shrink to a population that may exclude the fastest: renormalized
    sm2 = SpeedModel(4, max_gap=0.32, jitter=0.0, seed=1)
    sm2.factors = np.array([1.2, 1.32, 1.0, 1.1])  # fastest is replica 2
    sm2.resize(2)
    assert sm2.factors.min() == 1.0
    np.testing.assert_allclose(sm2.factors, [1.0, 1.1], atol=1e-12)


def test_measured_speed_resize_carries_emas():
    sm = MeasuredSpeedModel(3, warmup_windows=0)
    sm.observe(0, 100, 1.0)
    sm.observe(1, 100, 2.0)
    sm.observe(2, 100, 4.0)
    sm.resize(5)  # grow: survivors keep EMAs, joiners unmeasured
    assert sm.n_replicas == 5
    np.testing.assert_allclose(sm.t_per_work[:3], [0.01, 0.02, 0.04])
    assert np.isnan(sm.t_per_work[3:]).all()
    f = sm.factors
    np.testing.assert_allclose(f[:3], [1.0, 2.0, 4.0])
    np.testing.assert_allclose(f[3:], 1.0)  # prior until min_obs windows
    sm.resize(2)  # shrink: the slowest replica leaves
    np.testing.assert_allclose(sm.factors, [1.0, 2.0])
    np.testing.assert_array_equal(sm.n_obs, [1, 1])


def test_measured_speed_resize_discards_compile_window():
    """A resize to a first-visit population shape jit-compiles inside the
    next timed window; those seconds must not corrupt the EMAs. The window
    is still counted (warmup alignment) and the one after is attributed."""
    sm = MeasuredSpeedModel(2)  # warmup_windows=1
    sm.observe_plan(np.array([10.0, 10.0]), 9.0)  # cold-start: warmup
    sm.resize(3)
    assert sm.n_windows == 1  # warmup alignment survives the resize
    sm.observe_plan(np.array([10.0, 10.0, 10.0]), 60.0,
                    u=np.array([1, 1, 1]), n_rounds=1)  # first-visit compile
    assert sm.n_windows == 2
    assert (sm.n_obs == 0).all()  # compile window never reached an EMA
    sm.observe_plan(np.array([10.0, 10.0, 10.0]), 1.0,
                    u=np.array([1, 1, 1]), n_rounds=1)  # steady state
    assert (sm.n_obs == 1).all()
    np.testing.assert_allclose(sm.factors, np.ones(3))


# --------------------------------------------------------------------------
# trainer state carry
# --------------------------------------------------------------------------


def test_resize_grow_carries_momentum_and_clones_global(case_ds):
    base = build_case_trainer("adaptive", "scan", True, case_ds)
    tr = ElasticTrainer(
        base.model, base.provider, base.cfg, sgd=SGDConfig(momentum=0.9),
        base_lr=0.5, seed=3,
    )
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    mom_before = leaves_np(state.momentum)
    new = tr.resize(state, 6)
    assert tr.cfg.n_replicas == 6
    for old_l, new_l in zip(mom_before, leaves_np(new.momentum)):
        np.testing.assert_array_equal(new_l[:4], old_l)      # survivors
        assert (new_l[4:] == 0).all()                        # joiners: zero
    # 'merge' policy: every replica (joiners included) restarts from the
    # merged global, which is also the new global/prev-global pair
    for g_l, r_l in zip(leaves_np(new.global_model), leaves_np(new.replicas)):
        for r in range(6):
            np.testing.assert_array_equal(r_l[r], g_l)
    for g_l, p_l in zip(leaves_np(new.global_model), leaves_np(new.prev_global)):
        np.testing.assert_array_equal(p_l, g_l)
    assert new.b.shape == (6,) and new.lr.shape == (6,)
    # training continues at the new width
    new, info = tr.run_megabatch(new)
    assert len(info["u"]) == 6 and np.isfinite(info["train_loss"])


def test_resize_shrink_merges_leavers(case_ds):
    """A leaving replica's updates must fold into the merged global: the
    post-shrink global differs from a merge over the survivors alone."""
    base = build_case_trainer("crossbow", "scan", True, case_ds)
    tr = ElasticTrainer(
        base.model, base.provider, base.cfg, sgd=SGDConfig(momentum=0.9),
        base_lr=0.5, seed=3,
    )
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)  # crossbow: replicas stay diverged
    reps = leaves_np(state.replicas)
    mom_before = leaves_np(state.momentum)
    alphas = np.asarray(state.b) / np.asarray(state.b).sum()
    new = tr.resize(state, 2)
    assert tr.cfg.n_replicas == 2
    for old_l, gl in zip(reps, leaves_np(new.global_model)):
        # all four old replicas (incl. the two leavers) entered the merge
        want = np.tensordot(alphas, old_l.astype(np.float64), axes=(0, 0))
        np.testing.assert_allclose(gl, want.astype(gl.dtype), rtol=1e-5,
                                   atol=1e-6)
        survivors_only = old_l[:2].mean(axis=0)
        if not np.allclose(old_l[:2], old_l[2:], atol=1e-7):
            assert not np.allclose(gl, survivors_only, atol=1e-7)
    for old_l, new_l in zip(mom_before, leaves_np(new.momentum)):
        np.testing.assert_array_equal(new_l, old_l[:2])


def test_resize_preserve_policy_keeps_survivor_params(case_ds):
    """CROSSBOW (resize_policy='preserve'): survivors keep their diverged
    parameters bit-for-bit; only joiners clone the merged center."""
    tr = build_case_trainer("crossbow", "scan", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    reps = leaves_np(state.replicas)
    new = tr.resize(state, 6)
    for old_l, new_l, gl in zip(reps, leaves_np(new.replicas),
                                leaves_np(new.global_model)):
        np.testing.assert_array_equal(new_l[:4], old_l)   # survivors as-is
        for r in range(4, 6):
            np.testing.assert_array_equal(new_l[r], gl)   # joiners: center


def test_resize_merge_policy_resets_all_replicas(case_ds):
    tr = build_case_trainer("adaptive", "scan", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    new = tr.resize(state, 2)
    for r_l, g_l in zip(leaves_np(new.replicas), leaves_np(new.global_model)):
        np.testing.assert_array_equal(r_l[0], g_l)
        np.testing.assert_array_equal(r_l[1], g_l)


def test_resize_same_R_is_noop(case_ds):
    tr = build_case_trainer("adaptive", "scan", True, case_ds)
    state = tr.init_state()
    assert tr.resize(state, 4) is state


def test_resize_single_clamps_to_noop(case_ds):
    tr = build_case_trainer("single", "scan", True, case_ds)
    state = tr.init_state()
    assert tr.resize(state, 4) is state  # resolve_n_replicas pins R=1
    assert tr.cfg.n_replicas == 1


def test_resize_fixed_policy_raises(case_ds):
    tr = build_case_trainer("elastic", "scan", True, case_ds)
    tr.algo.resize_policy = "fixed"  # instance-level override for the test
    state = tr.init_state()
    with pytest.raises(ValueError, match="resize_policy"):
        tr.resize(state, 2)


def test_resize_invalid_count_raises(case_ds):
    tr = build_case_trainer("elastic", "scan", True, case_ds)
    state = tr.init_state()
    with pytest.raises(ValueError):
        tr.resize(state, 0)


def test_sync_resize_rederives_equal_shares(case_ds):
    tr = build_case_trainer("sync", "scan", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    cfg = tr.cfg
    np.testing.assert_allclose(
        state.b, max(cfg.b_min, cfg.b_max // 4)
    )
    new = tr.resize(state, 2)
    np.testing.assert_allclose(
        new.b, max(tr.cfg.b_min, tr.cfg.b_max // 2)
    )  # global batch stays b_max at the new R


def test_resize_feeds_measured_speed_at_new_width(case_ds):
    base = build_case_trainer("adaptive", "scan", True, case_ds)
    tr = ElasticTrainer(
        base.model, base.provider, base.cfg, base_lr=0.5, seed=3,
        speed=MeasuredSpeedModel(base.cfg.n_replicas, warmup_windows=0),
    )
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    emas = tr.speed.t_per_work.copy()
    state = tr.resize(state, 6)
    np.testing.assert_array_equal(tr.speed.t_per_work[:4], emas)
    # first post-resize window: R=6 is a first-visit shape, so the executor
    # compiles inside the timed window — discarded, EMAs untouched
    state, _ = tr.run_megabatch(state)
    np.testing.assert_array_equal(tr.speed.t_per_work[:4], emas)
    assert (tr.speed.n_obs[4:] == 0).all()
    # second window is clean: every replica of the new width is measured
    state, _ = tr.run_megabatch(state)
    assert tr.speed.n_obs.shape == (6,)
    assert (tr.speed.n_obs > 0).all()


def test_resize_legacy_engine(case_ds):
    """The per-round host-loop engine resizes through the same path (its
    jitted round is shape-keyed exactly like the scan executor)."""
    tr = build_case_trainer("adaptive", "legacy_loop", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    state = tr.resize(state, 2)
    state, info = tr.run_megabatch(state)
    assert len(info["u"]) == 2 and np.isfinite(info["train_loss"])


# --------------------------------------------------------------------------
# zero-recompile contract
# --------------------------------------------------------------------------


def test_resize_revisited_population_recompiles_nothing(case_ds):
    """Resizing back to a previously-seen R (same pow2 round bucket) must
    reuse every jitted executor variant (DESIGN.md §6). Checked two ways:
    the trainer's own jit-cache census stays flat, and the RetraceSentinel
    sees zero backend compiles — the latter also covers programs the census
    cannot see (shard_map internals, helper jits)."""
    tr = build_case_trainer("elastic", "scan", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)   # R=4 variants compile
    state = tr.resize(state, 2)          # + resize merge @4
    state, _ = tr.run_megabatch(state)   # R=2 variants compile
    state = tr.resize(state, 4)          # + resize merge @2
    state, _ = tr.run_megabatch(state)   # R=4 again: cached
    state = tr.resize(state, 2)          # merge @4 again: cached
    n0 = tr.compile_cache_size()
    with RetraceSentinel(budget=0, label="revisited population"):
        state, info = tr.run_megabatch(state)
    assert np.isfinite(info["train_loss"])
    assert tr.compile_cache_size() == n0, (
        "revisiting a previously-seen population shape recompiled"
    )


# --------------------------------------------------------------------------
# bit-identity and convergence through run(resize_schedule=...)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo", sorted(algorithms.available()))
def test_constant_schedule_bit_identical(case_ds, algo):
    """``resize_schedule={0: R}`` (the '0:R' CLI schedule) must reproduce
    the never-resized run exactly, for every registered algorithm."""
    R = algorithms.get(algo).resolve_n_replicas(4)

    def go(schedule):
        tr = build_case_trainer(algo, "scan", True, case_ds)
        state, mlog = tr.run(2, resize_schedule=schedule)
        return state, [r["train_loss"] for r in mlog.records]

    st_plain, losses_plain = go(None)
    st_const, losses_const = go({0: R})
    assert losses_plain == losses_const
    for a, b in zip(leaves_np(st_plain.replicas), leaves_np(st_const.replicas)):
        np.testing.assert_array_equal(a, b)
    if st_plain.global_model is not None:
        for a, b in zip(leaves_np(st_plain.global_model),
                        leaves_np(st_const.global_model)):
            np.testing.assert_array_equal(a, b)


def test_resize_invalidates_pending_prefetch(case_ds):
    """A resize at the boundary revokes the prefetched plan (staged for the
    old population) with a full cursor rollback (DESIGN.md §8): continuing
    at the new width must match a run that never prefetched."""
    def go(prefetch):
        tr = build_case_trainer("adaptive", "scan", True, case_ds)
        tr.overlap = prefetch
        state = tr.init_state()
        state, _ = tr.run_megabatch(state, prefetch=prefetch)
        if prefetch:
            assert tr._staged is not None
        state = tr.resize(state, 6)
        if prefetch:
            assert tr._staged is None       # resize revoked it
        state, info = tr.run_megabatch(state)
        return tr, info

    tr_p, info_p = go(True)
    tr_s, info_s = go(False)
    assert info_p["train_loss"] == info_s["train_loss"]
    assert info_p["u"] == info_s["u"]
    assert tr_p.provider.state_dict() == tr_s.provider.state_dict()
    np.testing.assert_array_equal(tr_p.scheduler.clock.t,
                                  tr_s.scheduler.clock.t)


def test_constant_schedule_keeps_prefetch(case_ds):
    """``resize_schedule={mb: current_R}`` is a no-op boundary: the staged
    plan survives it (and the run stays bit-identical — covered above by
    test_constant_schedule_bit_identical, which runs with overlap on)."""
    tr = build_case_trainer("adaptive", "scan", True, case_ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)
    assert tr._staged is not None
    state = tr.resize(state, tr.cfg.n_replicas)     # same R: early return
    assert tr._staged is not None


def test_grow_then_shrink_converges_within_5pct(case_ds):
    """The acceptance bar: an elastic run that grows then shrinks stays
    within 5% of the fixed-R final loss on the bench task."""
    def go(schedule):
        tr = build_case_trainer("adaptive", "scan", True, case_ds)
        _, mlog = tr.run(8, resize_schedule=schedule)
        return mlog

    fixed = go(None)
    elastic = go({2: 6, 5: 3})  # grow 4->6, shrink 6->3
    rs = [r["n_replicas"] for r in elastic.records]
    assert rs == [4, 4, 6, 6, 6, 3, 3, 3]
    lf = fixed.records[-1]["train_loss"]
    le = elastic.records[-1]["train_loss"]
    assert np.isfinite(lf) and np.isfinite(le)
    assert abs(le - lf) / lf < 0.05, (lf, le)
    # both runs actually learned
    assert le < elastic.records[0]["train_loss"]


def test_launcher_elastic_schedule_end_to_end():
    from repro.launch import train as train_mod

    state, mlog = train_mod.main([
        "--workload", "xml", "--algorithm", "adaptive",
        "--elastic-schedule", "0:2,2:4,4:2",
        "--megabatches", "6", "--mega-batch", "4", "--b-max", "16",
        "--samples", "512", "--features", "256", "--classes", "64",
        "--avg-nnz", "16", "--hidden", "32", "--lr", "1.0",
    ])
    assert [r["n_replicas"] for r in mlog.records] == [2, 2, 4, 4, 2, 2]
    assert np.isfinite(mlog.records[-1]["train_loss"])


# --------------------------------------------------------------------------
# multi-device parity across resizes (the CI multi-device job runs this)
# --------------------------------------------------------------------------

RESIZE_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    import numpy as np
    import jax
    import jax.tree_util as jtu

    assert len(jax.devices()) == 8, jax.devices()

    from golden.generate import build_case_trainer, make_case_dataset
    from repro.sharding.rules import REPLICA_AXIS

    ds = make_case_dataset()
    SCHEDULE = {1: 8, 3: 2}   # grow 4->8 (8 shards), shrink 8->2 (2 shards)

    def run(algo, placement):
        tr = build_case_trainer(algo, "scan", True, ds, placement=placement)
        state = tr.init_state()
        losses = []
        for mb in range(4):
            if mb in SCHEDULE:
                state = tr.resize(state, SCHEDULE[mb])
            state, info = tr.run_megabatch(state)
            losses.append(info["train_loss"])
        return tr, state, losses

    def close(a, b, rtol, atol):
        for la, lb in zip(jtu.tree_leaves(a), jtu.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=rtol, atol=atol)

    for algo in ("adaptive", "crossbow", "delayed_sync"):
        tv, sv, lv = run(algo, "vmap")
        ts, ss, ls = run(algo, "sharded")
        np.testing.assert_allclose(lv, ls, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{algo} losses diverged")
        close(sv.replicas, ss.replicas, rtol=2e-3, atol=1e-5)
        if sv.global_model is not None:
            close(sv.global_model, ss.global_model, rtol=2e-3, atol=1e-5)
        print(f"OK {algo}")

    # sharded zero-recompile: revisiting an (R, shard-count) pair reuses
    # the cached executors and their compiled variants
    tr = build_case_trainer("elastic", "scan", True, ds, placement="sharded")
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)   # R=4 over 4 shards
    state = tr.resize(state, 8)
    state, _ = tr.run_megabatch(state)   # R=8 over 8 shards
    state = tr.resize(state, 4)
    state, _ = tr.run_megabatch(state)   # 4-shard executors: cached
    state = tr.resize(state, 8)
    n0 = tr.compile_cache_size()
    state, info = tr.run_megabatch(state)
    assert np.isfinite(info["train_loss"])
    assert tr.compile_cache_size() == n0, "sharded resize revisit recompiled"
    print("OK zero-recompile")
    print("RESIZE-PARITY-PASSED")
""")


@pytest.mark.slow
def test_resize_sharded_vs_vmap_multidevice_parity():
    """Grow 4->8 then shrink 8->2 on a real multi-shard replica mesh: the
    sharded placement must track the vmap oracle through both membership
    changes, and revisiting a shard count must not recompile."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "tests"),
         env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", RESIZE_PARITY_SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, (
        f"resize parity subprocess failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "RESIZE-PARITY-PASSED" in proc.stdout
