"""Conformance suite for the pluggable Algorithm API (DESIGN.md §4).

Three layers of guarantees:

1. **Seed-behavior goldens** — every pre-refactor algorithm must reproduce
   the losses and merged-parameter fingerprints recorded from the five-way
   ``if algo == ...`` trainer before the strategy refactor
   (tests/golden/algorithms_seed.json, regenerated only deliberately via
   tests/golden/generate.py), on both engines, sparse and dense paths.
2. **Registry-wide conformance** — every *registered* algorithm (including
   ones added after the goldens, e.g. ``delayed_sync``, and any future
   plugin) must produce identical results on the scan and legacy engines
   and must match its dense-autodiff oracle on the sparse path.
3. **Public-API extensibility** — a toy algorithm registered through
   nothing but ``@algorithms.register`` runs end-to-end, including through
   ``launch/train.py --algorithm``.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np
import pytest

from repro.core import algorithms

# the case definition (dataset, model, trainer settings, fingerprinting) is
# owned by the golden generator — importing it guarantees the replayed runs
# cannot drift from what the goldens were recorded with
from golden.generate import (
    ENGINES,
    N_MEGA,
    OUT as GOLDEN_PATH,
    build_case_trainer,
    fingerprint as _fingerprint,
    make_case_dataset,
)

with open(GOLDEN_PATH) as f:
    GOLDEN = json.load(f)
assert GOLDEN["n_megabatches"] == N_MEGA, (
    "golden file out of date — regenerate via tests/golden/generate.py"
)

SEED_ALGOS = sorted({k.split("|")[0] for k in GOLDEN["cases"]})

_cache: dict = {}


def _case(algo: str, engine: str, sparse: bool, placement: str = "vmap"):
    """One deterministic training run; cached — each (algo, engine, path)
    combination is executed once and shared by all assertions on it."""
    key = (algo, engine, sparse, placement)
    if key not in _cache:
        if "ds" not in _cache:
            _cache["ds"] = make_case_dataset()
        tr = build_case_trainer(algo, engine, sparse, _cache["ds"],
                                placement=placement)
        state = tr.init_state()
        infos = []
        for _ in range(N_MEGA):
            state, info = tr.run_megabatch(state)
            infos.append(info)
        _cache[key] = (state, infos)
    return _cache[key]


def _assert_tree_close(a, b, **tol):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **tol)


# --------------------------------------------------------------------------
# registry basics
# --------------------------------------------------------------------------


def test_builtin_algorithms_registered():
    avail = algorithms.available()
    for name in (*SEED_ALGOS, "delayed_sync"):
        assert name in avail, f"{name} missing from registry: {avail}"


def test_unknown_algorithm_raises_with_listing():
    with pytest.raises(KeyError, match="registered"):
        algorithms.get("definitely_not_an_algorithm")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        @algorithms.register("adaptive")
        class Impostor(algorithms.Algorithm):
            pass


def test_register_requires_algorithm_subclass():
    with pytest.raises(TypeError):
        algorithms.register("not_a_strategy")(dict)


def test_ci_smoke_matrix_covers_registry():
    """The CI algorithm-smoke matrix must list exactly the built-in
    registry — registering a 7th algorithm without extending the matrix
    (or vice versa) fails here, in tier-1, not in a forgotten YAML."""
    ci = os.path.join(os.path.dirname(__file__), "..", ".github",
                      "workflows", "ci.yml")
    if not os.path.exists(ci):
        pytest.skip("no CI workflow in this checkout")
    with open(ci) as f:
        text = f.read()
    m = re.search(r"algorithm:\s*\n?\s*\[([^\]]+)\]", text)
    assert m, "could not locate the algorithm matrix in ci.yml"
    matrix = {a.strip() for a in m.group(1).replace("\n", " ").split(",")}
    # toy_* strategies are registered by this test module, not shipped
    builtin = {n for n in algorithms.available() if not n.startswith("toy_")}
    assert matrix == builtin, (
        f"CI matrix {sorted(matrix)} != registry {sorted(builtin)}; "
        "update .github/workflows/ci.yml"
    )


# --------------------------------------------------------------------------
# 1. seed-behavior goldens (pre-refactor parity)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sparse", [True, False], ids=["sparse", "dense"])
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("algo", SEED_ALGOS)
def test_matches_pre_refactor_golden(algo, engine, sparse):
    want = GOLDEN["cases"][f"{algo}|{engine}|{'sparse' if sparse else 'dense'}"]
    state, infos = _case(algo, engine, sparse)

    np.testing.assert_allclose(
        [i["train_loss"] for i in infos], want["train_loss"],
        rtol=1e-5, atol=1e-7,
    )
    np.testing.assert_allclose(
        [i["train_accuracy"] for i in infos], want["train_accuracy"],
        rtol=1e-5, atol=1e-6,
    )
    assert [i["u"] for i in infos] == want["u"]
    np.testing.assert_allclose(np.asarray(state.b), want["b"], rtol=1e-12)
    np.testing.assert_allclose(np.asarray(state.lr), want["lr"], rtol=1e-12)

    merged = state.global_model
    if merged is None:
        merged = jax.tree_util.tree_map(lambda l: l[0], state.replicas)
    for k, fp in _fingerprint(merged).items():
        np.testing.assert_allclose(fp["mean"], want["global"][k]["mean"],
                                   rtol=1e-5, atol=1e-8, err_msg=f"global/{k}")
        np.testing.assert_allclose(fp["l2"], want["global"][k]["l2"],
                                   rtol=1e-5, err_msg=f"global/{k}")
    for k, fp in _fingerprint(state.replicas).items():
        np.testing.assert_allclose(fp["l2"], want["replicas"][k]["l2"],
                                   rtol=1e-5, err_msg=f"replicas/{k}")


# --------------------------------------------------------------------------
# 2. registry-wide conformance: every registered algorithm, both engines,
#    sparse and dense gradient paths
# --------------------------------------------------------------------------


@pytest.mark.parametrize("algo", algorithms.available())
def test_engine_parity(algo):
    """scan and legacy_loop must agree on losses, update counts and params."""
    st_s, inf_s = _case(algo, "scan", True)
    st_l, inf_l = _case(algo, "legacy_loop", True)
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_s], [i["train_loss"] for i in inf_l],
        rtol=2e-4, atol=1e-5,
    )
    assert [i["u"] for i in inf_s] == [i["u"] for i in inf_l]
    _assert_tree_close(st_s.replicas, st_l.replicas, rtol=1e-4, atol=1e-5)
    if st_s.global_model is not None and st_l.global_model is not None:
        _assert_tree_close(st_s.global_model, st_l.global_model,
                           rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", algorithms.available())
def test_sparse_dense_parity(algo):
    """The row-sparse gradient path must match its dense-autodiff oracle."""
    st_s, inf_s = _case(algo, "scan", True)
    st_d, inf_d = _case(algo, "scan", False)
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_s], [i["train_loss"] for i in inf_d],
        rtol=2e-4, atol=1e-5,
    )
    _assert_tree_close(st_s.replicas, st_d.replicas, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("algo", algorithms.available())
def test_metrics_contract(algo):
    """Every strategy must fill the engine's full metrics-log contract."""
    _, infos = _case(algo, "scan", True)
    rec = infos[-1]
    for key in ("u", "b", "lr", "alphas", "pert_active", "train_loss",
                "train_accuracy", "virtual_time", "n_rounds"):
        assert key in rec, f"{algo} missing {key}"
    R = algorithms.get(algo).resolve_n_replicas(4)
    assert len(rec["u"]) == len(rec["b"]) == len(rec["alphas"]) == R
    assert np.isfinite(rec["train_loss"])


# --------------------------------------------------------------------------
# sharded placement (DESIGN.md §5): the shard_map replica executor must be a
# drop-in for the vmapped one, for every registered algorithm x both engines
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("algo", algorithms.available())
def test_sharded_placement_parity(algo, engine):
    """placement='sharded' must reproduce the vmap path. In-process jax has
    one device, so the replica mesh is size 1: every collective (psum /
    pmean / pmax) degenerates to the identity and the comparison is
    BIT-LEVEL — any reduction routed around the collective helpers, or any
    reordering of the merge math, fails exactly. Real multi-device
    execution (collectives with >1 shard, float reassociation tolerance)
    is covered by tests/test_sharded_placement.py in a subprocess with 8
    virtual devices — the layout the multi-device CI job runs."""
    st_v, inf_v = _case(algo, engine, True, "vmap")
    st_s, inf_s = _case(algo, engine, True, "sharded")
    assert [i["train_loss"] for i in inf_v] == [i["train_loss"] for i in inf_s]
    assert [i["u"] for i in inf_v] == [i["u"] for i in inf_s]
    _assert_tree_close(st_v.replicas, st_s.replicas, rtol=0, atol=0)
    if st_v.global_model is not None:
        _assert_tree_close(st_v.global_model, st_s.global_model,
                           rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(st_v.b), np.asarray(st_s.b),
                               rtol=1e-12)


def test_sharded_placement_rejects_bad_config():
    from repro.core.trainer import ElasticTrainer

    if "ds" not in _cache:
        _cache["ds"] = make_case_dataset()
    tr = build_case_trainer("adaptive", "scan", True, _cache["ds"])
    import dataclasses

    with pytest.raises(ValueError, match="placement"):
        ElasticTrainer(
            tr.model, tr.provider,
            dataclasses.replace(tr.cfg, placement="teleported"),
        )


# --------------------------------------------------------------------------
# delayed_sync (the sixth algorithm) semantics
# --------------------------------------------------------------------------


def test_delayed_sync_mask_weighted_mean():
    """Masked replicas' zero gradients must not dilute the live mean."""
    import jax.numpy as jnp
    from repro.core.algorithms.delayed_sync import masked_mean_grads

    g = {"w": jnp.asarray([[2.0, 4.0], [0.0, 0.0], [4.0, 8.0]])}
    mask = jnp.asarray([1.0, 0.0, 1.0])
    out = masked_mean_grads(g, mask)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.broadcast_to([3.0, 6.0], (3, 2)))


def test_delayed_sync_charges_one_merge_per_megabatch():
    """The delay hides aggregation latency: one barrier cost, not per-round
    like `sync` — that is the algorithm's entire virtual-time advantage."""
    _, inf_ds = _case("delayed_sync", "scan", True)
    _, inf_sy = _case("sync", "scan", True)
    assert inf_ds[-1]["virtual_time"] < inf_sy[-1]["virtual_time"]


def test_delayed_sync_adapts_batch_sizes():
    state, infos = _case("delayed_sync", "scan", True)
    b = np.asarray(state.b)
    assert not np.allclose(b, b[0]) or np.any(b < 32.0), (
        "batch sizes never adapted under heterogeneity"
    )


# --------------------------------------------------------------------------
# 3. extensibility through the public API only
# --------------------------------------------------------------------------


@algorithms.register("toy_halfstep")
class ToyHalfStep(algorithms.Algorithm):
    """Toy plugin: elastic averaging that halves the merge contribution of
    the slowest replica — registered with zero trainer edits."""

    def merge(self, trainer, state, plan, replicas):
        import numpy as _np

        alphas = _np.ones(trainer.cfg.n_replicas)
        alphas[int(_np.argmin(plan.u))] *= 0.5
        alphas /= alphas.sum()
        new_global, new_replicas = trainer.merge_models(
            replicas, alphas, None, None, 0.0
        )
        return algorithms.MergeOutcome(
            replicas=new_replicas, global_model=new_global, alphas=alphas
        )


def test_toy_algorithm_via_public_api():
    """The registered toy strategy trains end-to-end on both engines and
    its merge weights reach the metrics log."""
    st_s, inf_s = _case("toy_halfstep", "scan", True)
    st_l, inf_l = _case("toy_halfstep", "legacy_loop", True)
    assert np.isfinite(inf_s[-1]["train_loss"])
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_s], [i["train_loss"] for i in inf_l],
        rtol=2e-4, atol=1e-5,
    )
    assert abs(sum(inf_s[-1]["alphas"]) - 1.0) < 1e-6
    assert min(inf_s[-1]["alphas"]) < 1.0 / 4


def test_toy_algorithm_through_launcher():
    """--algorithm picks up registry plugins with no launcher edits."""
    from repro.launch import train as train_mod

    state, mlog = train_mod.main([
        "--workload", "xml", "--algorithm", "toy_halfstep", "--replicas", "2",
        "--megabatches", "1", "--mega-batch", "2", "--b-max", "16",
        "--samples", "256", "--features", "128", "--classes", "32",
        "--avg-nnz", "8", "--hidden", "16", "--lr", "0.5",
    ])
    assert len(mlog.records) == 1
    assert np.isfinite(mlog.records[-1]["train_loss"])
