"""Optimizer: per-replica lr vectors, masked updates, clipping, schedules,
and the row-sparse update path (DESIGN.md §3)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.row_sparse import RowSparseGrad, first_occurrence
from repro.optim.schedules import cosine_decay, linear_scaled_lr, rescale_lr, warmup_factor
from repro.optim.sgd import SGDConfig, clip_by_global_norm, init_momentum, sgd_update


class TestSGD:
    def test_basic_step(self):
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.ones((3,)) * 2.0}
        new, _ = sgd_update(p, g, 0.1, SGDConfig())
        np.testing.assert_allclose(np.asarray(new["w"]), 0.8, rtol=1e-6)

    def test_per_replica_lr_vector(self):
        p = {"w": jnp.ones((2, 3))}  # R=2
        g = {"w": jnp.ones((2, 3))}
        lr = jnp.asarray([0.1, 0.5])
        new, _ = sgd_update(p, g, lr, SGDConfig(), replica_dim=True)
        np.testing.assert_allclose(np.asarray(new["w"])[0], 0.9, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new["w"])[1], 0.5, rtol=1e-6)

    def test_update_mask_freezes_replica(self):
        p = {"w": jnp.ones((2, 3))}
        g = {"w": jnp.ones((2, 3))}
        mask = jnp.asarray([1.0, 0.0])
        new, _ = sgd_update(p, g, 0.1, SGDConfig(), update_mask=mask, replica_dim=True)
        np.testing.assert_allclose(np.asarray(new["w"])[0], 0.9, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new["w"])[1], 1.0, rtol=1e-6)

    def test_momentum_accumulates(self):
        cfg = SGDConfig(momentum=0.9)
        p = {"w": jnp.zeros((2,))}
        m = init_momentum(p, cfg)
        g = {"w": jnp.ones((2,))}
        p1, m1 = sgd_update(p, g, 1.0, cfg, momentum_state=m)
        p2, m2 = sgd_update(p1, g, 1.0, cfg, momentum_state=m1)
        # v1 = 1; v2 = 0.9 + 1 = 1.9; w = -(1 + 1.9) = -2.9
        np.testing.assert_allclose(np.asarray(p2["w"]), -2.9, rtol=1e-6)

    def test_momentum_respects_mask(self):
        cfg = SGDConfig(momentum=0.9)
        p = {"w": jnp.zeros((2, 2))}
        m = init_momentum(p, cfg)
        g = {"w": jnp.ones((2, 2))}
        mask = jnp.asarray([1.0, 0.0])
        _, m1 = sgd_update(p, g, 1.0, cfg, momentum_state=m, update_mask=mask, replica_dim=True)
        assert np.asarray(m1["w"])[0].sum() > 0
        np.testing.assert_allclose(np.asarray(m1["w"])[1], 0.0)

    def test_clip_global_norm(self):
        g = {"w": jnp.ones((4,)) * 3.0}  # norm 6
        c = clip_by_global_norm(g, 3.0, replica_dim=False)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(c["w"])), 3.0, rtol=1e-4
        )

    def test_clip_per_replica(self):
        g = {"w": jnp.stack([jnp.ones(4) * 3.0, jnp.ones(4) * 0.1])}
        c = clip_by_global_norm(g, 3.0, replica_dim=True)
        arr = np.asarray(c["w"])
        np.testing.assert_allclose(np.linalg.norm(arr[0]), 3.0, rtol=1e-4)
        np.testing.assert_allclose(arr[1], 0.1, rtol=1e-4)  # under the cap

    def test_weight_decay(self):
        p = {"w": jnp.ones((2,))}
        g = {"w": jnp.zeros((2,))}
        new, _ = sgd_update(p, g, 0.1, SGDConfig(weight_decay=0.5))
        np.testing.assert_allclose(np.asarray(new["w"]), 1 - 0.1 * 0.5, rtol=1e-6)


def _sparse_case(R=2, NF=30, H=4, S=8, seed=0, sentinel=True):
    rng = np.random.default_rng(seed)
    p = {"w1": jnp.asarray(rng.normal(size=(R, NF, H)), jnp.float32)}
    rows = rng.integers(0, NF, (R, S)).astype(np.int32)
    rows[:, 1] = rows[:, 0]  # duplicate rows in every replica
    vals = rng.normal(size=(R, S, H)).astype(np.float32)
    if sentinel:
        rows[:, -1] = NF     # padded slot: dropped by the scatter
        vals[:, -1] = 0.0
    g = {"w1": RowSparseGrad(jnp.asarray(rows), jnp.asarray(vals), NF)}
    return p, g, rows, vals


class TestRowSparseSGD:
    def test_plain_matches_densified(self):
        """The paper's local update (plain SGD) must match the dense oracle."""
        p, g, _, _ = _sparse_case()
        lr = jnp.asarray([0.1, 0.4])
        mask = jnp.asarray([1.0, 0.0])
        dense = {"w1": g["w1"].densify()}
        ns, _ = sgd_update(p, g, lr, SGDConfig(), update_mask=mask, replica_dim=True)
        nd, _ = sgd_update(p, dense, lr, SGDConfig(), update_mask=mask, replica_dim=True)
        np.testing.assert_allclose(
            np.asarray(ns["w1"]), np.asarray(nd["w1"]), rtol=1e-6, atol=1e-7
        )
        # masked replica is frozen bit-exactly
        np.testing.assert_array_equal(np.asarray(ns["w1"][1]), np.asarray(p["w1"][1]))

    def test_unbatched_leaf(self):
        p, g, rows, vals = _sparse_case(R=1)
        p1 = {"w1": p["w1"][0]}
        g1 = {"w1": RowSparseGrad(jnp.asarray(rows[0]), jnp.asarray(vals[0]), 30)}
        ns, _ = sgd_update(p1, g1, 0.2, SGDConfig())
        want = np.asarray(p1["w1"]) - 0.2 * np.asarray(g1["w1"].densify())
        np.testing.assert_allclose(np.asarray(ns["w1"]), want, rtol=1e-6, atol=1e-7)

    def test_lazy_momentum_touched_rows_exact(self):
        """Touched rows follow the dense rule m' = mu*m + g; untouched rows
        keep their momentum (lazy, documented in DESIGN.md §3)."""
        cfg = SGDConfig(momentum=0.9)
        p, g, rows, vals = _sparse_case()
        m0 = init_momentum(p, cfg)
        m0 = {"w1": m0["w1"] + 0.5}  # nonzero so laziness is observable
        ns, ms = sgd_update(p, g, 0.1, cfg, momentum_state=m0, replica_dim=True)
        dense_m = 0.9 * 0.5 + np.asarray(g["w1"].densify())
        for r in range(2):
            touched = np.zeros(30, bool)
            touched[rows[r][rows[r] < 30]] = True
            np.testing.assert_allclose(
                np.asarray(ms["w1"][r])[touched], dense_m[r][touched],
                rtol=1e-5, atol=1e-6,
            )
            # lazy: untouched rows neither decay momentum nor move params
            np.testing.assert_allclose(np.asarray(ms["w1"][r])[~touched], 0.5)
            np.testing.assert_array_equal(
                np.asarray(ns["w1"][r])[~touched], np.asarray(p["w1"][r])[~touched]
            )

    def test_lazy_weight_decay_once_per_row(self):
        """Duplicate rows must decay exactly once (first-occurrence mask)."""
        cfg = SGDConfig(weight_decay=0.5)
        p, g, rows, vals = _sparse_case()
        ns, _ = sgd_update(p, g, 0.1, cfg, replica_dim=True)
        want = np.asarray(p["w1"]).copy()
        for r in range(2):
            touched = np.zeros(30, bool)
            touched[rows[r][rows[r] < 30]] = True
            want[r] -= 0.1 * np.asarray(g["w1"].densify()[r])
            want[r][touched] -= 0.1 * 0.5 * np.asarray(p["w1"][r])[touched]
        np.testing.assert_allclose(np.asarray(ns["w1"]), want, rtol=1e-5, atol=1e-6)

    def test_grad_clip_densifies(self):
        """grad_clip needs the duplicate-reduced norm: result must equal the
        dense path exactly."""
        cfg = SGDConfig(grad_clip=0.7)
        p, g, _, _ = _sparse_case()
        dense = {"w1": g["w1"].densify()}
        ns, _ = sgd_update(p, g, 0.1, cfg, replica_dim=True)
        nd, _ = sgd_update(p, dense, 0.1, cfg, replica_dim=True)
        np.testing.assert_allclose(
            np.asarray(ns["w1"]), np.asarray(nd["w1"]), rtol=1e-6, atol=1e-7
        )

    def test_first_occurrence_mask(self):
        rows = jnp.asarray([3, 3, 1, 5, 1, 7], jnp.int32)
        got = np.asarray(first_occurrence(rows, n_rows=6))
        np.testing.assert_array_equal(got, [1, 0, 1, 1, 0, 0])  # 7 = sentinel

    def test_mixed_tree_dense_and_sparse(self):
        p, g, _, _ = _sparse_case()
        p["b"] = jnp.ones((2, 3))
        g["b"] = jnp.full((2, 3), 2.0)
        ns, _ = sgd_update(p, g, 0.5, SGDConfig(), replica_dim=True)
        np.testing.assert_allclose(np.asarray(ns["b"]), 0.0)


class TestSchedules:
    def test_linear_scaling(self):
        assert linear_scaled_lr(0.1, 256, 512) == pytest.approx(0.2)
        np.testing.assert_allclose(
            linear_scaled_lr(0.1, 256, np.array([128, 256])), [0.05, 0.1]
        )

    def test_rescale_matches_algorithm1(self):
        np.testing.assert_allclose(rescale_lr(0.1, 100, 150), 0.15)

    def test_warmup(self):
        assert warmup_factor(0, 10) == pytest.approx(0.1)
        assert warmup_factor(9, 10) == 1.0
        assert warmup_factor(100, 10) == 1.0
        assert warmup_factor(0, 0) == 1.0

    def test_cosine(self):
        assert cosine_decay(0, 100) == pytest.approx(1.0)
        assert cosine_decay(100, 100) == pytest.approx(0.1)
