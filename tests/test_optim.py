"""Optimizer: per-replica lr vectors, masked updates, clipping, schedules."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.schedules import cosine_decay, linear_scaled_lr, rescale_lr, warmup_factor
from repro.optim.sgd import SGDConfig, clip_by_global_norm, init_momentum, sgd_update


class TestSGD:
    def test_basic_step(self):
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.ones((3,)) * 2.0}
        new, _ = sgd_update(p, g, 0.1, SGDConfig())
        np.testing.assert_allclose(np.asarray(new["w"]), 0.8, rtol=1e-6)

    def test_per_replica_lr_vector(self):
        p = {"w": jnp.ones((2, 3))}  # R=2
        g = {"w": jnp.ones((2, 3))}
        lr = jnp.asarray([0.1, 0.5])
        new, _ = sgd_update(p, g, lr, SGDConfig(), replica_dim=True)
        np.testing.assert_allclose(np.asarray(new["w"])[0], 0.9, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new["w"])[1], 0.5, rtol=1e-6)

    def test_update_mask_freezes_replica(self):
        p = {"w": jnp.ones((2, 3))}
        g = {"w": jnp.ones((2, 3))}
        mask = jnp.asarray([1.0, 0.0])
        new, _ = sgd_update(p, g, 0.1, SGDConfig(), update_mask=mask, replica_dim=True)
        np.testing.assert_allclose(np.asarray(new["w"])[0], 0.9, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new["w"])[1], 1.0, rtol=1e-6)

    def test_momentum_accumulates(self):
        cfg = SGDConfig(momentum=0.9)
        p = {"w": jnp.zeros((2,))}
        m = init_momentum(p, cfg)
        g = {"w": jnp.ones((2,))}
        p1, m1 = sgd_update(p, g, 1.0, cfg, momentum_state=m)
        p2, m2 = sgd_update(p1, g, 1.0, cfg, momentum_state=m1)
        # v1 = 1; v2 = 0.9 + 1 = 1.9; w = -(1 + 1.9) = -2.9
        np.testing.assert_allclose(np.asarray(p2["w"]), -2.9, rtol=1e-6)

    def test_momentum_respects_mask(self):
        cfg = SGDConfig(momentum=0.9)
        p = {"w": jnp.zeros((2, 2))}
        m = init_momentum(p, cfg)
        g = {"w": jnp.ones((2, 2))}
        mask = jnp.asarray([1.0, 0.0])
        _, m1 = sgd_update(p, g, 1.0, cfg, momentum_state=m, update_mask=mask, replica_dim=True)
        assert np.asarray(m1["w"])[0].sum() > 0
        np.testing.assert_allclose(np.asarray(m1["w"])[1], 0.0)

    def test_clip_global_norm(self):
        g = {"w": jnp.ones((4,)) * 3.0}  # norm 6
        c = clip_by_global_norm(g, 3.0, replica_dim=False)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(c["w"])), 3.0, rtol=1e-4
        )

    def test_clip_per_replica(self):
        g = {"w": jnp.stack([jnp.ones(4) * 3.0, jnp.ones(4) * 0.1])}
        c = clip_by_global_norm(g, 3.0, replica_dim=True)
        arr = np.asarray(c["w"])
        np.testing.assert_allclose(np.linalg.norm(arr[0]), 3.0, rtol=1e-4)
        np.testing.assert_allclose(arr[1], 0.1, rtol=1e-4)  # under the cap

    def test_weight_decay(self):
        p = {"w": jnp.ones((2,))}
        g = {"w": jnp.zeros((2,))}
        new, _ = sgd_update(p, g, 0.1, SGDConfig(weight_decay=0.5))
        np.testing.assert_allclose(np.asarray(new["w"]), 1 - 0.1 * 0.5, rtol=1e-6)


class TestSchedules:
    def test_linear_scaling(self):
        assert linear_scaled_lr(0.1, 256, 512) == pytest.approx(0.2)
        np.testing.assert_allclose(
            linear_scaled_lr(0.1, 256, np.array([128, 256])), [0.05, 0.1]
        )

    def test_rescale_matches_algorithm1(self):
        np.testing.assert_allclose(rescale_lr(0.1, 100, 150), 0.15)

    def test_warmup(self):
        assert warmup_factor(0, 10) == pytest.approx(0.1)
        assert warmup_factor(9, 10) == 1.0
        assert warmup_factor(100, 10) == 1.0
        assert warmup_factor(0, 0) == 1.0

    def test_cosine(self):
        assert cosine_decay(0, 100) == pytest.approx(1.0)
        assert cosine_decay(100, 100) == pytest.approx(0.1)
