"""Sharded replica executor (DESIGN.md §5): real multi-device parity and the
measured-speed feedback loop.

The parity layer runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the virtual device
count must be fixed before jax initializes), trains every registered
algorithm on both engines under ``placement='sharded'`` (R=4 replicas over a
4-device replica mesh, collectives with real cross-shard traffic) and
compares losses/update-counts/params against the vmap placement in the same
process. Bit-level single-device parity lives in tests/test_algorithms.py;
this file owns the >1-shard float-reassociation-tolerance layer — the same
suite the multi-device CI job executes.

MeasuredSpeedModel is unit-tested in-process with an injected fake timer
(no sleeping, no hardware dependence).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.heterogeneity import CostModel, MeasuredSpeedModel
from repro.sharding.rules import REPLICA_AXIS, replica_mesh, replica_mesh_size

# --------------------------------------------------------------------------
# replica mesh construction
# --------------------------------------------------------------------------


def test_replica_mesh_single_device_degenerates():
    mesh = replica_mesh(4)  # in-process: one CPU device
    assert mesh.shape[REPLICA_AXIS] in (1, 2, 4)
    assert 4 % mesh.shape[REPLICA_AXIS] == 0


def test_replica_mesh_picks_largest_divisor():
    assert replica_mesh_size(4, 6) == 4   # more devices than replicas
    assert replica_mesh_size(4, 4) == 4   # one replica per device
    assert replica_mesh_size(6, 4) == 3   # 6 replicas / 3 devices = 2 each
    assert replica_mesh_size(5, 4) == 1   # prime R: no even split
    assert replica_mesh_size(8, 8) == 8


# --------------------------------------------------------------------------
# MeasuredSpeedModel: the paper-§3.1 feedback loop, driven by a fake clock
# --------------------------------------------------------------------------


class FakeTimer:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_measured_speed_prior_is_homogeneous():
    sm = MeasuredSpeedModel(4, timer=FakeTimer())
    np.testing.assert_allclose(sm.factors, np.ones(4))
    assert sm.step_factor(2) == 1.0


def test_measured_speed_relative_factors():
    sm = MeasuredSpeedModel(3, timer=FakeTimer())
    # replica 0 does 100 work units in 1s, replica 1 the same work in 2s
    sm.observe(0, 100, 1.0)
    sm.observe(1, 100, 2.0)
    f = sm.factors
    assert f[0] == 1.0            # fastest normalized to 1
    np.testing.assert_allclose(f[1], 2.0)
    assert f[2] == 1.0            # unmeasured replica keeps the prior


def test_measured_speed_ema_tracks_drift():
    sm = MeasuredSpeedModel(2, ema=0.5, timer=FakeTimer())
    sm.observe(0, 100, 1.0)
    sm.observe(1, 100, 1.0)
    for _ in range(8):            # replica 1 slows down over time
        sm.observe(1, 100, 3.0)
    assert sm.factors[1] > 2.5    # EMA converged toward the 3x slowdown


def test_measured_speed_timer_is_injectable():
    ft = FakeTimer()
    sm = MeasuredSpeedModel(2, timer=ft)
    h = sm.begin()
    ft.t += 1.5
    assert sm.elapsed(h) == pytest.approx(1.5)


def test_measured_speed_observe_plan_attribution():
    """Lockstep attribution: same wall window, more work => faster."""
    sm = MeasuredSpeedModel(3, warmup_windows=0, timer=FakeTimer())
    sm.observe_plan(np.array([200.0, 100.0, 0.0]), 1.0)
    f = sm.factors
    assert f[0] == 1.0 and f[1] == pytest.approx(2.0) and f[2] == 1.0


def test_measured_speed_warmup_discards_compile_window():
    """The first window is jit-compile-dominated; it must not bias EMAs."""
    sm = MeasuredSpeedModel(2, timer=FakeTimer())  # warmup_windows=1 default
    sm.observe_plan(np.array([100.0, 100.0]), 60.0)   # compile-heavy window
    np.testing.assert_allclose(sm.factors, np.ones(2))
    assert (sm.n_obs == 0).all()
    sm.observe_plan(np.array([100.0, 50.0]), 1.0)     # steady state
    assert (sm.n_obs == 1).all()


def test_measured_speed_share_normalization_no_amplification():
    """Planner asymmetry must not masquerade as a speed difference.

    On homogeneous hardware the planner may hand one replica an extra
    round (the leftover dispatch). Charged the *whole* window, the
    short-changed replica would measure slower, receive even less work
    next plan, and the asymmetry would self-amplify without any hardware
    cause. Charged only its scheduled share (u_i/n_rounds), equal
    per-round throughput measures equal speed."""
    sm = MeasuredSpeedModel(2, warmup_windows=0, timer=FakeTimer())
    # homogeneous machine: 3 rounds of work for r0, 2 for r1, same b=32;
    # window = 3 equal rounds
    sm.observe_plan(np.array([96.0, 64.0]), 3.0, u=np.array([3, 2]),
                    n_rounds=3)
    np.testing.assert_allclose(sm.factors, np.ones(2))


def test_measured_speed_ignores_degenerate_samples():
    sm = MeasuredSpeedModel(2, timer=FakeTimer())
    sm.observe(0, 0, 1.0)       # no work: unattributable
    sm.observe(1, 100, 0.0)     # no elapsed time: clock glitch
    np.testing.assert_allclose(sm.factors, np.ones(2))


def test_measured_speed_degenerate_plan_counts_window():
    """Regression: a fully-masked mega-batch (``n_rounds == 0`` or all-zero
    ``u``) used to fall into the unattributed whole-window branch (or a
    division by the zero round count); it must charge no EMA but still
    advance ``n_windows`` so the compile-warmup discard stays aligned with
    the trainer's mega-batch sequence."""
    sm = MeasuredSpeedModel(2, warmup_windows=0, timer=FakeTimer())
    sm.observe_plan(np.array([100.0, 100.0]), 1.0, u=np.array([0, 0]),
                    n_rounds=0)                       # nothing dispatched
    assert sm.n_windows == 1
    assert (sm.n_obs == 0).all()
    np.testing.assert_allclose(sm.factors, np.ones(2))
    sm.observe_plan(np.array([100.0, 100.0]), 1.0, u=np.array([0, 0]),
                    n_rounds=3)                       # all-masked rounds
    assert sm.n_windows == 2
    assert (sm.n_obs == 0).all()
    sm.observe_plan(np.array([100.0, 100.0]), 1.0, u=np.array([1, 1]),
                    n_rounds=1)                       # healthy plan resumes
    assert sm.n_windows == 3
    assert (sm.n_obs == 1).all()


def test_measured_speed_degenerate_plan_respects_warmup():
    """The counted-but-unattributed window must consume a warmup slot like
    any other window (alignment is the point of counting it)."""
    sm = MeasuredSpeedModel(2, timer=FakeTimer())      # warmup_windows=1
    sm.observe_plan(np.array([100.0, 100.0]), 60.0, u=np.array([0, 0]),
                    n_rounds=0)                        # degenerate warmup
    sm.observe_plan(np.array([100.0, 50.0]), 1.0, u=np.array([1, 1]),
                    n_rounds=1)
    assert (sm.n_obs == 1).all()                       # past warmup


def test_measured_speed_drives_cost_model_and_scheduler():
    """The measured factors must steer the virtual clock: after observing a
    2x-slower replica, the availability-driven plan gives it fewer rounds."""
    from repro.configs.base import ElasticConfig
    from repro.core.scheduler import DynamicScheduler

    sm = MeasuredSpeedModel(2, timer=FakeTimer())
    sm.observe(0, 100, 1.0)
    sm.observe(1, 100, 2.0)
    sched = DynamicScheduler(ElasticConfig(n_replicas=2), CostModel(sm))
    plan = sched.plan_megabatch(np.array([32, 32]), 32 * 20)
    assert plan.u[0] > plan.u[1]


def test_trainer_feeds_measured_speed():
    """End-to-end: a trainer built with MeasuredSpeedModel accumulates real
    observations for every replica once past the compile-warmup window."""
    sys.path.insert(0, os.path.dirname(__file__))
    from golden.generate import build_case_trainer, make_case_dataset
    from repro.core.trainer import ElasticTrainer

    base = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    tr = ElasticTrainer(
        base.model, base.provider, base.cfg, base_lr=0.5, seed=3,
        speed=MeasuredSpeedModel(base.cfg.n_replicas),
    )
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)      # warmup window: discarded
    assert (tr.speed.n_obs == 0).all()
    state, _ = tr.run_megabatch(state)      # first measured window
    assert (tr.speed.n_obs > 0).all()
    assert np.isfinite(tr.speed.t_per_work).all()


# --------------------------------------------------------------------------
# multi-device parity (the CI multi-device job's core suite)
# --------------------------------------------------------------------------

PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    import dataclasses
    import numpy as np
    import jax
    import jax.tree_util as jtu

    assert len(jax.devices()) == 8, jax.devices()

    from golden.generate import build_case_trainer, make_case_dataset
    from repro.core import algorithms
    from repro.core.trainer import ElasticTrainer
    from repro.sharding.rules import REPLICA_AXIS

    ds = make_case_dataset()

    def run(algo, engine, placement):
        tr = build_case_trainer(algo, engine, True, ds, placement=placement)
        if placement == "sharded":
            want = 1 if algo == "single" else 4
            assert tr.mesh.shape[REPLICA_AXIS] == want, tr.mesh
        state = tr.init_state()
        infos = []
        for _ in range(2):
            state, info = tr.run_megabatch(state)
            infos.append(info)
        return state, infos

    def close(a, b, rtol, atol):
        for la, lb in zip(jtu.tree_leaves(a), jtu.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=rtol, atol=atol)

    for algo in sorted(algorithms.available()):
        for engine in ("scan", "legacy_loop"):
            st_v, inf_v = run(algo, engine, "vmap")
            st_s, inf_s = run(algo, engine, "sharded")
            np.testing.assert_allclose(
                [i["train_loss"] for i in inf_v],
                [i["train_loss"] for i in inf_s], rtol=1e-5, atol=1e-6,
                err_msg=f"{algo}/{engine} losses diverged",
            )
            assert [i["u"] for i in inf_v] == [i["u"] for i in inf_s], (
                f"{algo}/{engine} update counts diverged"
            )
            close(st_v.replicas, st_s.replicas, rtol=2e-3, atol=1e-5)
            if st_v.global_model is not None:
                close(st_v.global_model, st_s.global_model,
                      rtol=2e-3, atol=1e-5)
            print(f"OK {algo}/{engine}")
    print("PARITY-SUITE-PASSED")
""")


@pytest.mark.slow
def test_sharded_vs_vmap_multidevice_parity():
    """All registered algorithms x both engines on a real 4-shard replica
    mesh must match the single-program vmap oracle."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "tests"),
         env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, (
        f"parity subprocess failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "PARITY-SUITE-PASSED" in proc.stdout
