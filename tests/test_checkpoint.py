"""Checkpoint store roundtrip + trainer-state integration."""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
from generate import build_case_trainer, make_case_dataset  # noqa: E402


def test_roundtrip_pytree(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "c": [jnp.zeros(5)]},
    }
    store.save(str(tmp_path / "ckpt"), tree, metadata={"step": 7})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, meta = store.load(str(tmp_path / "ckpt"), like)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["nested"]["c"][0]), np.zeros(5)
    )


def test_shape_mismatch_raises(tmp_path):
    store.save(str(tmp_path / "c"), {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        store.load(str(tmp_path / "c"), {"w": jnp.zeros((3, 3))})


def test_model_params_roundtrip(tmp_path):
    from repro.configs.archs import ARCHS
    from repro.models import model as MDL

    cfg = ARCHS["llama3.2-1b"].reduced()
    params = MDL.init(cfg, jax.random.PRNGKey(0))
    store.save(str(tmp_path / "m"), params, metadata={"arch": cfg.name})
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored, meta = store.load(str(tmp_path / "m"), like)
    assert meta["arch"] == cfg.name
    l0 = jax.tree_util.tree_leaves(params)[0]
    r0 = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(r0))


# --------------------------------------------------------------------------
# atomicity + error taxonomy (DESIGN.md §7)
# --------------------------------------------------------------------------


def test_crash_mid_write_leaves_no_partial_checkpoint(tmp_path, monkeypatch):
    """A writer dying inside np.savez must never publish a directory."""
    def boom(*a, **k):
        raise RuntimeError("disk died")

    monkeypatch.setattr(store.np, "savez", boom)
    with pytest.raises(RuntimeError, match="disk died"):
        store.save(str(tmp_path / "c"), {"w": jnp.zeros(3)})
    assert not (tmp_path / "c").exists()
    # staging temp dir is cleaned up on the failure path too
    assert [p for p in tmp_path.iterdir()] == []


def test_crash_mid_overwrite_keeps_old_checkpoint(tmp_path, monkeypatch):
    path = str(tmp_path / "c")
    store.save(path, {"w": jnp.zeros(3)}, metadata={"v": 1})
    real_savez = store.np.savez
    monkeypatch.setattr(
        store.np, "savez",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("torn")),
    )
    with pytest.raises(RuntimeError):
        store.save(path, {"w": jnp.ones(3)}, metadata={"v": 2})
    monkeypatch.setattr(store.np, "savez", real_savez)
    restored, meta = store.load(path, {"w": jnp.zeros(3)})
    assert meta["v"] == 1  # the old complete checkpoint survived intact
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.zeros(3))


def test_load_missing_checkpoint_raises_checkpoint_error(tmp_path):
    with pytest.raises(store.CheckpointError, match="no checkpoint"):
        store.load(str(tmp_path / "nope"), {"w": jnp.zeros(2)})


def test_load_corrupt_tensors_raises_checkpoint_error(tmp_path):
    path = str(tmp_path / "c")
    store.save(path, {"w": jnp.zeros(2)})
    with open(os.path.join(path, "tensors.npz"), "wb") as f:
        f.write(b"torn write, not a zip")
    with pytest.raises(store.CheckpointError, match="corrupt"):
        store.load(path, {"w": jnp.zeros(2)})


def test_load_missing_key_raises_checkpoint_error(tmp_path):
    path = str(tmp_path / "c")
    store.save(path, {"w": jnp.zeros(2)})
    with pytest.raises(store.CheckpointError, match="extra"):
        store.load(path, {"w": jnp.zeros(2), "extra": jnp.zeros(1)})


def test_latest_checkpoint_ignores_incomplete_and_tmp(tmp_path):
    store.save(str(tmp_path / "ckpt-000002"), {"w": jnp.zeros(1)})
    store.save(str(tmp_path / "ckpt-000004"), {"w": jnp.zeros(1)})
    # a higher-index dir without meta.json (torn pre-atomic write) loses
    os.makedirs(tmp_path / "ckpt-000006")
    os.makedirs(tmp_path / ".tmp-ckpt-000008-x")
    assert store.latest_checkpoint(str(tmp_path)).endswith("ckpt-000004")
    assert store.resolve_checkpoint(str(tmp_path)).endswith("ckpt-000004")
    with pytest.raises(store.CheckpointError):
        store.resolve_checkpoint(str(tmp_path / "empty"))


# --------------------------------------------------------------------------
# CheckpointManager
# --------------------------------------------------------------------------


class _FakeTrainer:
    def checkpoint_payload(self, state):
        return {"x": state["x"]}, {"megabatch_idx": int(state["idx"])}


def _fake_state(idx):
    return {"x": np.full(3, float(idx)), "idx": idx}


class _DictState(dict):
    @property
    def megabatch_idx(self):
        return self["idx"]


def test_manager_interval_and_retention(tmp_path):
    mgr = store.CheckpointManager(str(tmp_path), every=2, retain=2,
                                  async_write=False)
    tr = _FakeTrainer()
    for idx in range(1, 9):
        mgr.maybe_save(tr, _DictState(_fake_state(idx)))
    names = sorted(
        n for n in os.listdir(tmp_path) if n.startswith(store.CKPT_PREFIX)
    )
    assert names == ["ckpt-000006", "ckpt-000008"]  # retention swept 2,4
    assert mgr.latest().endswith("ckpt-000008")


def test_manager_snapshot_is_immutable(tmp_path):
    """The host snapshot must be copied before the trainer mutates state."""
    mgr = store.CheckpointManager(str(tmp_path), every=1, async_write=True)
    tr = _FakeTrainer()
    state = _DictState(_fake_state(3))
    mgr.maybe_save(tr, state)
    state["x"][:] = -1.0  # trainer mutating in place after the snapshot
    mgr.wait()
    restored, _ = store.load(mgr.latest(), {"x": np.zeros(3)})
    np.testing.assert_array_equal(restored["x"], np.full(3, 3.0))


def test_manager_background_failure_surfaces(tmp_path, monkeypatch):
    mgr = store.CheckpointManager(str(tmp_path), every=1, async_write=True)
    monkeypatch.setattr(
        store, "save",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("writer died")),
    )
    mgr.maybe_save(_FakeTrainer(), _DictState(_fake_state(1)))
    with pytest.raises(store.CheckpointError, match="writer died"):
        mgr.wait()


def test_manager_validates_args(tmp_path):
    with pytest.raises(ValueError):
        store.CheckpointManager(str(tmp_path), every=0)
    with pytest.raises(ValueError):
        store.CheckpointManager(str(tmp_path), retain=0)


# --------------------------------------------------------------------------
# full ElasticState round-trip + restore equivalence (DESIGN.md §7)
# --------------------------------------------------------------------------


def test_full_elastic_state_roundtrip(tmp_path):
    """Params (ml_dtypes leaves included), momentum, b/lr, clocks, speed
    model, provider cursor: everything in checkpoint_payload survives."""
    ds = make_case_dataset()
    tr = build_case_trainer("adaptive", "scan", True, ds)
    state = tr.init_state()
    for _ in range(2):
        state, _ = tr.run_megabatch(state)
    tree, meta = tr.checkpoint_payload(state)
    path = str(tmp_path / "full")
    store.save(path, tree, metadata=meta)

    tr2 = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    restored = tr2.restore_checkpoint(path)
    assert restored.megabatch_idx == 2
    np.testing.assert_array_equal(restored.b, state.b)
    np.testing.assert_array_equal(restored.lr, state.lr)
    np.testing.assert_array_equal(tr2.scheduler.clock.t, tr.scheduler.clock.t)
    np.testing.assert_array_equal(tr2.speed.factors, tr.speed.factors)
    for a, b in zip(
        jax.tree_util.tree_leaves(state.replicas),
        jax.tree_util.tree_leaves(restored.replicas),
    ):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(
        jax.tree_util.tree_leaves(state.momentum),
        jax.tree_util.tree_leaves(restored.momentum),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # provider stream cursor continues where the writer stopped
    assert tr2.provider.state_dict() == tr.provider.state_dict()


def test_restore_checkpoint_rejects_mismatches(tmp_path):
    ds = make_case_dataset()
    tr = build_case_trainer("adaptive", "scan", True, ds)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    tree, meta = tr.checkpoint_payload(state)
    path = str(tmp_path / "c")
    store.save(path, tree, metadata=meta)
    other = build_case_trainer("elastic", "scan", True, make_case_dataset())
    with pytest.raises(store.CheckpointError, match="algorithm"):
        other.restore_checkpoint(path)


@pytest.mark.parametrize("algo", sorted(
    __import__("repro.core.algorithms", fromlist=["available"]).available()
))
def test_restore_equivalence(tmp_path, algo):
    """train N straight == train k -> checkpoint -> restore (fresh trainer,
    fresh process semantics) -> train N-k, for every registered algorithm."""
    N, K = 4, 2
    ds = make_case_dataset()

    straight = build_case_trainer(algo, "scan", True, ds)
    s_state, s_log = straight.run(N)

    split = build_case_trainer(algo, "scan", True, make_case_dataset())
    mgr = store.CheckpointManager(str(tmp_path / algo), every=K,
                                  async_write=False)
    split.run(K, checkpoint=mgr)
    assert mgr.latest() is not None

    resumed = build_case_trainer(algo, "scan", True, make_case_dataset())
    r_state, r_log = resumed.run(N, restore_from=str(tmp_path / algo))

    s_losses = [rec["train_loss"] for rec in s_log.records]
    r_losses = [rec["train_loss"] for rec in r_log.records]
    assert len(r_losses) == N - K
    np.testing.assert_allclose(r_losses, s_losses[K:], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(r_state.b), np.asarray(s_state.b), rtol=1e-12
    )
    ref = s_state.global_model
    got = r_state.global_model
    for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=1e-4, atol=1e-6,
        )
