"""Checkpoint store roundtrip + trainer-state integration."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def test_roundtrip_pytree(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16), "c": [jnp.zeros(5)]},
    }
    store.save(str(tmp_path / "ckpt"), tree, metadata={"step": 7})
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, meta = store.load(str(tmp_path / "ckpt"), like)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["nested"]["c"][0]), np.zeros(5)
    )


def test_shape_mismatch_raises(tmp_path):
    store.save(str(tmp_path / "c"), {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        store.load(str(tmp_path / "c"), {"w": jnp.zeros((3, 3))})


def test_model_params_roundtrip(tmp_path):
    from repro.configs.archs import ARCHS
    from repro.models import model as MDL

    cfg = ARCHS["llama3.2-1b"].reduced()
    params = MDL.init(cfg, jax.random.PRNGKey(0))
    store.save(str(tmp_path / "m"), params, metadata={"arch": cfg.name})
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored, meta = store.load(str(tmp_path / "m"), like)
    assert meta["arch"] == cfg.name
    l0 = jax.tree_util.tree_leaves(params)[0]
    r0 = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(r0))
