"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle, plus hypothesis property tests on kernel invariants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import moe_ffn_gmm
from repro.kernels.moe_gmm.ref import moe_ffn_gmm_ref
from repro.kernels.spmm.ops import spmm
from repro.kernels.spmm.ref import spmm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.weighted_merge.ops import merge, merge_pytree
from repro.kernels.weighted_merge.ref import weighted_merge_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-5
    )


def _f32(x):
    return np.asarray(x, np.float32)


# --------------------------------------------------------------------------
# weighted_merge
# --------------------------------------------------------------------------


@pytest.mark.parametrize("r,n", [(2, 256), (4, 2048), (8, 5001), (3, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_merge_sweep(r, n, dtype):
    reps = jnp.asarray(RNG.normal(size=(r, n)), dtype)
    alphas = jnp.asarray(RNG.random(r), jnp.float32)
    got = merge(reps, alphas)
    want = weighted_merge_ref(reps, alphas)
    np.testing.assert_allclose(_f32(got), _f32(want), **_tol(dtype))


@pytest.mark.parametrize("r,n", [(4, 1000), (2, 4096)])
def test_weighted_merge_momentum(r, n):
    reps = jnp.asarray(RNG.normal(size=(r, n)), jnp.float32)
    alphas = jnp.asarray(RNG.random(r), jnp.float32)
    g = jnp.asarray(RNG.normal(size=n), jnp.float32)
    gp = jnp.asarray(RNG.normal(size=n), jnp.float32)
    got = merge(reps, alphas, g, gp, 0.9)
    want = weighted_merge_ref(reps, alphas, g, gp, 0.9)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=1e-5, atol=1e-5)


def test_weighted_merge_pytree():
    tree = {
        "a": jnp.asarray(RNG.normal(size=(4, 16, 8)), jnp.float32),
        "b": {"c": jnp.asarray(RNG.normal(size=(4, 100)), jnp.float32)},
    }
    alphas = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    out = merge_pytree(tree, alphas)
    want_a = weighted_merge_ref(tree["a"].reshape(4, -1), alphas).reshape(16, 8)
    np.testing.assert_allclose(_f32(out["a"]), _f32(want_a), rtol=1e-5, atol=1e-6)
    assert out["b"]["c"].shape == (100,)


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(2, 8),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_weighted_merge_property_convex(r, n, seed):
    """Merged model with normalized weights lies in the convex hull: for
    constant replicas the merge returns the constant exactly."""
    rng = np.random.default_rng(seed)
    alphas = rng.random(r).astype(np.float32)
    alphas = alphas / alphas.sum()
    const = rng.normal()
    reps = jnp.full((r, n), const, jnp.float32)
    out = merge(reps, jnp.asarray(alphas))
    np.testing.assert_allclose(_f32(out), const, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# spmm
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,k,nf,h", [(4, 16, 512, 128), (8, 7, 300, 512), (2, 33, 1024, 200)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block_k", [1, 8])
def test_spmm_sweep(b, k, nf, h, dtype, block_k):
    fi = jnp.asarray(RNG.integers(0, nf, (b, k)), jnp.int32)
    fv = jnp.asarray(RNG.normal(size=(b, k)), jnp.float32)
    fm = jnp.asarray(RNG.random((b, k)) > 0.3)
    w = jnp.asarray(RNG.normal(size=(nf, h)), dtype)
    got = spmm(fi, fv, fm, w, block_k=block_k)
    want = spmm_ref(fi, fv, fm, w)
    np.testing.assert_allclose(_f32(got), _f32(want), **_tol(dtype))


def test_spmm_all_masked():
    fi = jnp.zeros((2, 4), jnp.int32)
    fv = jnp.ones((2, 4), jnp.float32)
    fm = jnp.zeros((2, 4), bool)
    w = jnp.asarray(RNG.normal(size=(16, 128)), jnp.float32)
    np.testing.assert_allclose(_f32(spmm(fi, fv, fm, w)), 0.0)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_property_linearity(b, k, seed):
    """spmm is linear in the values: spmm(2v) == 2 spmm(v)."""
    rng = np.random.default_rng(seed)
    nf, h = 64, 128
    fi = jnp.asarray(rng.integers(0, nf, (b, k)), jnp.int32)
    fv = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    fm = jnp.asarray(rng.random((b, k)) > 0.2)
    w = jnp.asarray(rng.normal(size=(nf, h)), jnp.float32)
    one = spmm(fi, fv, fm, w)
    two = spmm(fi, 2.0 * fv, fm, w)
    np.testing.assert_allclose(_f32(two), 2.0 * _f32(one), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,hd,causal,window",
    [
        (2, 128, 128, 4, 2, 64, True, 0),     # GQA causal
        (1, 256, 256, 8, 2, 32, True, 64),    # sliding window
        (2, 96, 160, 4, 4, 64, False, 0),     # cross (non-causal, Sq != Skv)
        (1, 200, 200, 2, 1, 64, True, 0),     # non-divisible (padding)
    ],
)
def test_flash_attention_sweep(b, sq, skv, hq, hkv, hd, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, sq, hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    b, s, hq, hkv, hd = 1, 128, 4, 2, 64
    q = jnp.asarray(RNG.normal(size=(b, s, hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_model_blockwise():
    """Kernel agrees with the model's jnp online-softmax fallback."""
    from repro.models.layers import blockwise_attention

    b, s, hq, hkv, hd = 2, 128, 8, 4, 32
    q = jnp.asarray(RNG.normal(size=(b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), jnp.float32)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(_f32(got), _f32(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), shift=st.floats(-3.0, 3.0))
def test_flash_attention_property_shift_invariance(seed, shift):
    """Softmax shift invariance: adding a constant to all K projections of a
    single position's scores doesn't change output when added uniformly —
    here we test scale stability: outputs are convex combos of V rows, so
    max|out| <= max|V|."""
    rng = np.random.default_rng(seed)
    b, s, h, hd = 1, 64, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)) + shift, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, block_q=32, block_k=32)
    assert np.max(np.abs(_f32(out))) <= np.max(np.abs(_f32(v))) + 1e-4


# --------------------------------------------------------------------------
# moe_gmm
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "e,c,d,f", [(4, 64, 128, 256), (2, 100, 64, 300), (8, 32, 256, 512)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(e, c, d, f, dtype):
    buf = jnp.asarray(RNG.normal(size=(e, c, d)) * 0.5, dtype)
    wi = jnp.asarray(RNG.normal(size=(e, d, f)) * d ** -0.5, dtype)
    wg = jnp.asarray(RNG.normal(size=(e, d, f)) * d ** -0.5, dtype)
    wo = jnp.asarray(RNG.normal(size=(e, f, d)) * f ** -0.5, dtype)
    got = moe_ffn_gmm(buf, wi, wg, wo, block_c=32, block_f=128)
    want = moe_ffn_gmm_ref(buf, wi, wg, wo)
    np.testing.assert_allclose(_f32(got), _f32(want), **_tol(dtype))


def test_moe_gmm_zero_rows_give_zero():
    """Capacity-padding rows (zero inputs) must produce zero outputs."""
    e, c, d, f = 2, 16, 32, 64
    buf = jnp.zeros((e, c, d), jnp.float32)
    wi = jnp.asarray(RNG.normal(size=(e, d, f)), jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(e, d, f)), jnp.float32)
    wo = jnp.asarray(RNG.normal(size=(e, f, d)), jnp.float32)
    np.testing.assert_allclose(
        _f32(moe_ffn_gmm(buf, wi, wg, wo, block_c=16, block_f=32)), 0.0
    )


def test_moe_gmm_matches_moe_layer_path():
    """moe_ffn(use_gmm_kernel=True) == moe_ffn(False) end to end."""
    from repro.models import moe as MOE

    key = jax.random.PRNGKey(0)
    params = MOE.init_moe(key, 64, 128, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    y0, a0 = MOE.moe_ffn(params, x, top_k=2, use_gmm_kernel=False)
    y1, a1 = MOE.moe_ffn(params, x, top_k=2, use_gmm_kernel=True)
    np.testing.assert_allclose(_f32(y0), _f32(y1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-6)


# --------------------------------------------------------------------------
# ssd_scan
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,l,h,p,n,c",
    [(2, 128, 4, 32, 16, 32), (1, 256, 2, 64, 64, 64), (2, 64, 8, 16, 8, 16)],
)
def test_ssd_scan_sweep(b, l, h, p, n, c):
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    dA = -jnp.asarray(RNG.random((b, l, h)) * 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    y, fin = ssd_scan(x, dA, Bm, Cm, chunk=c)
    yr, finr = ssd_scan_ref(x, dA, Bm, Cm, c)
    np.testing.assert_allclose(_f32(y), _f32(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(_f32(fin), _f32(finr), rtol=1e-4, atol=1e-4)


def test_ssd_scan_bf16_inputs():
    b, l, h, p, n, c = 1, 64, 2, 32, 16, 32
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)) * 0.5, jnp.bfloat16)
    dA = -jnp.asarray(RNG.random((b, l, h)) * 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.bfloat16)
    Cm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.bfloat16)
    y, _ = ssd_scan(x, dA, Bm, Cm, chunk=c)
    yr, _ = ssd_scan_ref(
        x.astype(jnp.float32), dA, Bm.astype(jnp.float32),
        Cm.astype(jnp.float32), c,
    )
    np.testing.assert_allclose(_f32(y), _f32(yr), rtol=3e-2, atol=3e-2)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes must give identical results (associativity of
    the inter-chunk recurrence)."""
    b, l, h, p, n = 1, 128, 2, 16, 8
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    dA = -jnp.asarray(RNG.random((b, l, h)) * 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    y32, f32_ = ssd_scan(x, dA, Bm, Cm, chunk=32)
    y64, f64_ = ssd_scan(x, dA, Bm, Cm, chunk=64)
    np.testing.assert_allclose(_f32(y32), _f32(y64), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(_f32(f32_), _f32(f64_), rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_recurrent_decode():
    """Kernel output position t == sequential recurrence through t (the
    train/decode consistency invariant that makes the KV-cache-free SSM
    serving path valid)."""
    b, l, h, p, n, c = 1, 32, 2, 8, 4, 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    dA = -jnp.asarray(rng.random((b, l, h)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    y, _ = ssd_scan(x, dA, Bm, Cm, chunk=c)
    # naive recurrence: s_t = exp(dA_t) s_{t-1} + B_t x_t^T ; y_t = C_t s_t
    state = np.zeros((b, h, p, n), np.float32)
    for t in range(l):
        da = np.exp(np.asarray(dA[:, t]))  # (b,h)
        bx = np.einsum("bhp,bhn->bhpn", np.asarray(x[:, t]), np.asarray(Bm[:, t]))
        state = state * da[..., None, None] + bx
        yt = np.einsum("bhpn,bhn->bhp", state, np.asarray(Cm[:, t]))
        np.testing.assert_allclose(_f32(y[:, t]), yt, rtol=1e-3, atol=1e-3)
