"""scripts/bench_check.py regression tests (ISSUE 8 bugfix).

The first run of any new benchmark column produces a fresh BENCH_*.json
with headline metrics the committed (``git show HEAD:``) baseline predates.
That used to KeyError inside ``headline_metrics`` (e.g. baseline rows
without ``best_acc``) and exit 2 — the gate must instead report such
metrics as informational NEW rows and keep gating the metrics both sides
share.
"""
from __future__ import annotations

import importlib.util
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_spec = importlib.util.spec_from_file_location(
    "bench_check", os.path.join(REPO_ROOT, "scripts", "bench_check.py")
)
bench_check = importlib.util.module_from_spec(_spec)
sys.modules.setdefault("bench_check", bench_check)
_spec.loader.exec_module(bench_check)


def _algo_row(algo, tta, best_acc=None):
    row = {"algorithm": algo, "tta": tta}
    if best_acc is not None:
        row["best_acc"] = best_acc
    return row


def test_baseline_predating_metric_does_not_crash():
    """Baseline rows without best_acc (written before the metric existed)
    must not KeyError; the fresh-only metrics show up as NEW table rows."""
    base = {"rows": [_algo_row("sync", 3.0)]}
    fresh = {"rows": [_algo_row("sync", 2.9, best_acc=0.81)]}
    failures, table = bench_check.check_file(
        "BENCH_algorithms.json", fresh, base, tolerance=0.25
    )
    assert failures == []
    new_rows = [ln for ln in table if ln.rstrip().endswith("NEW")]
    assert len(new_rows) == 1 and "best_acc/sync" in new_rows[0]


def test_new_benchmark_entry_is_informational():
    """A brand-new speedup key gates nothing but is shown as NEW."""
    base = {"speedup_steps_per_s": {"engine_R1": 5.0}}
    fresh = {"speedup_steps_per_s": {"engine_R1": 5.1, "engine_R8": 2.0}}
    failures, table = bench_check.check_file(
        "BENCH_engine.json", fresh, base, tolerance=0.25
    )
    assert failures == []
    assert any("engine_R8" in ln and ln.rstrip().endswith("NEW")
               for ln in table)


def test_shared_metrics_still_gated_alongside_new_ones():
    """NEW-row tolerance must not weaken the gate for shared metrics."""
    base = {"speedup_steps_per_s": {"engine_R1": 5.0}}
    fresh = {"speedup_steps_per_s": {"engine_R1": 2.0, "engine_R8": 2.0}}
    failures, _ = bench_check.check_file(
        "BENCH_engine.json", fresh, base, tolerance=0.25
    )
    assert len(failures) == 1 and "engine_R1" in failures[0]


def test_metric_missing_from_fresh_still_fails():
    """The inverse direction (baseline has it, fresh lost it) stays fatal."""
    base = {"rows": [_algo_row("sync", 3.0, best_acc=0.8)]}
    fresh = {"rows": [_algo_row("sync", 2.9)]}
    failures, _ = bench_check.check_file(
        "BENCH_algorithms.json", fresh, base, tolerance=0.25
    )
    assert any("best_acc/sync" in f for f in failures)


def test_main_with_baseline_dir(tmp_path):
    """End-to-end through main(): old-schema baseline dir + new-schema
    fresh file exits 0 (used to exit 2 via the KeyError handler)."""
    (tmp_path / "baseline").mkdir()
    (tmp_path / "baseline" / "BENCH_algorithms.json").write_text(
        json.dumps({"rows": [_algo_row("sync", 3.0)]})
    )
    fresh_path = tmp_path / "BENCH_algorithms.json"
    fresh_path.write_text(
        json.dumps({"rows": [_algo_row("sync", 2.9, best_acc=0.81)]})
    )
    rc = bench_check.main([
        str(fresh_path), "--baseline-dir", str(tmp_path / "baseline"),
    ])
    assert rc == 0
