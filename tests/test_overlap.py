"""Overlapped mega-batch pipeline (DESIGN.md §8).

Layers:

* bit-identity — ``overlap=True`` (prefetch + async eval + fused staging)
  must reproduce the sequential oracle ``overlap=False`` exactly: loss
  trajectory, eval metrics, virtual clock, final params — for every
  registered algorithm on both engines (the legacy engine never pipelines;
  the dispatcher must still behave);
* staging primitives — ``StagingBuffers`` double buffering and its in-use
  latch, lazy fetch + fused whole-plan gather vs the eager per-sample pack;
* prefetch lifecycle — cursor snapshot/rollback on ``invalidate_prefetch``
  and on consume-time mismatch, checkpoint-mid-prefetch cursor
  substitution;
* async eval — ``evaluate_async`` equals the sync path; ``run()`` backfills
  eval metrics into the record of the mega-batch they were dispatched for;
* per-shard measured timing — ``ShardWindowTimer`` + ``observe_shards``
  under an injected fake timer (2-fast-1-slow fleet converges to the true
  factor ratios), and the sharded + measured + overlap end-to-end smoke.

Multi-device (8 virtual CPU devices) overlap parity runs in a subprocess,
same pattern as tests/test_sharded_placement.py — the CI multi-device job
executes this whole file.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax.tree_util as jtu
import numpy as np
import pytest

from golden.generate import build_case_trainer, make_case_dataset
from tools.jaxlint.sentinel import RetraceSentinel
from repro.core import algorithms
from repro.core.heterogeneity import (
    MeasuredSpeedModel,
    ShardWindowTimer,
)
from repro.core.trainer import ElasticTrainer
from repro.data.batcher import StagingBuffers
from repro.data.providers import SparseProvider, TokenProvider


@pytest.fixture(scope="module")
def case_ds():
    return make_case_dataset()


def leaves_np(tree):
    return [np.asarray(l) for l in jtu.tree_leaves(tree)]


def _trainer(algo, engine, case_ds, overlap):
    tr = build_case_trainer(algo, engine, True, case_ds)
    tr.overlap = overlap
    return tr


# --------------------------------------------------------------------------
# bit-identity: pipelined vs sequential oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["scan", "legacy_loop"])
@pytest.mark.parametrize("algo", sorted(algorithms.available()))
def test_overlap_bit_identical(case_ds, algo, engine):
    """run(overlap on) == run(overlap off): losses, clock, final params."""
    def go(overlap):
        tr = _trainer(algo, engine, case_ds, overlap)
        state, mlog = tr.run(3)
        return state, mlog.records

    st_on, rec_on = go(True)
    st_off, rec_off = go(False)
    assert [r["train_loss"] for r in rec_on] == \
           [r["train_loss"] for r in rec_off]
    assert [r["virtual_time"] for r in rec_on] == \
           [r["virtual_time"] for r in rec_off]
    assert [r["u"] for r in rec_on] == [r["u"] for r in rec_off]
    for a, b in zip(leaves_np(st_on.replicas), leaves_np(st_off.replicas)):
        np.testing.assert_array_equal(a, b)
    if st_on.global_model is not None:
        for a, b in zip(leaves_np(st_on.global_model),
                        leaves_np(st_off.global_model)):
            np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(st_on.b, st_off.b)
    np.testing.assert_array_equal(st_on.lr, st_off.lr)


def test_overlap_steady_state_never_retraces(case_ds):
    """After the warmup mega-batch, the pipelined path must be compile-free:
    staging, async dispatch, and the scan executor all reuse their first
    programs (DESIGN.md §8 — a retrace inside the overlap window would
    serialize the pipeline it exists to hide)."""
    tr = _trainer("elastic", "scan", case_ds, True)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)   # compiles everything
    with RetraceSentinel(budget=0, label="overlap steady state"):
        for _ in range(2):
            state, info = tr.run_megabatch(state, prefetch=True)
    assert np.isfinite(info["train_loss"])


def test_overlap_bit_identical_with_eval(case_ds):
    """Async eval (dispatched at the boundary, collected one boundary
    later) must publish the same metrics into the same records."""
    from repro.data.sparse import train_test_split

    train, test = train_test_split(case_ds, 0.25, seed=1)

    def go(overlap):
        tr = build_case_trainer("adaptive", "scan", True, train)
        tr.overlap = overlap
        batches = tr.provider.test_batches(test, tr.cfg.b_max)
        _, mlog = tr.run(4, test_batches=batches, eval_every=2)
        return mlog.records

    rec_on, rec_off = go(True), go(False)
    assert [r.get("accuracy") for r in rec_on] == \
           [r.get("accuracy") for r in rec_off]
    assert [r.get("test_loss") for r in rec_on] == \
           [r.get("test_loss") for r in rec_off]
    # eval landed on the mega-batches the cadence names, despite the
    # one-boundary collection delay
    assert [i for i, r in enumerate(rec_on) if "accuracy" in r] == [1, 3]


def test_overlap_token_provider(case_ds):
    """The eager-fetch staging path (token batches have no lazy form)."""
    from repro.configs.base import ElasticConfig, ModelConfig
    from repro.models import model as MDL

    cfg = ModelConfig(
        name="tiny-test", arch_type="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
    )
    def go(overlap):
        model = MDL.make_model(cfg)
        prov = TokenProvider.make(cfg.vocab_size, 16, seed=0)
        ecfg = ElasticConfig.from_bmax(8, algorithm="adaptive",
                                       n_replicas=2, mega_batch=3)
        tr = ElasticTrainer(model, prov, ecfg, base_lr=0.1, seed=0,
                            engine="scan", overlap=overlap)
        state, mlog = tr.run(3)
        return [r["train_loss"] for r in mlog.records]

    assert go(True) == go(False)


# --------------------------------------------------------------------------
# staging primitives
# --------------------------------------------------------------------------

SPEC = {"x": ((2, 3), np.float32), "m": ((2,), bool)}


def test_staging_buffers_alternate_and_zero():
    bufs = StagingBuffers()
    k0, s0 = bufs.acquire(SPEC)
    s0["x"][...] = 7.0
    k1, s1 = bufs.acquire(SPEC)
    assert k0 != k1 and s1["x"] is not s0["x"]
    bufs.release(k0)
    k2, s2 = bufs.acquire(SPEC)      # slot 0 again, re-zeroed in place
    assert k2 == k0 and s2 is s0
    assert (s2["x"] == 0).all()


def test_staging_buffers_busy_latch():
    bufs = StagingBuffers()
    bufs.acquire(SPEC)
    bufs.acquire(SPEC)
    with pytest.raises(RuntimeError, match="in flight"):
        bufs.acquire(SPEC)           # both slots staged, none collected
    bufs.reset()
    bufs.acquire(SPEC)               # reset clears the latches


def test_staging_buffers_reallocate_on_spec_change():
    bufs = StagingBuffers()
    k0, s0 = bufs.acquire(SPEC)
    bufs.release(k0)
    bufs.acquire(SPEC)               # move _next past slot 1... no: use both
    bufs.reset()
    k, s = bufs.acquire({"x": ((4, 3), np.float32), "m": ((4,), bool)})
    assert s["x"].shape == (4, 3)
    bufs.reset()
    k, s = bufs.acquire({"y": ((2,), np.int32)})   # new key set
    assert set(s) == {"y"}


def test_lazy_stack_matches_eager(case_ds):
    """fetch_staged + fused stack == fetch + per-sample pack, same cursor."""
    b_slots = 16
    eager = SparseProvider.make(case_ds, seed=9)
    lazy = SparseProvider.make(case_ds, seed=9)
    grid_e, grid_l = [], []
    for takes in ((8, 3), (16, 0), (5, 16)):
        row_e, row_l = [], []
        for t in takes:
            if t == 0:
                row_e.append(None), row_l.append(None)
                continue
            row_e.append(eager.fetch(t, b_slots))
            p, work = lazy.fetch_staged(t, b_slots)
            assert work == eager.work_units(row_e[-1])
            row_l.append(p)
        grid_e.append(row_e), grid_l.append(row_l)
    assert eager.state_dict() == lazy.state_dict()   # same stream cursor
    st_e, mask_e = eager.stack_plan(grid_e, b_slots)
    bufs = StagingBuffers()
    _, out = bufs.acquire(lazy.staging_spec(len(grid_l), 2, b_slots))
    st_l, mask_l = lazy.stack_plan(grid_l, b_slots, out=out)
    np.testing.assert_array_equal(mask_e, mask_l)
    assert set(st_e) == set(st_l)
    for k in st_e:
        np.testing.assert_array_equal(st_e[k], st_l[k], err_msg=k)


# --------------------------------------------------------------------------
# prefetch lifecycle
# --------------------------------------------------------------------------


def test_prefetch_leaves_no_dangling_state_by_default(case_ds):
    tr = _trainer("adaptive", "scan", case_ds, True)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)      # prefetch not requested
    assert tr._staged is None


def test_invalidate_prefetch_rolls_cursors_back(case_ds):
    tr = _trainer("adaptive", "scan", case_ds, True)
    oracle = _trainer("adaptive", "scan", case_ds, False)
    state = tr.init_state()
    o_state = oracle.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)
    o_state, _ = oracle.run_megabatch(o_state)
    assert tr._staged is not None
    # staging advanced the live cursors past the oracle's...
    assert tr.provider.state_dict() != oracle.provider.state_dict()
    tr.invalidate_prefetch()
    # ...and revocation restores them exactly
    assert tr._staged is None
    assert tr.provider.state_dict() == oracle.provider.state_dict()
    np.testing.assert_array_equal(tr.scheduler.clock.t,
                                  oracle.scheduler.clock.t)
    assert repr(tr.speed.state_dict()) == repr(oracle.speed.state_dict())
    # and the continued run matches the oracle bit-for-bit
    state, info = tr.run_megabatch(state, prefetch=False)
    o_state, o_info = oracle.run_megabatch(o_state)
    assert info["train_loss"] == o_info["train_loss"]


def test_stale_prefetch_discarded_on_mismatch(case_ds):
    """A staged plan that no longer matches (b, lr) is replayed, not used."""
    tr = _trainer("adaptive", "scan", case_ds, True)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)
    assert tr._staged is not None
    state.b = state.b * 0 + float(tr.cfg.b_min)     # out-of-band mutation
    state.lr = state.lr * 0 + 0.125
    state, info = tr.run_megabatch(state)           # discard + restage
    assert tr._staged is None and np.isfinite(info["train_loss"])


def test_checkpoint_mid_prefetch_uses_snapshot_cursors(case_ds):
    """A pending prefetched plan must checkpoint the *pre-staging* cursors
    so a restore replays it instead of skipping its samples."""
    tr = _trainer("adaptive", "scan", case_ds, True)
    oracle = _trainer("adaptive", "scan", case_ds, False)
    state = tr.init_state()
    o_state = oracle.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)
    o_state, _ = oracle.run_megabatch(o_state)
    tree, meta = tr.checkpoint_payload(state)
    o_tree, o_meta = oracle.checkpoint_payload(o_state)
    assert meta["provider"] == o_meta["provider"]
    assert repr(meta["speed_meta"]) == repr(o_meta["speed_meta"])
    np.testing.assert_array_equal(tree["clock_t"], o_tree["clock_t"])
    for k in tree["speed"]:
        np.testing.assert_array_equal(tree["speed"][k], o_tree["speed"][k])


def test_overlap_off_consumes_stale_prefetch_safely(case_ds):
    """Flipping overlap off between calls rolls the prefetch back."""
    tr = _trainer("adaptive", "scan", case_ds, True)
    oracle = _trainer("adaptive", "scan", case_ds, False)
    state = tr.init_state()
    o_state = oracle.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)
    o_state, _ = oracle.run_megabatch(o_state)
    tr.overlap = False
    for _ in range(2):
        state, info = tr.run_megabatch(state)
        o_state, o_info = oracle.run_megabatch(o_state)
        assert info["train_loss"] == o_info["train_loss"]


# --------------------------------------------------------------------------
# async eval
# --------------------------------------------------------------------------


def test_evaluate_async_equals_sync(case_ds):
    from repro.data.sparse import train_test_split

    train, test = train_test_split(case_ds, 0.25, seed=2)
    tr = build_case_trainer("adaptive", "scan", True, train)
    batches = tr.provider.test_batches(test, tr.cfg.b_max)
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    sync = tr.evaluate(state.global_model, batches)
    collect = tr.evaluate_async(state.global_model, batches)
    state, _ = tr.run_megabatch(state)      # eval overlaps the mega-batch
    assert collect() == sync


def test_run_backfills_every_due_record(case_ds):
    from repro.data.sparse import train_test_split

    train, test = train_test_split(case_ds, 0.25, seed=3)
    tr = build_case_trainer("adaptive", "scan", True, train)
    batches = tr.provider.test_batches(test, tr.cfg.b_max)
    _, mlog = tr.run(5, test_batches=batches, eval_every=2)
    due = [i for i, r in enumerate(mlog.records) if "accuracy" in r]
    assert due == [1, 3]                    # the eval_every=2 cadence
    assert all(np.isfinite(mlog.records[i]["accuracy"]) for i in due)


# --------------------------------------------------------------------------
# per-shard measured timing
# --------------------------------------------------------------------------


class FakeTimer:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_shard_window_timer_basic():
    ft = FakeTimer()
    t = ShardWindowTimer(timer=ft)
    t.reset(2)
    t.mark_start(0)
    ft.t = 0.5
    t.mark_start(1)
    t.mark_start(0)              # duplicate start: first wins
    ft.t = 1.0
    t.mark_end(0)
    ft.t = 2.0
    t.mark_end(1)
    ft.t = 2.5
    t.mark_end(1)                # duplicate end: last wins
    w = t.take()
    np.testing.assert_allclose(w, [1.0, 2.0])
    assert t.take() is None      # self-clearing


def test_shard_window_timer_incomplete_is_none():
    ft = FakeTimer()
    t = ShardWindowTimer(timer=ft)
    t.reset(2)
    t.mark_start(0)
    ft.t = 1.0
    t.mark_end(0)                # shard 1 never reported
    assert t.take() is None
    t.reset(1)
    t.mark_start(0)
    t.mark_end(0)                # zero-width window
    assert t.take() is None


def test_observe_shards_attributes_per_shard_contrast():
    """2-fast-1-slow: per-shard windows converge to the true ratios that
    whole-window attribution cannot see through the lockstep barrier."""
    sm = MeasuredSpeedModel(3, warmup_windows=0, timer=FakeTimer())
    work = np.array([100.0, 100.0, 100.0])
    for _ in range(6):
        # shard 2's device is 3x slower; the barrier would stretch a single
        # host window to 3.0 for everyone
        sm.observe_shards(np.array([1.0, 1.0, 3.0]), work)
    f = sm.factors
    np.testing.assert_allclose(f, [1.0, 1.0, 3.0])
    # the whole-window fallback measures the same fleet as homogeneous
    sm2 = MeasuredSpeedModel(3, warmup_windows=0, timer=FakeTimer())
    for _ in range(6):
        sm2.observe_plan(work, 3.0)
    np.testing.assert_allclose(sm2.factors, np.ones(3))


def test_observe_shards_share_normalization():
    sm = MeasuredSpeedModel(4, warmup_windows=0, timer=FakeTimer())
    # 2 shards x 2 replicas; replica 3 was scheduled half the rounds (and
    # so did half the work): same per-round throughput as its shard-mate
    # must measure the same speed, not "twice as fast"
    sm.observe_shards(np.array([1.0, 2.0]), np.array([100.0, 100.0, 100.0, 50.0]),
                      u=np.array([4, 4, 4, 2]), n_rounds=4)
    f = sm.factors
    assert f[0] == f[1] == 1.0
    np.testing.assert_allclose(f[2], 2.0)
    np.testing.assert_allclose(f[3], 2.0)   # half window, half work


def test_observe_shards_rejects_stale_shard_count():
    sm = MeasuredSpeedModel(4, warmup_windows=0, timer=FakeTimer())
    sm.observe_shards(np.array([1.0, 1.0, 1.0]), np.array([100.0] * 4))
    assert (sm.n_obs == 0).all()            # 3 shards !| 4 replicas
    assert sm.n_windows == 1                # but the window was consumed


def test_observe_shards_warmup_gate_shared():
    sm = MeasuredSpeedModel(2, timer=FakeTimer())   # warmup_windows=1
    sm.observe_shards(np.array([9.0, 9.0]), np.array([100.0, 100.0]))
    assert (sm.n_obs == 0).all()
    sm.observe_shards(np.array([1.0, 2.0]), np.array([100.0, 100.0]))
    np.testing.assert_allclose(sm.factors, [1.0, 2.0])


def test_sharded_measured_overlap_smoke(case_ds):
    """End-to-end: sharded placement + measured speed + overlap records
    per-shard windows via the debug-callback markers (single-shard mesh
    in-process; the multi-shard path runs in the subprocess suite)."""
    base = build_case_trainer("adaptive", "scan", True, case_ds,
                              placement="sharded")
    tr = ElasticTrainer(
        base.model, base.provider, base.cfg, base_lr=0.5, seed=3,
        engine="scan", speed=MeasuredSpeedModel(base.cfg.n_replicas),
        overlap=True,
    )
    assert tr._shard_timer is not None
    state = tr.init_state()
    state, _ = tr.run_megabatch(state, prefetch=True)   # warmup window
    state, _ = tr.run_megabatch(state, prefetch=False)
    assert (tr.speed.n_obs > 0).all()
    assert np.isfinite(tr.speed.t_per_work).all()


# --------------------------------------------------------------------------
# multi-device overlap parity (subprocess; the CI multi-device job)
# --------------------------------------------------------------------------

OVERLAP_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

    import numpy as np
    import jax
    import jax.tree_util as jtu

    assert len(jax.devices()) == 8, jax.devices()

    from golden.generate import build_case_trainer, make_case_dataset
    from repro.core import algorithms

    ds = make_case_dataset()

    def run(algo, overlap):
        tr = build_case_trainer(algo, "scan", True, ds, placement="sharded")
        tr.overlap = overlap
        state, mlog = tr.run(2)
        return state, [r["train_loss"] for r in mlog.records]

    for algo in sorted(algorithms.available()):
        st_on, losses_on = run(algo, True)
        st_off, losses_off = run(algo, False)
        assert losses_on == losses_off, (algo, losses_on, losses_off)
        for a, b in zip(jtu.tree_leaves(st_on.replicas),
                        jtu.tree_leaves(st_off.replicas)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), algo
        print(f"OK {algo}")
    print("OVERLAP-PARITY-PASSED")
""")


@pytest.mark.slow
def test_overlap_sharded_multidevice_parity():
    """Overlap on == off, bitwise, on a real multi-shard replica mesh."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "tests"),
         env.get("PYTHONPATH", "")]
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", OVERLAP_PARITY_SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, (
        f"overlap parity subprocess failed\n--- stdout ---\n{proc.stdout}"
        f"\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "OVERLAP-PARITY-PASSED" in proc.stdout
