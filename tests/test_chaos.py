"""Kill-and-restore chaos test (DESIGN.md §7 acceptance).

Runs the real launcher (``python -m repro.launch.train``) as a subprocess
with async checkpointing on, SIGKILLs it as soon as the first complete
checkpoint is published, restarts with ``--restore-from``, and checks:

* the restart resumes exactly one mega-batch after the newest *complete*
  checkpoint (at most one checkpoint interval of work is lost),
* the post-restore loss trajectory matches an uninterrupted reference run
  (CPU runs are deterministic; restore must be trajectory-equivalent).
"""
from __future__ import annotations

import os
import re
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

EVERY = 2
MEGABATCHES = 12

LOSS_RE = re.compile(r"\[repro\] \[adaptive\] mb=(\d+) loss=([^ ]+)")


def _base_cmd():
    return [
        sys.executable, "-u", "-m", "repro.launch.train",
        "--workload", "xml", "--samples", "1024", "--features", "256",
        "--classes", "64", "--hidden", "32", "--b-max", "32",
        "--mega-batch", "6", "--replicas", "3", "--algorithm", "adaptive",
        "--megabatches", str(MEGABATCHES), "--seed", "0",
    ]


def _env():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")]
    )
    return env


def _losses(stderr: str) -> dict[int, float]:
    return {
        int(m.group(1)): float(m.group(2))
        for m in LOSS_RE.finditer(stderr)
    }


def _complete_checkpoints(ckpt_dir) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("ckpt-") and os.path.exists(
            os.path.join(ckpt_dir, name, "meta.json")
        ):
            out.append(int(name.split("-")[1]))
    return sorted(out)


@pytest.mark.slow
def test_sigkill_and_restore_matches_uninterrupted_run(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    env = _env()

    # 1. uninterrupted reference trajectory
    ref = subprocess.run(
        _base_cmd(), capture_output=True, text=True, env=env, timeout=600,
    )
    assert ref.returncode == 0, ref.stderr[-4000:]
    ref_losses = _losses(ref.stderr)
    assert sorted(ref_losses) == list(range(1, MEGABATCHES + 1))

    # 2. same run with async checkpointing; SIGKILL (no cleanup, no atexit)
    # the instant the first complete checkpoint is published
    victim = subprocess.Popen(
        _base_cmd() + ["--checkpoint-dir", ckpt_dir,
                       "--checkpoint-every", str(EVERY)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env,
    )
    deadline = time.monotonic() + 300
    while not _complete_checkpoints(ckpt_dir):
        if victim.poll() is not None:
            _, err = victim.communicate()
            pytest.fail(f"victim exited before checkpointing:\n{err[-4000:]}")
        if time.monotonic() > deadline:
            victim.kill()
            pytest.fail("no checkpoint published within 300s")
        time.sleep(0.05)
    victim.send_signal(signal.SIGKILL)
    _, victim_err = victim.communicate()
    assert victim.returncode == -signal.SIGKILL

    published = _complete_checkpoints(ckpt_dir)
    latest = published[-1]
    victim_done = max(_losses(victim_err), default=0)
    # crash consistency: whatever survived is a complete checkpoint, and at
    # most the interval being written on top of the current one is lost
    assert latest >= 1
    assert victim_done - latest <= 2 * EVERY

    # 3. restore and finish the run
    resumed = subprocess.run(
        _base_cmd() + ["--checkpoint-dir", ckpt_dir,
                       "--checkpoint-every", str(EVERY),
                       "--restore-from", ckpt_dir],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert resumed.returncode == 0, resumed.stderr[-4000:]
    res_losses = _losses(resumed.stderr)

    # resumes exactly one mega-batch after the newest complete checkpoint
    assert sorted(res_losses) == list(range(latest + 1, MEGABATCHES + 1))

    # trajectory equivalence with the uninterrupted run
    mbs = sorted(res_losses)
    np.testing.assert_allclose(
        [res_losses[mb] for mb in mbs],
        [ref_losses[mb] for mb in mbs],
        rtol=1e-4, atol=1e-6,
    )


def test_checkpoint_mid_prefetch_restore_equivalence(tmp_path):
    """Overlap interplay (DESIGN.md §8): a checkpoint taken while the next
    mega-batch is prefetched must record the *pre-staging* cursors, so the
    restored run replays the staged-but-untrained batch. In-process (no
    SIGKILL): the writer runs with overlap on and checkpoints at a boundary
    where a prefetch is pending; a fresh trainer restores and continues;
    the trajectory must match an uninterrupted run mega-batch for
    mega-batch."""
    from golden.generate import build_case_trainer, make_case_dataset
    from repro.checkpoint import store

    N, K = 6, 2
    ds = make_case_dataset()

    straight = build_case_trainer("adaptive", "scan", True, ds)
    _, s_log = straight.run(N)
    ref = {r["megabatch"]: r["train_loss"] for r in s_log.records}

    # writer stops after 3 mega-batches; its ckpt-2 was saved while the
    # plan for mega-batch 3 sat prefetched (run() prefetches every non-
    # final boundary with overlap on)
    writer = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    assert writer.overlap
    mgr = store.CheckpointManager(str(tmp_path / "c"), every=K)
    _, w_log = writer.run(3, checkpoint=mgr)
    for r in w_log.records:
        assert r["train_loss"] == ref[r["megabatch"]]

    resumed = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    _, r_log = resumed.run(N, restore_from=str(tmp_path / "c"))
    got = {r["megabatch"]: r["train_loss"] for r in r_log.records}
    assert sorted(got) == [3, 4, 5, 6]      # resumed one past ckpt-2
    for mb, loss in got.items():
        assert loss == ref[mb], (mb, loss, ref[mb])
