"""InterleaveSentinel suite (ISSUE 10): the runtime half of the
concurrency family.

Layers:

* scheduler semantics — determinism (same seed → same schedule → same
  outcome), seed diversity, deadlock detection, cooperative lock mutual
  exclusion, virtual-time event waits, thread-error propagation;
* regressions against real units — each test drives a pre-existing
  concurrency defect fixed in this PR and asserts the post-fix invariant
  over *every* explored interleaving:
    - HeartbeatMonitor: a concurrent daemon renewal must not resurrect
      ``status="live"`` over an announced ``"leaving"`` (sticky status);
    - CheckpointManager: exactly one caller claims a writer-thread error
      (atomic check-and-clear in ``_reraise``);
    - ShardWindowTimer: concurrent start markers take exactly one
      timestamp (first-wins is atomic with its check);
* exploration — StagingBuffers' busy latch holds under every schedule.

The sentinel fully serializes its threads, so these tests are exact, not
probabilistic: a failure names the seed, and rerunning that seed replays
the identical schedule.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)

from tools.jaxlint.interleave import (  # noqa: E402
    InterleaveError,
    InterleaveSentinel,
)

SEEDS = range(8)


# --------------------------------------------------------------------------
# scheduler semantics
# --------------------------------------------------------------------------


def _racy_counter(seed: int, locked: bool):
    """Two threads do read-modify-write ×3 each; unguarded, a seed may
    lose updates. Returns (schedule, final_count)."""
    sent = InterleaveSentinel(seed=seed)
    lock = sent.lock("counter") if locked else None
    state = {"x": 0}

    def body(name):
        for _ in range(3):
            if locked:
                with lock:
                    v = state["x"]
                    sent.yield_point(f"{name}-rmw")
                    state["x"] = v + 1
            else:
                v = state["x"]
                sent.yield_point(f"{name}-rmw")
                state["x"] = v + 1

    sent.spawn("a", body, "a")
    sent.spawn("b", body, "b")
    sent.run()
    return tuple(sent.schedule), state["x"]


def test_same_seed_same_schedule_same_outcome():
    s1, x1 = _racy_counter(7, locked=False)
    s2, x2 = _racy_counter(7, locked=False)
    assert s1 == s2 and x1 == x2


def test_seeds_explore_distinct_interleavings():
    schedules = {_racy_counter(s, locked=False)[0] for s in SEEDS}
    assert len(schedules) > 1


def test_unguarded_rmw_loses_updates_on_some_seed():
    finals = [_racy_counter(s, locked=False)[1] for s in SEEDS]
    assert any(x < 6 for x in finals), finals


def test_sentinel_lock_restores_atomicity_on_every_seed():
    finals = [_racy_counter(s, locked=True)[1] for s in SEEDS]
    assert all(x == 6 for x in finals), finals


def test_deadlock_is_detected_deterministically():
    def run_once(seed):
        sent = InterleaveSentinel(seed=seed)
        l1, l2 = sent.lock("l1"), sent.lock("l2")

        def ab():
            with l1:
                sent.yield_point("got l1")
                with l2:
                    pass

        def ba():
            with l2:
                sent.yield_point("got l2")
                with l1:
                    pass

        sent.spawn("ab", ab)
        sent.spawn("ba", ba)
        sent.run(timeout=10)

    hit = []
    for seed in SEEDS:
        try:
            run_once(seed)
        except InterleaveError as e:
            assert "deadlock" in str(e)
            hit.append(seed)
    assert hit, "no seed produced the lock-order deadlock"
    # and the detection itself is deterministic per seed
    with pytest.raises(InterleaveError, match="deadlock"):
        run_once(hit[0])


def test_event_timed_wait_is_virtual():
    """A timed wait never parks: sentinel time is virtual, the timeout is
    deemed elapsed and the flag state is returned immediately."""
    sent = InterleaveSentinel(seed=0)
    ev = sent.event("go")
    seen = []

    def solo():
        seen.append(ev.wait(timeout=300.0))  # unset: returns False, no sleep
        ev.set()
        seen.append(ev.wait(timeout=300.0))  # set: returns True

    sent.spawn("solo", solo)
    sent.run(timeout=10)
    assert seen == [False, True]


def test_event_untimed_wait_blocks_until_set():
    sent = InterleaveSentinel(seed=0)
    ev = sent.event("go")
    order = []

    def waiter():
        ev.wait()  # untimed: parks until the setter runs
        order.append("woke")

    def setter():
        order.append("set")
        ev.set()

    sent.spawn("waiter", waiter)
    sent.spawn("setter", setter)
    sent.run(timeout=10)
    assert order == ["set", "woke"]


def test_thread_exception_reraised_from_run():
    sent = InterleaveSentinel(seed=0)

    def boom():
        raise ValueError("inner failure")

    sent.spawn("boom", boom)
    with pytest.raises(ValueError, match="inner failure"):
        sent.run(timeout=10)


# --------------------------------------------------------------------------
# regression: HeartbeatMonitor sticky status (the ISSUE 10 defect)
# --------------------------------------------------------------------------


def _lease_status(mon):
    from repro.core.fleet import LEASE_PREFIX

    path = os.path.join(mon.leases_dir, f"{LEASE_PREFIX}{mon.process_id}.json")
    with open(path) as f:
        return json.load(f)["status"]


@pytest.mark.parametrize("seed", SEEDS)
def test_daemon_renewal_cannot_resurrect_announced_departure(tmp_path, seed):
    """Pre-fix, ``renew`` took ``status`` as a per-call parameter
    defaulting to "live": a daemon-thread renewal racing an announced
    ``status="leaving"`` could publish "live" *last*, erasing the
    departure peers act on. Post-fix the status is sticky monitor state —
    every interleaving leaves "leaving" on disk."""
    from repro.core.fleet import HeartbeatMonitor

    mon = HeartbeatMonitor(str(tmp_path), process_id=0)
    sent = InterleaveSentinel(seed=seed, trace=("repro/core/fleet.py",))
    mon._lock = sent.lock("monitor")  # cooperative: scheduler keeps control
    sent.spawn("main", mon.renew, status="leaving")
    sent.spawn("daemon", mon.renew)  # the background loop's bare renew()
    sent.run()
    assert _lease_status(mon) == "leaving"


# --------------------------------------------------------------------------
# regression: CheckpointManager error conservation
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_checkpoint_error_claimed_exactly_once(tmp_path, monkeypatch, seed):
    """Pre-fix ``_reraise`` did a bare check-then-swap: two concurrent
    callers could both pass the check, double-raising one failure (the
    second with ``None``). Post-fix the check-and-clear is atomic, so
    exactly one caller claims the error under every interleaving."""
    from repro.checkpoint import store as store_mod
    from repro.checkpoint.store import CheckpointError, CheckpointManager

    mgr = CheckpointManager(str(tmp_path), async_write=True)
    sent = InterleaveSentinel(
        seed=seed, trace=("repro/checkpoint/store.py",)
    )
    mgr._lock = sent.lock("store")

    def failing_save(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(store_mod, "save", failing_save)
    caught = []

    def reader(tag):
        try:
            mgr._reraise()
        except CheckpointError:
            caught.append(tag)

    sent.spawn("writer", mgr._write_job, str(tmp_path / "ckpt"), {}, {})
    sent.spawn("r1", reader, "r1")
    sent.spawn("r2", reader, "r2")
    sent.run()
    pending = 1 if mgr._error is not None else 0
    assert len(caught) + pending == 1, (caught, mgr._error)


# --------------------------------------------------------------------------
# regression: ShardWindowTimer first-wins start marker
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_shard_timer_first_start_marker_wins_atomically(seed):
    """Pre-fix ``mark_start`` was a bare check-then-set over ``_t0``: two
    callback threads for the same shard could both pass the ``not in``
    check and both stamp, so the *later* timestamp could win and shrink
    the measured window. Post-fix the check is atomic with the set:
    exactly one timer() call per shard, on every interleaving."""
    from repro.core.heterogeneity import ShardWindowTimer

    calls = []

    def fake_timer():
        calls.append(len(calls))
        return float(len(calls))

    t = ShardWindowTimer(timer=fake_timer)
    sent = InterleaveSentinel(
        seed=seed, trace=("repro/core/heterogeneity.py",)
    )
    if hasattr(t, "_lock"):
        t._lock = sent.lock("timer")
    t.reset(1)
    sent.spawn("cb1", t.mark_start, 0)
    sent.spawn("cb2", t.mark_start, 0)
    sent.run()
    assert len(calls) == 1, f"{len(calls)} timestamps for one shard"
    t.mark_end(0)
    w = t.take()
    assert w is not None and np.all(w > 0)


# --------------------------------------------------------------------------
# exploration: StagingBuffers busy latch
# --------------------------------------------------------------------------


def test_staging_buffer_busy_latch_holds_under_every_schedule():
    """Three producers race acquire→release over the two alternating
    staging slots. Whatever the schedule: no two producers ever hold the
    same slot at once (the latch raises instead of handing out an
    in-flight buffer), and the seeds genuinely explore both the
    fully-serialized and the latched orderings."""
    from repro.data.batcher import StagingBuffers

    spec = {"x": ((2, 2), np.float32)}
    outcome_sets = set()
    for seed in SEEDS:
        bufs = StagingBuffers()
        sent = InterleaveSentinel(seed=seed)
        outcomes = []
        in_flight: set[int] = set()

        def producer(tag, sent=sent, bufs=bufs, outcomes=outcomes,
                     in_flight=in_flight):
            try:
                slot_id, _ = bufs.acquire(spec)
            except RuntimeError:
                outcomes.append("latched")
                return
            assert slot_id not in in_flight, "double-acquired in-flight slot"
            in_flight.add(slot_id)
            sent.yield_point(f"{tag}-in-flight")
            in_flight.discard(slot_id)
            bufs.release(slot_id)
            outcomes.append("ok")

        for tag in ("p1", "p2", "p3"):
            sent.spawn(tag, producer, tag)
        sent.run()
        assert len(outcomes) == 3
        outcome_sets.add(tuple(sorted(outcomes)))
    # exploration actually reached more than one protocol outcome
    assert len(outcome_sets) > 1, outcome_sets
