"""Multi-host fleet tests (DESIGN.md §10).

Three layers:

* :class:`HeartbeatMonitor` under a fake clock — lease renewal, missed
  deadlines, flapping, rejoin backoff, tombstones — no real sleeps;
* :class:`FleetController` consuming monitor events through a stub
  trainer (the heartbeat → eviction path, no injector involved);
* :class:`MultihostContext` — slot blocks, the file exchange (two
  contexts in threads), peer-death drop, event agreement;
* subprocess end-to-end (``slow``): a two-process fleet matches the
  single-process sharded trajectory, and a SIGKILLed process is evicted
  via the heartbeat path while the survivor completes the run.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.fleet import (
    FleetController,
    HeartbeatMonitor,
    read_leases,
    write_lease,
)
from repro.launch.multihost import (
    MultihostSpec,
    ProcessCondemned,
    bootstrap,
    spec_from_env,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_monitor(tmp_path, clock, process_id=None, **kw):
    kw.setdefault("grace", 3.0)
    kw.setdefault("rejoin_backoff", 2)
    return HeartbeatMonitor(
        str(tmp_path), process_id=process_id, clock=clock, **kw
    )


# ---------------------------------------------------------------------------
# lease files


def test_write_and_read_leases(tmp_path):
    d = str(tmp_path)
    write_lease(d, 0, 1, megabatch=7)
    write_lease(d, 1, 4, status="leaving")
    (tmp_path / "junk.json").write_text("{not json")
    (tmp_path / "README").write_text("ignore me")
    leases = read_leases(d)
    assert set(leases) == {0, 1}
    assert leases[0]["megabatch"] == 7
    assert leases[1]["status"] == "leaving"


def test_write_lease_rejects_unknown_status(tmp_path):
    with pytest.raises(ValueError):
        write_lease(str(tmp_path), 0, 1, status="zombie")


# ---------------------------------------------------------------------------
# HeartbeatMonitor under a fake clock


def test_renewing_peer_stays_live(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew(megabatch=0)
    for mb in range(5):
        mon.renew(megabatch=mb)
        assert mon.poll(mb) == []
        clock.advance(2.0)          # < grace, renewed every boundary
        peer.renew(megabatch=mb)
    assert mon.live_processes() == {0, 1}
    assert mon.last_megabatch(1) == 4


def test_missed_deadline_is_a_crash_reported_once(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    assert mon.poll(0) == []        # lease observed fresh
    clock.advance(3.5)              # > grace, never renewed
    events = mon.poll(1)
    assert [(e.kind, e.process) for e in events] == [("crash", 1)]
    assert mon.poll(2) == []        # dead peers are not re-reported
    assert not mon.peer_fresh(1)


def test_flap_inside_grace_is_not_an_event(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    assert mon.poll(0) == []
    clock.advance(2.9)              # one long mega-batch, still in grace
    peer.renew()
    assert mon.poll(1) == []        # renewal resets the staleness clock
    clock.advance(2.9)
    assert mon.poll(2) == []


def test_rejoin_waits_out_the_backoff(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    mon.poll(0)
    clock.advance(4.0)
    assert [e.kind for e in mon.poll(2)] == ["crash"]   # evicted at mb=2
    peer.renew()                    # the process is back...
    assert mon.poll(3) == []        # ...but 3 - 2 < rejoin_backoff (2)
    clock.advance(0.5)
    peer.renew()
    events = mon.poll(4)            # 4 - 2 >= backoff -> join
    assert [(e.kind, e.process) for e in events] == [("join", 1)]
    assert mon.poll(5) == []        # live again, nothing to report


def test_leaving_status_is_a_preempt(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew(status="leaving")
    events = mon.poll(0)
    assert [(e.kind, e.process) for e in events] == [("preempt", 1)]
    assert mon.poll(1) == []


def test_done_status_is_a_clean_exit(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew(status="done")
    assert mon.poll(0) == []
    clock.advance(10.0)             # staleness after 'done' is not a crash
    assert mon.poll(1) == []
    assert 1 not in mon.live_processes()


def test_tombstone_outranks_a_fresh_lease(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    mon.poll(0)
    (tmp_path / "condemned" / "p1").write_text("")
    events = mon.poll(1)
    assert [(e.kind, e.process) for e in events] == [("crash", 1)]


def test_condemned_self_raises(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    mon.renew()
    (tmp_path / "condemned" / "p0").write_text("")
    with pytest.raises(RuntimeError, match="condemned"):
        mon.poll(0)


def test_background_renewal_thread_uses_real_time(tmp_path):
    mon = HeartbeatMonitor(str(tmp_path), process_id=0, interval=0.01)
    mon.renew(megabatch=0)
    first = read_leases(mon.leases_dir)[0]["counter"]
    mon.start()
    try:
        for _ in range(100):
            if read_leases(mon.leases_dir)[0]["counter"] > first:
                break
            time.sleep(0.02)
        else:
            pytest.fail("renewal thread never renewed")
    finally:
        mon.stop()


# ---------------------------------------------------------------------------
# FleetController consuming monitor events (stub trainer, no injector)


class _StubAlgo:
    resize_policy = "merge"


class _StubCfg:
    def __init__(self, n):
        self.n_replicas = n


class _StubTrainer:
    """Records membership calls; mimics the trainer's width bookkeeping."""

    def __init__(self, n):
        self.cfg = _StubCfg(n)
        self.algo = _StubAlgo()
        self.calls = []

    def remove_replicas(self, state, slots, merge_leavers=False):
        self.calls.append(("remove", tuple(slots), merge_leavers))
        self.cfg.n_replicas -= len(slots)
        return state

    def resize(self, state, n):
        self.calls.append(("resize", n))
        self.cfg.n_replicas = n
        return state

    def invalidate_prefetch(self):
        pass


def test_controller_evicts_dead_process_via_slot_map(tmp_path):
    clock = FakeClock()
    mon = make_monitor(
        tmp_path, clock, process_id=0, slot_map={0: [0, 1], 1: [2, 3]}
    )
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    fleet = FleetController(monitor=mon, verbose=False)
    trainer = _StubTrainer(4)
    fleet.step(trainer, "state", 1)
    assert trainer.calls == []
    clock.advance(4.0)              # peer dies silently
    fleet.step(trainer, "state", 2)
    assert trainer.calls == [("remove", (2, 3), False)]
    assert trainer.cfg.n_replicas == 2
    # the monitor path queues no quarantine: no injector-style rejoin
    fleet.step(trainer, "state", 3)
    fleet.step(trainer, "state", 10)
    assert trainer.calls == [("remove", (2, 3), False)]


def test_controller_readmits_on_lease_resume(tmp_path):
    clock = FakeClock()
    mon = make_monitor(
        tmp_path, clock, process_id=0, slot_map={0: [0, 1], 1: [2, 3]}
    )
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    fleet = FleetController(monitor=mon, max_replicas=8, verbose=False)
    trainer = _StubTrainer(4)
    fleet.step(trainer, "state", 1)
    clock.advance(4.0)
    fleet.step(trainer, "state", 2)         # evicted at mb=2
    peer.renew()                            # lease resumes
    fleet.step(trainer, "state", 3)         # inside backoff: nothing
    clock.advance(0.5)
    peer.renew()
    fleet.step(trainer, "state", 4)         # backoff elapsed: join
    assert trainer.calls == [("remove", (2, 3), False), ("resize", 4)]


def test_controller_preempt_merges_leavers(tmp_path):
    clock = FakeClock()
    mon = make_monitor(
        tmp_path, clock, process_id=0, slot_map={1: [2, 3]}
    )
    write_lease(mon.leases_dir, 1, 1, status="leaving")
    fleet = FleetController(monitor=mon, verbose=False)
    trainer = _StubTrainer(4)
    fleet.step(trainer, "state", 1)
    assert trainer.calls == [("remove", (2, 3), True)]


def test_controller_respects_min_replicas(tmp_path):
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0, slot_map={1: [2, 3]})
    peer = make_monitor(tmp_path, clock, process_id=1)
    peer.renew()
    fleet = FleetController(monitor=mon, min_replicas=3, verbose=False)
    trainer = _StubTrainer(4)
    fleet.step(trainer, "state", 1)
    clock.advance(4.0)
    fleet.step(trainer, "state", 2)   # 4 - 2 < min_replicas: skip
    assert trainer.calls == []
    assert trainer.cfg.n_replicas == 4


# ---------------------------------------------------------------------------
# MultihostContext: specs, slots, the file exchange


def test_spec_from_env_roundtrip(tmp_path):
    assert spec_from_env({}) is None
    env = {
        "REPRO_MH_NUM_PROCESSES": "2",
        "REPRO_MH_PROCESS_ID": "1",
        "REPRO_MH_FLEET_DIR": str(tmp_path),
    }
    spec = spec_from_env(env)
    assert spec == MultihostSpec(
        num_processes=2, process_id=1, fleet_dir=str(tmp_path)
    )
    with pytest.raises(ValueError):
        MultihostSpec(num_processes=2, process_id=5, fleet_dir=str(tmp_path))


def _ctx(tmp_path, pid, n=2):
    spec = MultihostSpec(
        num_processes=n, process_id=pid, fleet_dir=str(tmp_path),
        spanning="host",
    )
    return bootstrap(spec)


def test_slot_blocks(tmp_path):
    ctx = _ctx(tmp_path, 0)
    ctx.assign_slots(4)
    assert ctx.local_bounds() == (0, 2)
    assert ctx.bounds_of(1) == (2, 4)
    assert ctx.slots_of(1) == [2, 3]
    assert ctx.processes_for_slots([2, 3]) == [1]
    with pytest.raises(ValueError):
        ctx.processes_for_slots([1, 2])   # tears a block
    with pytest.raises(ValueError):
        ctx.assign_slots(3)               # not divisible
    with pytest.raises(ProcessCondemned):
        ctx.processes_for_slots([0, 1])   # dropping *our* block


def test_remove_process_renumbers_survivors_first(tmp_path):
    ctx = _ctx(tmp_path, 0, n=3)
    ctx.assign_slots(6)
    ctx.remove_process(1)
    assert ctx.active_processes() == [0, 2]
    assert 1 in ctx.condemned()
    ctx.assign_slots(4)
    assert ctx.bounds_of(0) == (0, 2)
    assert ctx.bounds_of(2) == (2, 4)


def test_exchange_allreduce_and_allgather(tmp_path):
    c0, c1 = _ctx(tmp_path, 0), _ctx(tmp_path, 1)
    results = {}

    def run(pid, ctx):
        tree = {"x": np.full(3, float(pid + 1)), "n": np.float64(pid)}
        results[pid] = (
            ctx.allreduce_sum("t", tree),
            ctx.allgather("g", np.asarray([pid])),
        )

    threads = [
        threading.Thread(target=run, args=(p, c))
        for p, c in ((0, c0), (1, c1))
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for pid in (0, 1):
        (tree, contributors), gathered = results[pid]
        assert contributors == [0, 1]
        np.testing.assert_allclose(tree["x"], np.full(3, 3.0))
        assert float(tree["n"]) == 1.0
        assert sorted(gathered) == [0, 1]
        assert int(gathered[1][0]) == 1


class _DeadPeerLiveness:
    """Exchange wait predicate stub: peer 1 is gone."""

    def __init__(self):
        self.condemned = []

    def peer_fresh(self, pid):
        return pid != 1

    def note_condemned(self, pid):
        self.condemned.append(pid)


def test_exchange_drops_stale_peer_and_condemns_it(tmp_path):
    ctx = _ctx(tmp_path, 0)
    liveness = _DeadPeerLiveness()
    ctx.attach_liveness(liveness)
    tree, contributors = ctx.allreduce_sum("t", {"x": np.ones(2)})
    assert contributors == [0]
    np.testing.assert_allclose(tree["x"], np.ones(2))
    assert liveness.condemned == [1]
    assert os.path.exists(os.path.join(str(tmp_path), "condemned", "p1"))
    # once condemned, a later exchange never waits for it again
    tree, contributors = ctx.allreduce_sum("t2", {"x": np.ones(2)})
    assert contributors == [0]


def test_agree_events_union_and_self_condemnation(tmp_path):
    from repro.core.fleet import FaultEvent

    c0, c1 = _ctx(tmp_path, 0), _ctx(tmp_path, 1)
    out = {}

    def run(pid, ctx, events):
        try:
            out[pid] = ctx.agree_events(events)
        except ProcessCondemned as e:
            out[pid] = e

    # process 0 proposes evicting process 1 (whose own view is clean):
    # the union must reach both — 0 applies it, 1 stops participating.
    t0 = threading.Thread(
        target=run, args=(0, c0, [FaultEvent("crash", process=1)])
    )
    t1 = threading.Thread(target=run, args=(1, c1, []))
    t0.start()
    t1.start()
    t0.join()
    t1.join()
    assert [(e.kind, e.process) for e in out[0]] == [("crash", 1)]
    assert isinstance(out[1], ProcessCondemned)


def test_single_process_exchange_short_circuits(tmp_path):
    ctx = _ctx(tmp_path, 0, n=1)
    tree, contributors = ctx.allreduce_sum("t", {"x": np.ones(2)})
    assert contributors == [0]
    gathered = ctx.allgather("g", np.ones(1))
    assert list(gathered) == [0]


# ---------------------------------------------------------------------------
# subprocess end-to-end


MEGABATCHES = 5
LOSS_RE = re.compile(r"\[repro\] \[adaptive\] mb=(\d+) loss=([^ ]+)")


def _root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env(device_count=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_root(), "src"), env.get("PYTHONPATH", "")]
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    if device_count is not None:
        env["XLA_FLAGS"] = (
            f"{env.get('XLA_FLAGS', '')} "
            f"--xla_force_host_platform_device_count={device_count}"
        ).strip()
        env.pop("REPRO_MH_NUM_PROCESSES", None)
    return env


def _workload_args(megabatches=MEGABATCHES):
    return [
        "--workload", "xml", "--samples", "1024", "--features", "256",
        "--classes", "64", "--hidden", "32", "--b-max", "32",
        "--mega-batch", "6", "--replicas", "4", "--algorithm", "adaptive",
        "--megabatches", str(megabatches), "--seed", "0",
    ]


def _losses(text):
    return {int(m.group(1)): float(m.group(2)) for m in LOSS_RE.finditer(text)}


def _launch(tmp_path, extra, train_extra, megabatches=MEGABATCHES):
    fleet_dir = str(tmp_path / "fleet")
    cmd = [
        sys.executable, os.path.join(_root(), "scripts", "multihost_launch.py"),
        "--procs", "2", "--devices-per-proc", "2",
        "--fleet-dir", fleet_dir, "--timeout", "600",
        *extra, "--", *_workload_args(megabatches), *train_extra,
    ]
    res = subprocess.run(
        cmd, capture_output=True, text=True, env=_env(), timeout=700,
    )
    logs = {}
    for pid in (0, 1):
        path = os.path.join(fleet_dir, "logs", f"proc{pid}.log")
        logs[pid] = open(path).read() if os.path.exists(path) else ""
    return res, logs


@pytest.mark.slow
def test_two_process_run_matches_single_process_trajectory(tmp_path):
    ref = subprocess.run(
        [sys.executable, "-u", "-m", "repro.launch.train",
         *_workload_args(), "--placement", "sharded", "--multihost", "off"],
        capture_output=True, text=True, env=_env(device_count=4), timeout=600,
    )
    assert ref.returncode == 0, ref.stderr[-4000:]
    ref_losses = _losses(ref.stderr)
    assert sorted(ref_losses) == list(range(1, MEGABATCHES + 1))

    res, logs = _launch(tmp_path, [], [])
    assert res.returncode == 0, res.stdout[-4000:] + res.stderr[-2000:]
    for pid in (0, 1):
        mh_losses = _losses(logs[pid])
        assert sorted(mh_losses) == list(range(1, MEGABATCHES + 1)), logs[pid][-2000:]
        for mb, ref_loss in ref_losses.items():
            assert abs(mh_losses[mb] - ref_loss) <= 2e-3 * (1 + abs(ref_loss)), (
                f"proc {pid} mb={mb}: {mh_losses[mb]} vs ref {ref_loss}"
            )


@pytest.mark.slow
def test_sigkill_heals_through_heartbeat_path(tmp_path):
    res, logs = _launch(
        tmp_path,
        ["--kill-proc", "1", "--kill-after-mb", "2"],
        ["--heartbeat-interval", "0.3", "--heartbeat-grace", "2.0"],
        megabatches=8,
    )
    assert res.returncode == 0, res.stdout[-4000:] + res.stderr[-2000:]
    survivor = logs[0]
    # the eviction came from the heartbeat -> FleetController path
    assert "action=evict" in survivor and "process=1" in survivor, survivor[-3000:]
    assert "crash" in survivor
    # training completed at the reduced width
    assert f"mb={8} " in survivor or "mb=8 " in survivor, survivor[-3000:]
    assert "final" in survivor


# ---------------------------------------------------------------------------
# ISSUE 10 regressions: sticky lease status, injectable exchange clock
# ---------------------------------------------------------------------------


def test_renew_status_is_sticky(tmp_path):
    """Once a process announces 'leaving'/'done', later renewals that pass
    no status (the daemon loop's bare ``renew()``) must keep republishing
    it — a per-call default of 'live' would resurrect the departure."""
    clock = FakeClock()
    mon = make_monitor(tmp_path, clock, process_id=0)
    mon.renew(megabatch=1)
    assert read_leases(mon.leases_dir)[0]["status"] == "live"
    mon.renew(status="leaving")
    mon.renew(megabatch=2)          # daemon-style renewal: no status arg
    lease = read_leases(mon.leases_dir)[0]
    assert lease["status"] == "leaving"
    assert lease["megabatch"] == 2  # liveness itself keeps flowing
    mon.renew(status="done")
    mon.renew()
    assert read_leases(mon.leases_dir)[0]["status"] == "done"


def test_rendezvous_times_out_on_fake_clock(tmp_path):
    """The rendezvous/exchange wait loops run on injectable _clock/_sleep
    (JL105): a missing peer times out in virtual time, no real sleeping."""
    ctx = _ctx(tmp_path, 0)
    clock = FakeClock()
    ctx._clock = clock
    ctx._sleep = lambda dt: clock.advance(dt)  # sleeping advances the clock
    mon = make_monitor(tmp_path, clock, process_id=0)
    mon.renew()                      # own lease only; peer 1 never appears
    with pytest.raises(RuntimeError, match="rendezvous timed out"):
        ctx.rendezvous(timeout=5.0)
    assert clock.t >= 5.0            # the wait burned virtual, not real, time


def test_exchange_times_out_on_fake_clock(tmp_path):
    ctx = _ctx(tmp_path, 0)
    clock = FakeClock()
    ctx._clock = clock
    ctx._sleep = lambda dt: clock.advance(dt)
    ctx.exchange_timeout = 5.0
    with pytest.raises(RuntimeError, match="timed out waiting for"):
        ctx.allreduce_sum("t", [np.ones(2)])  # peer 1 never contributes
    assert clock.t >= 5.0
