"""Model-internals correctness: SSD vs naive recurrence, decode==forward,
blockwise attention vs dense reference, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.models import model as MDL
from repro.models.layers import blockwise_attention
from repro.models.mamba2 import ssd_chunked
from repro.models.moe import _dispatch_indices, moe_ffn, init_moe


def dense_attention_ref(q, k, v, causal=True, window=0):
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    s = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    rel = np.arange(sq)[:, None] - np.arange(skv)[None, :]
    allow = np.ones((sq, skv), bool)
    if causal:
        allow &= rel >= 0
    if window:
        allow &= rel < window
    s = np.where(allow[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


class TestBlockwiseAttention:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
    @pytest.mark.parametrize("chunk", [8, 32, 64])
    def test_matches_dense(self, causal, window, chunk):
        rng = np.random.default_rng(0)
        q = rng.normal(size=(2, 64, 3, 16)).astype(np.float32)
        k = rng.normal(size=(2, 64, 3, 16)).astype(np.float32)
        v = rng.normal(size=(2, 64, 3, 16)).astype(np.float32)
        out = blockwise_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=causal, window=window, q_chunk=chunk, kv_chunk=chunk,
        )
        ref = dense_attention_ref(q, k, v, causal, window)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)

    def test_kv_mask(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(1, 16, 2, 8)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, 16, 2, 8)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, 16, 2, 8)).astype(np.float32))
        mask = jnp.asarray(np.arange(16) < 8)[None]
        out = blockwise_attention(q, k, v, causal=False, kv_seq_mask=mask, q_chunk=8, kv_chunk=8)
        # identical to attending over the first 8 kv only
        ref = dense_attention_ref(
            np.asarray(q), np.asarray(k[:, :8]), np.asarray(v[:, :8]), causal=False
        )
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


class TestSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_matches_naive_recurrence(self, chunk):
        rng = np.random.default_rng(0)
        b, l, h, p, n = 2, 64, 3, 8, 16
        x = rng.normal(size=(b, l, h, p)).astype(np.float32)
        dt = rng.uniform(0.01, 0.2, size=(b, l, h)).astype(np.float32)
        A = -np.exp(rng.normal(size=(h,)).astype(np.float32))
        B = rng.normal(size=(b, l, h, n)).astype(np.float32)
        C = rng.normal(size=(b, l, h, n)).astype(np.float32)
        y, final = ssd_chunked(
            jnp.asarray(x * dt[..., None]), jnp.asarray(dt * A),
            jnp.asarray(B), jnp.asarray(C), chunk=chunk,
        )
        state = np.zeros((b, h, p, n))
        ys = []
        for t in range(l):
            state = state * np.exp(dt[:, t] * A)[..., None, None] + np.einsum(
                "bhp,bhn->bhpn", x[:, t] * dt[:, t][..., None], B[:, t]
            )
            ys.append(np.einsum("bhpn,bhn->bhp", state, C[:, t]))
        np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(final), state, rtol=1e-4, atol=1e-5)

    def test_initial_state_continuation(self):
        """Splitting a sequence across two ssd calls == one call (prefill
        chunking invariant)."""
        rng = np.random.default_rng(2)
        b, l, h, p, n = 1, 32, 2, 4, 8
        x = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.05, 0.2, size=(b, l, h)).astype(np.float32))
        A = -jnp.exp(jnp.asarray(rng.normal(size=(h,)).astype(np.float32)))
        B = jnp.asarray(rng.normal(size=(b, l, h, n)).astype(np.float32))
        C = jnp.asarray(rng.normal(size=(b, l, h, n)).astype(np.float32))
        xd, dA = x * dt[..., None], dt * A
        y_full, s_full = ssd_chunked(xd, dA, B, C, chunk=8)
        y1, s1 = ssd_chunked(xd[:, :16], dA[:, :16], B[:, :16], C[:, :16], chunk=8)
        y2, s2 = ssd_chunked(
            xd[:, 16:], dA[:, 16:], B[:, 16:], C[:, 16:], chunk=8, initial_state=s1
        )
        np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-4, atol=1e-5)


class TestDecodeConsistency:
    @pytest.mark.parametrize("name", ["llama3.2-1b", "mamba2-780m", "jamba-1.5-large-398b"])
    def test_decode_matches_forward(self, name):
        cfg = ARCHS[name].reduced()
        params = MDL.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(0)
        S = 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, S)), jnp.int32)
        batch = {"tokens": toks, "targets": toks, "sample_mask": jnp.ones((1,), bool)}
        x, _ = MDL._embed_inputs(cfg, params, batch)
        h, _ = MDL._trunk(cfg, params, x)
        full = np.asarray(MDL._logits(cfg, params, h))[0]
        cache = MDL.init_cache(cfg, 1, S)
        step = jax.jit(lambda p, c, t: MDL.decode_step(cfg, p, c, t))
        outs = []
        for t in range(S):
            lg, cache = step(params, cache, toks[:, t : t + 1])
            outs.append(np.asarray(lg[0, 0]))
        np.testing.assert_allclose(np.stack(outs), full, rtol=1e-3, atol=2e-4)


class TestMoE:
    def test_dispatch_slots_unique_and_bounded(self):
        rng = np.random.default_rng(0)
        e, cap = 4, 8
        ids = jnp.asarray(rng.integers(0, e, size=(24,)), jnp.int32)
        sort_idx, slots, keep = _dispatch_indices(ids, e, cap)
        slots = np.asarray(slots)[np.asarray(keep)]
        assert len(np.unique(slots)) == len(slots)  # no collisions among kept
        assert slots.max() < e * cap

    def test_capacity_overflow_dropped(self):
        ids = jnp.asarray(np.zeros(10, np.int32))  # all to expert 0
        _, _, keep = _dispatch_indices(ids, 4, 4)
        assert int(np.asarray(keep).sum()) == 4

    def test_moe_ffn_routes_all_tokens_at_high_capacity(self):
        """With capacity_factor high enough nothing is dropped; output must
        differ from zero for every token."""
        key = jax.random.PRNGKey(0)
        p = init_moe(key, 32, 64, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        out, aux = moe_ffn(p, x, top_k=2, capacity_factor=4.0)
        assert out.shape == x.shape
        assert np.all(np.abs(np.asarray(out)).sum(-1) > 0)
        assert float(aux) > 0.5  # load-balance loss near 1 for uniform-ish routing

    def test_moe_grad_flows_to_router(self):
        key = jax.random.PRNGKey(0)
        p = init_moe(key, 16, 32, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))

        def loss(p):
            out, aux = moe_ffn(p, x, top_k=2)
            return jnp.sum(out ** 2) + aux

        g = jax.grad(loss)(p)
        assert np.abs(np.asarray(g["router"])).sum() > 0
