"""Differential tests: the scan-fused device-resident mega-batch engine must
be numerically equivalent to the legacy per-round host loop (DESIGN.md §1) —
same per-mega-batch losses, same merged parameters — for every algorithm.

Also covers the engine plumbing: the scheduler's plan -> dense grid handoff
and the providers' whole-plan stacking.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import CostModel, SpeedModel
from repro.core.scheduler import DynamicScheduler
from repro.core.trainer import ElasticTrainer, _next_pow2
from repro.data.providers import SparseProvider, TokenProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model
from repro.optim.sgd import SGDConfig

ALGOS = ["adaptive", "elastic", "sync", "crossbow", "single"]


@pytest.fixture(scope="module")
def xml_data():
    full = make_xml_dataset(
        n_samples=1536, n_features=512, n_classes=64, avg_nnz=24, seed=0
    )
    return train_test_split(full, 0.15)


@pytest.fixture(scope="module")
def model():
    return make_model(XMLMLPConfig(n_features=512, n_classes=64, hidden=48))


def _run(engine, algo, xml_data, model, n_mega=3, momentum=0.0, seed=3):
    ds, _ = xml_data
    R = 1 if algo == "single" else 4
    prov = SparseProvider.make(ds, seed=seed)
    cfg = ElasticConfig.from_bmax(32, algorithm=algo, n_replicas=R, mega_batch=6)
    tr = ElasticTrainer(
        model, prov, cfg, base_lr=0.5, seed=seed, engine=engine,
        sgd=SGDConfig(momentum=momentum),
    )
    state = tr.init_state()
    infos = []
    for _ in range(n_mega):
        state, info = tr.run_megabatch(state)
        infos.append(info)
    return state, infos


def _assert_tree_close(a, b, **tol):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **tol)


@pytest.mark.parametrize("algo", ALGOS)
def test_scan_matches_legacy(algo, xml_data, model):
    """Same losses, same merged params, same replicas after N mega-batches."""
    st_l, inf_l = _run("legacy_loop", algo, xml_data, model)
    st_s, inf_s = _run("scan", algo, xml_data, model)
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_l],
        [i["train_loss"] for i in inf_s],
        rtol=2e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        [i["train_accuracy"] for i in inf_l],
        [i["train_accuracy"] for i in inf_s],
        rtol=2e-4, atol=1e-4,
    )
    assert [i["u"] for i in inf_l] == [i["u"] for i in inf_s]
    _assert_tree_close(st_l.replicas, st_s.replicas, rtol=1e-4, atol=1e-5)
    _assert_tree_close(st_l.global_model, st_s.global_model, rtol=1e-4, atol=1e-5)


def test_scan_matches_legacy_with_momentum(xml_data, model):
    """Momentum state threads through the scan carry identically."""
    st_l, inf_l = _run("legacy_loop", "adaptive", xml_data, model, momentum=0.9)
    st_s, inf_s = _run("scan", "adaptive", xml_data, model, momentum=0.9)
    np.testing.assert_allclose(
        [i["train_loss"] for i in inf_l],
        [i["train_loss"] for i in inf_s],
        rtol=2e-4, atol=1e-5,
    )
    _assert_tree_close(st_l.momentum, st_s.momentum, rtol=1e-4, atol=1e-5)
    _assert_tree_close(st_l.replicas, st_s.replicas, rtol=1e-4, atol=1e-5)


def test_round_bucketing_is_noop(xml_data, model):
    """Pow2 round padding (masked no-op rounds) must not change results."""
    ds, _ = xml_data
    prov = SparseProvider.make(ds, seed=5)
    cfg = ElasticConfig.from_bmax(32, algorithm="adaptive", n_replicas=4, mega_batch=5)
    outs = {}
    for bucket in (False, True):
        prov = SparseProvider.make(ds, seed=5)
        tr = ElasticTrainer(
            make_model(XMLMLPConfig(n_features=512, n_classes=64, hidden=48)),
            prov, cfg, base_lr=0.5, seed=5, engine="scan",
        )
        tr.round_bucket = bucket
        state = tr.init_state()
        state, info = tr.run_megabatch(state)
        outs[bucket] = (state, info)
    np.testing.assert_allclose(
        outs[False][1]["train_loss"], outs[True][1]["train_loss"],
        rtol=1e-5, atol=1e-6,
    )
    _assert_tree_close(
        outs[False][0].replicas, outs[True][0].replicas, rtol=1e-5, atol=1e-6
    )


def test_next_pow2():
    assert [_next_pow2(n) for n in (0, 1, 2, 3, 7, 8, 9)] == [1, 1, 2, 4, 8, 8, 16]


def test_payload_grid_handoff():
    """plan.payload_grid is dense, complete, and pads with masked rounds."""
    cfg = ElasticConfig(n_replicas=3, b_max=16, b_min=2)
    sched = DynamicScheduler(cfg, CostModel(SpeedModel(3, seed=1)))
    plan = sched.plan_megabatch(
        np.array([4, 4, 4]), 40, fetch_fn=lambda i, take: (("payload", i, take), take)
    )
    grid = plan.payload_grid(3)
    assert len(grid) == plan.n_rounds
    n_dispatched = sum(p is not None for row in grid for p in row)
    assert n_dispatched == len(plan.dispatches)
    padded = plan.payload_grid(3, min_rounds=plan.n_rounds + 3)
    assert len(padded) == plan.n_rounds + 3
    assert all(p is None for row in padded[plan.n_rounds:] for p in row)


def test_stack_plan_sparse(xml_data):
    """stack_plan == per-round stack of (payload or empty), for every round."""
    ds, _ = xml_data
    prov = SparseProvider.make(ds, seed=7)
    b_slots = 16
    grid = [
        [prov.fetch(8, b_slots), None, prov.fetch(16, b_slots)],
        [None, prov.fetch(3, b_slots), None],
    ]
    stacked, mask = prov.stack_plan(grid, b_slots)
    np.testing.assert_array_equal(mask, [[1, 0, 1], [0, 1, 0]])
    for r, row in enumerate(grid):
        per_round = prov.stack([p if p is not None else prov.empty(b_slots) for p in row])
        for k, v in per_round.items():
            np.testing.assert_array_equal(stacked[k][r], v)


def test_stack_plan_tokens():
    prov = TokenProvider.make(vocab_size=64, seq_len=12, seed=0)
    b_slots = 8
    grid = [[prov.fetch(8, b_slots), None], [None, prov.fetch(4, b_slots)]]
    stacked, mask = prov.stack_plan(grid, b_slots)
    np.testing.assert_array_equal(mask, [[1, 0], [0, 1]])
    assert stacked["tokens"].shape == (2, 2, b_slots, 12)
    for r, row in enumerate(grid):
        per_round = prov.stack([p if p is not None else prov.empty(b_slots) for p in row])
        for k, v in per_round.items():
            np.testing.assert_array_equal(stacked[k][r], v)


def test_token_provider_scan_engine():
    """The scan engine runs the LM workload end-to-end (token provider)."""
    from repro.configs.base import ModelConfig
    from repro.models import model as MDL

    cfg = ModelConfig(
        name="tiny-test", arch_type="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
    )
    model = MDL.make_model(cfg)
    prov = TokenProvider.make(cfg.vocab_size, 16, seed=0)
    ecfg = ElasticConfig.from_bmax(8, algorithm="adaptive", n_replicas=2, mega_batch=3)
    tr = ElasticTrainer(model, prov, ecfg, base_lr=0.1, seed=0, engine="scan")
    state = tr.init_state()
    state, info = tr.run_megabatch(state)
    assert np.isfinite(info["train_loss"])
