"""Fault injection, targeted eviction, quarantine, and the non-finite
guard (DESIGN.md §7)."""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleet import (
    FaultEvent,
    FaultInjector,
    FleetController,
    parse_fault_spec,
)
from repro.utils import tree as tu

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
from generate import build_case_trainer, make_case_dataset  # noqa: E402


def leaves_np(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]


def tree_finite(tree) -> bool:
    return all(np.isfinite(l).all() for l in leaves_np(tree))


def poison_row(state, slot):
    import dataclasses

    return dataclasses.replace(
        state,
        replicas=tu.tree_map(
            lambda l: l.at[slot].set(jnp.asarray(jnp.nan, l.dtype)),
            state.replicas,
        ),
    )


# --------------------------------------------------------------------------
# spec parsing + injector determinism
# --------------------------------------------------------------------------


def test_parse_fault_spec():
    inj = parse_fault_spec("seed=7,p_crash=0.25,3:crash:1,5:join,7:nan:0,9:stall:2:4")
    assert inj.seed == 7 and inj.p_crash == 0.25
    assert inj.schedule[3] == (FaultEvent("crash", 1),)
    assert inj.schedule[5][0].kind == "join"
    assert inj.schedule[5][0].replica is None
    assert inj.schedule[9][0].duration == 4


@pytest.mark.parametrize("bad", [
    "p_bogus=1", "x:crash", "3:meteor", "-1:crash:0", "3", "3:crash:0:0",
])
def test_parse_fault_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_fault_spec(bad)


def test_injector_deterministic_and_history_free():
    inj = FaultInjector(seed=3, p_crash=0.5, p_join=0.5)
    seq = [tuple((e.kind, e.replica) for e in inj.events_for(mb, 4))
           for mb in range(20)]
    # same injector, replayed: identical (no draw-history dependence)
    again = [tuple((e.kind, e.replica) for e in inj.events_for(mb, 4))
             for mb in range(20)]
    assert seq == again
    # querying out of order must not change any event
    shuffled = {mb: tuple((e.kind, e.replica) for e in inj.events_for(mb, 4))
                for mb in reversed(range(20))}
    assert [shuffled[mb] for mb in range(20)] == seq
    assert any(seq)  # p=0.5 over 20 boundaries: events actually fire


def test_injector_schedule_and_rates_compose():
    inj = FaultInjector(seed=0, p_crash=1.0,
                        schedule={2: (FaultEvent("join"),)})
    kinds = [e.kind for e in inj.events_for(2, 4)]
    assert kinds[0] == "join" and "crash" in kinds


# --------------------------------------------------------------------------
# targeted eviction: remove_replicas permutation semantics
# --------------------------------------------------------------------------


def test_remove_replicas_permutes_per_replica_state():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    state = tr.init_state()
    state.b[:] = [10.0, 20.0, 30.0, 40.0]
    state.lr[:] = [0.1, 0.2, 0.3, 0.4]
    tr.speed.factors[:] = [1.0, 1.1, 1.2, 1.3]
    tr.scheduler.clock.t[:] = [5.0, 6.0, 7.0, 8.0]

    state = tr.remove_replicas(state, [1], merge_leavers=True)

    assert tr.cfg.n_replicas == 3
    np.testing.assert_array_equal(state.b, [10.0, 30.0, 40.0])
    np.testing.assert_array_equal(state.lr, [0.1, 0.3, 0.4])
    # factors renormalize to fastest==1.0 after the shrink (resize contract)
    np.testing.assert_allclose(tr.speed.factors, [1.0, 1.2, 1.3])
    np.testing.assert_array_equal(tr.scheduler.clock.t, [5.0, 7.0, 8.0])
    assert all(l.shape[0] == 3 for l in leaves_np(state.replicas))


def test_remove_replicas_validates():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    state = tr.init_state()
    with pytest.raises(ValueError, match="out of range"):
        tr.remove_replicas(state, [7])
    with pytest.raises(ValueError, match="all"):
        tr.remove_replicas(state, [0, 1, 2, 3])
    assert tr.remove_replicas(state, []) is state


def test_remove_replicas_excludes_crashed_from_merge():
    """merge_leavers=False: a NaN-poisoned leaver must not touch the merged
    global (its Alg.-2 weight is redistributed; rows zeroed before the sum)."""
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    state = poison_row(state, 2)
    state = tr.remove_replicas(state, [2], merge_leavers=False)
    assert tr.cfg.n_replicas == 3
    assert tree_finite(state.replicas)
    assert tree_finite(state.global_model)


def test_remove_replicas_graceful_matches_tail_resize():
    """Evicting the tail slot with merge is exactly resize(R-1)."""
    ds = make_case_dataset()
    t1 = build_case_trainer("adaptive", "scan", True, ds)
    s1 = t1.init_state()
    s1, _ = t1.run_megabatch(s1)
    t2 = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    s2 = t2.init_state()
    s2, _ = t2.run_megabatch(s2)

    a = t1.remove_replicas(s1, [3], merge_leavers=True)
    b = t2.resize(s2, 3)
    for x, y in zip(leaves_np(a.replicas), leaves_np(b.replicas)):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------
# non-finite guard (trainer.guard_nonfinite)
# --------------------------------------------------------------------------


def test_guard_heals_poisoned_replica_and_merge_stays_close():
    ds = make_case_dataset()
    clean = build_case_trainer("adaptive", "scan", True, ds)
    c_state = clean.init_state()
    c_state, _ = clean.run_megabatch(c_state)

    faulty = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    f_state = faulty.init_state()
    f_state = poison_row(f_state, 1)
    f_state, info = faulty.run_megabatch(f_state)

    assert info["guard_repaired"] == [1]
    assert tree_finite(f_state.replicas)
    assert tree_finite(f_state.global_model)
    # acceptance: within tolerance of the fault-free run (one replica's
    # contribution was redistributed, not lost wholesale) — whole-tree
    # relative l2, so tiny bias leaves don't dominate the metric
    num = den = 0.0
    for a, b in zip(
        leaves_np(c_state.global_model), leaves_np(f_state.global_model)
    ):
        a64, b64 = a.astype(np.float64), b.astype(np.float64)
        num += float(np.sum((a64 - b64) ** 2))
        den += float(np.sum(a64**2))
    assert (num / max(den, 1e-18)) ** 0.5 < 0.05


def test_guard_is_inert_on_finite_runs():
    """Detection is read-only: guard on vs off is bit-identical."""
    ds = make_case_dataset()
    on = build_case_trainer("adaptive", "scan", True, ds)
    off = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    off.guard_nonfinite = False
    s_on, s_off = on.init_state(), off.init_state()
    for _ in range(2):
        s_on, i_on = on.run_megabatch(s_on)
        s_off, i_off = off.run_megabatch(s_off)
    assert "guard_repaired" not in i_on
    assert i_on["train_loss"] == i_off["train_loss"]
    for a, b in zip(leaves_np(s_on.global_model), leaves_np(s_off.global_model)):
        np.testing.assert_array_equal(a, b)


def test_guard_full_divergence_recovers_from_global():
    """The sync family spreads one NaN to every replica within a mega-batch
    (cross-replica gradient averaging); with a global copy on hand the
    whole population restarts from the last barrier."""
    tr = build_case_trainer("elastic", "scan", True, make_case_dataset())
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    for slot in range(4):
        state = poison_row(state, slot)
    state, info = tr.run_megabatch(state)
    assert info["guard_repaired"] == [0, 1, 2, 3]
    assert tree_finite(state.replicas)
    assert tree_finite(state.global_model)


def test_guard_full_divergence_without_global_raises():
    tr = build_case_trainer("sync", "scan", True, make_case_dataset())
    state = tr.init_state()  # sync keeps no global copy at init
    for slot in range(4):
        state = poison_row(state, slot)
    with pytest.raises(FloatingPointError, match="no global model"):
        tr.run_megabatch(state)


def test_nan_never_contaminates_merge_under_sync_gradient_crosstalk():
    """One poisoned replica under sync: the guard's donor is the last
    barrier global (state carries one from mega-batch 1 on)."""
    tr = build_case_trainer("sync", "scan", True, make_case_dataset())
    state = tr.init_state()
    state, _ = tr.run_megabatch(state)
    state = poison_row(state, 0)
    state, info = tr.run_megabatch(state)
    assert info.get("guard_repaired")  # crosstalk poisons every row
    assert tree_finite(state.replicas)
    assert tree_finite(state.global_model)


# --------------------------------------------------------------------------
# FleetController end-to-end
# --------------------------------------------------------------------------


def test_controller_crash_join_nan_converges():
    """The chaos scenario: crash + rejoin + join + NaN over a short run,
    driven through ElasticTrainer.run(fleet=...)."""
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    fleet = FleetController(
        injector=parse_fault_spec("1:nan:0,2:crash:1,4:join"),
        min_replicas=2, max_replicas=6, backoff=2,
    )
    state, mlog = tr.run(6, fleet=fleet)
    actions = [(e["mb"], e["action"]) for e in fleet.events]
    assert (1, "nan") in actions
    assert (2, "evict") in actions
    assert (4, "join") in actions
    assert (4, "rejoin") in actions  # crash at 2, backoff 2 -> due at 4
    assert tr.cfg.n_replicas == 5  # 4 - 1 + rejoin + join
    assert tree_finite(state.global_model)
    assert mlog.records[1].get("guard_repaired") == [0]
    # training still converges through the churn
    assert mlog.records[-1]["train_loss"] < mlog.records[0]["train_loss"]


def test_controller_respects_min_and_max():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    fleet = FleetController(
        injector=parse_fault_spec("0:crash:0,1:crash:0,2:crash:0,3:join,4:join"),
        min_replicas=2, max_replicas=4, backoff=16,
    )
    tr.run(6, fleet=fleet)
    skipped = [e for e in fleet.events if e["action"] == "crash_skipped"]
    assert any(e["reason"] == "at min_replicas" for e in skipped)
    assert 2 <= tr.cfg.n_replicas <= 4


def test_quarantine_backoff_escalates_for_flapping_worker():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    # crash at 1 (level 0, rejoin_in 2 -> rejoin at 3); crash again at 4,
    # inside the probation window of that readmission -> level 1, delay 4
    fleet = FleetController(
        injector=parse_fault_spec("1:crash:0,4:crash:0"),
        min_replicas=2, max_replicas=4, backoff=2, probation=4,
    )
    tr.run(9, fleet=fleet)
    evicts = [e for e in fleet.events if e["action"] == "evict"]
    assert [e["level"] for e in evicts] == [0, 1]
    assert [e["rejoin_in"] for e in evicts] == [2, 4]
    rejoins = [e["mb"] for e in fleet.events if e["action"] == "rejoin"]
    assert rejoins == [3, 8]


def test_stall_and_timeout_eviction():
    """A stalled replica blows the timeout factor and gets a graceful
    (preemption-style) eviction by the health detector."""
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    fleet = FleetController(
        injector=parse_fault_spec("1:stall:2:3"),
        min_replicas=2, max_replicas=4, timeout_factor=3.0,
    )
    tr.run(4, fleet=fleet)
    actions = [e["action"] for e in fleet.events]
    assert "stall" in actions
    evicts = [e for e in fleet.events if e["action"] == "evict"]
    assert evicts and evicts[0]["reason"] == "timeout"
    assert evicts[0]["graceful"] is True


def test_preempt_auto_rejoins_after_notice():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    fleet = FleetController(
        injector=parse_fault_spec("1:preempt:0:2"),
        min_replicas=2, max_replicas=4,
    )
    tr.run(5, fleet=fleet)
    evicts = [e for e in fleet.events if e["action"] == "evict"]
    assert evicts[0]["reason"] == "preempt" and evicts[0]["graceful"] is True
    rejoins = [e["mb"] for e in fleet.events if e["action"] == "rejoin"]
    assert rejoins == [3]


# --------------------------------------------------------------------------
# resize-schedule validation (fails at launch, not mid-run)
# --------------------------------------------------------------------------


def test_resize_schedule_validation_rejects_bad_schedules():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    with pytest.raises(ValueError, match="negative"):
        tr.run(2, resize_schedule={-1: 4})
    with pytest.raises(ValueError, match="twice"):
        tr.run(2, resize_schedule={"3": 4, 3: 6})
    with pytest.raises(ValueError, match="targets 0"):
        tr.run(2, resize_schedule={40: 0})
    with pytest.raises(ValueError, match="not.*integer"):
        tr.run(2, resize_schedule={1.5: 4})

    tr.algo.resize_policy = "fixed"  # instance shadow: simulate a pinned algo
    with pytest.raises(ValueError, match="fixed"):
        tr.run(2, resize_schedule={40: 2})


def test_resize_schedule_validation_accepts_good_schedule():
    tr = build_case_trainer("adaptive", "scan", True, make_case_dataset())
    norm = tr._validate_resize_schedule({"0": 4, 2: 3})
    assert norm == {0: 4, 2: 3}
