"""Benchmark regression gate (CI bench job).

Compares freshly produced ``BENCH_*.json`` headline metrics against the
committed baselines and fails on a regression larger than the tolerance —
bench artifacts have been uploaded since PR 1, but nothing ever *read*
them, so a change could silently halve a speedup and still merge green.

Headline metrics per benchmark (higher is better unless noted):

* ``BENCH_engine.json``      — every entry of ``speedup_steps_per_s``
  (scan-vs-legacy engine and end-to-end speedups per replica count) and
  of ``overlap_gain`` (overlapped pipeline vs sequential oracle,
  DESIGN.md §8)
* ``BENCH_spmm_grad.json``   — every entry of ``speedup_sparse_over_dense``
* ``BENCH_algorithms.json``  — per-algorithm ``tta`` (time-to-accuracy,
  LOWER is better; a fresh run that no longer reaches the target where the
  baseline did is an automatic failure), ``best_acc``, and the faults
  scenario's ``recovery_overhead`` (faulty TTA / clean TTA, LOWER is
  better, DESIGN.md §7)

Baselines default to ``git show HEAD:<file>`` so the gate needs no extra
artifact plumbing: the bench job regenerates the jsons in the workspace and
this script diffs them against the committed versions. ``--baseline-dir``
points at saved copies instead (e.g. when comparing two fresh runs).

Whether or not the gate trips, a per-metric drift table (baseline vs fresh
value, signed drift) is printed for every benchmark so CI logs show the
metric trajectories over time, not only the failures.

Exit code 0 = within tolerance, 1 = regression, 2 = usage/data error.

    python scripts/bench_check.py                  # all benchmarks, 25%
    python scripts/bench_check.py --tolerance 0.1 BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

DEFAULT_TOLERANCE = 0.25
BENCH_FILES = ("BENCH_engine.json", "BENCH_spmm_grad.json",
               "BENCH_algorithms.json")


def headline_metrics(name: str, data: dict) -> dict[str, tuple[float | None, bool]]:
    """{metric: (value, higher_is_better)} for one benchmark file."""
    out: dict[str, tuple[float | None, bool]] = {}
    if name == "BENCH_engine.json":
        for k, v in data.get("speedup_steps_per_s", {}).items():
            out[f"speedup_steps_per_s/{k}"] = (float(v), True)
        # overlap pipeline gain (DESIGN.md §8): scan overlap-on vs
        # overlap-off end-to-end throughput, per replica count
        for k, v in data.get("overlap_gain", {}).items():
            out[f"overlap_gain/{k}"] = (float(v), True)
    elif name == "BENCH_spmm_grad.json":
        for k, v in data.get("speedup_sparse_over_dense", {}).items():
            out[f"speedup_sparse_over_dense/{k}"] = (float(v), True)
    elif name == "BENCH_algorithms.json":
        if data.get("elastic_schedule"):
            # churn runs are a different experiment: their TTA/accuracy is
            # not comparable to the fixed-membership baseline this gate
            # protects (benchmarks/algorithms.py writes them to
            # BENCH_algorithms_elastic.json by default)
            raise KeyError(
                f"{name} was produced with an elastic schedule "
                f"({data['elastic_schedule']}) — the regression gate only "
                "compares fixed-membership runs; regenerate without "
                "--elastic-schedule"
            )
        for row in data.get("rows", []):
            algo = row["algorithm"]
            # per-metric presence checks: a baseline written before a metric
            # existed (first run of a new benchmark column) simply lacks the
            # key — that is "no baseline yet", not a data error
            if "tta" in row:
                tta = row["tta"]
                out[f"tta/{algo}"] = (
                    None if tta is None else float(tta), False
                )
            if "best_acc" in row:
                out[f"best_acc/{algo}"] = (float(row["best_acc"]), True)
        if data.get("faults"):
            # fault-recovery scenario (DESIGN.md §7): faulty TTA / clean
            # TTA under the seeded fault script — LOWER is better, and a
            # fresh run whose faulty trajectory no longer reaches the
            # target (recovery_overhead null) fails like a lost tta
            ro = data["faults"].get("recovery_overhead")
            out["faults/recovery_overhead"] = (
                None if ro is None else float(ro), False
            )
    else:
        raise KeyError(f"no headline extraction defined for {name}")
    return out


def load_baseline(name: str, baseline_dir: str | None, repo_root: str) -> dict:
    if baseline_dir:
        with open(os.path.join(baseline_dir, name)) as f:
            return json.load(f)
    blob = subprocess.run(
        ["git", "show", f"HEAD:{name}"], capture_output=True, text=True,
        cwd=repo_root, check=True,
    ).stdout
    return json.loads(blob)


def _fmt(v: float | None) -> str:
    return "never" if v is None else f"{v:.4g}"


def check_file(name: str, fresh: dict, base: dict,
               tolerance: float) -> tuple[list[str], list[str]]:
    """Returns ``(regression messages, per-metric drift table lines)``.

    The table covers *every* headline metric — it is printed on pass as
    well as on fail, so CI logs show the metric trajectories instead of
    only surfacing them once a run trips the tolerance. Metrics present in
    the fresh run but absent from the baseline (the first run of a new
    benchmark) are informational NEW rows: they gate nothing now and become
    the baseline once committed.
    """
    fresh_m = headline_metrics(name, fresh)
    base_m = headline_metrics(name, base)
    if not base_m:
        # a renamed/absent headline key must not disable the gate silently
        return ([f"{name}: baseline contains no headline metrics — "
                 "benchmark output schema changed? update headline_metrics()"],
                [])
    failures, table = [], []
    width = max(len(k) for k in (*base_m, *fresh_m))
    for key, (b_val, higher_better) in sorted(base_m.items()):
        f_val = fresh_m[key][0] if key in fresh_m else None
        drift = "n/a"
        if b_val is not None and f_val is not None and b_val != 0:
            rel = (f_val - b_val) / b_val
            drift = f"{rel:+.1%}"
        status = "ok"
        if key not in fresh_m:
            failures.append(f"{name}:{key} missing from the fresh run")
            status = "MISSING"
        elif b_val is None:
            pass                        # baseline never reached the target
        elif f_val is None:
            failures.append(
                f"{name}:{key} baseline={b_val:.4g} but the fresh run never "
                "reached the target"
            )
            status = "REGRESSED"
        elif higher_better:
            floor = b_val * (1.0 - tolerance)
            if f_val < floor:
                failures.append(
                    f"{name}:{key} regressed: {f_val:.4g} < {floor:.4g} "
                    f"(baseline {b_val:.4g}, tolerance {tolerance:.0%})"
                )
                status = "REGRESSED"
        else:
            ceil = b_val * (1.0 + tolerance)
            if f_val > ceil:
                failures.append(
                    f"{name}:{key} regressed: {f_val:.4g} > {ceil:.4g} "
                    f"(baseline {b_val:.4g}, tolerance {tolerance:.0%})"
                )
                status = "REGRESSED"
        arrow = "higher=better" if higher_better else "lower=better"
        table.append(
            f"  {key:<{width}}  baseline={_fmt(b_val):>8}  "
            f"fresh={_fmt(f_val):>8}  drift={drift:>7}  [{arrow}] {status}"
        )
    for key in sorted(set(fresh_m) - set(base_m)):
        f_val, higher_better = fresh_m[key]
        arrow = "higher=better" if higher_better else "lower=better"
        table.append(
            f"  {key:<{width}}  baseline={'--':>8}  "
            f"fresh={_fmt(f_val):>8}  drift={'n/a':>7}  [{arrow}] NEW"
        )
    return failures, table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=[],
                    help=f"benchmark jsons to gate (default: {BENCH_FILES})")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed relative regression (default 0.25 = 25%%)")
    ap.add_argument("--baseline-dir", default=None,
                    help="directory with baseline jsons (default: read the "
                         "committed versions via `git show HEAD:<file>`)")
    args = ap.parse_args(argv)

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = args.files or [os.path.join(repo_root, f) for f in BENCH_FILES]

    failures: list[str] = []
    checked: list[tuple[str, int]] = []  # (family, n metrics) per file
    for path in files:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                fresh = json.load(f)
            base = load_baseline(name, args.baseline_dir, repo_root)
        except (OSError, subprocess.CalledProcessError, json.JSONDecodeError) as e:
            print(f"bench_check: cannot load {name}: {e}", file=sys.stderr)
            return 2
        try:
            msgs, table = check_file(name, fresh, base, args.tolerance)
        except KeyError as e:
            print(f"bench_check: {e.args[0]}", file=sys.stderr)
            return 2
        status = "FAIL" if msgs else "ok"
        n = len(headline_metrics(name, base))
        print(f"[bench_check] {name}: {n} headline metrics — {status}")
        for line in table:      # drift trajectory, printed on pass AND fail
            print(line)
        failures.extend(msgs)
        checked.append((name.removeprefix("BENCH_").removesuffix(".json"), n))

    # one greppable line naming every benchmark family this run gated — a
    # file list that silently shrank must be visible in the log, not lore
    print("[bench_check] families checked: "
          + ", ".join(f"{fam} ({n} metrics)" for fam, n in checked))
    for msg in failures:
        print(f"[bench_check] REGRESSION {msg}", file=sys.stderr)
    if failures:
        print(f"[bench_check] {len(failures)} regression(s) beyond "
              f"{args.tolerance:.0%}", file=sys.stderr)
        return 1
    print("[bench_check] all headline metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
