"""Spawn an N-process multi-host fleet on one machine (DESIGN.md §10).

Launches N copies of ``python -m repro.launch.train`` with the
``REPRO_MH_*`` bootstrap environment (process id, fleet count, shared
fleet dir) and ``--xla_force_host_platform_device_count=K`` so each
process sees K virtual CPU devices. The processes rendezvous through the
fleet dir's heartbeat leases and exchange merge/metrics partials through
its file exchange — a real multi-process elastic fleet, no injector.

Exit status is 0 iff every process that was not deliberately killed
exited 0. Per-process output is teed to ``<fleet-dir>/logs/proc<i>.log``
and tails are printed on completion.

Fault drill: ``--kill-proc I --kill-after-mb M`` SIGKILLs process I once
its lease reports mega-batch >= M (the lease's ``megabatch`` field is
renewed by the FleetController each boundary, so the kill lands mid-run,
deterministically after M completed mega-batches). Survivors must detect
the missed heartbeat deadline, evict process I's replicas, and finish.

Example (2 processes x 2 replicas each, global R=4):
  PYTHONPATH=src python scripts/multihost_launch.py \
      --procs 2 --devices-per-proc 2 -- \
      --workload xml --placement sharded --replicas 4 --megabatches 5
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time


def _read_megabatch(leases_dir: str, pid: int) -> int:
    path = os.path.join(leases_dir, f"proc-{pid}.json")
    try:
        with open(path) as f:
            payload = json.load(f)
        return int(payload.get("megabatch") or 0)
    except (OSError, ValueError):
        return -1


def _tail(path: str, lines: int = 15) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-lines:])
    except OSError:
        return "<no log>"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--procs", type=int, default=2,
                    help="number of trainer processes to spawn")
    ap.add_argument("--devices-per-proc", type=int, default=2,
                    help="virtual CPU devices per process (XLA host"
                         " platform device count)")
    ap.add_argument("--fleet-dir", default="",
                    help="shared rendezvous/exchange dir (default: a fresh"
                         " mktemp dir, left on disk for post-mortem)")
    ap.add_argument("--kill-proc", type=int, default=-1,
                    help="SIGKILL this process id mid-run (heartbeat drill)")
    ap.add_argument("--kill-after-mb", type=int, default=2,
                    help="kill once the target's lease reports >= this"
                         " many completed mega-batches")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="overall wall-clock budget (seconds)")
    ap.add_argument("train_args", nargs=argparse.REMAINDER,
                    help="arguments after '--' go to repro.launch.train")
    args = ap.parse_args(argv)

    train_args = args.train_args
    if train_args and train_args[0] == "--":
        train_args = train_args[1:]
    if args.procs < 1:
        ap.error("--procs must be >= 1")
    if args.kill_proc >= args.procs:
        ap.error("--kill-proc out of range")

    fleet_dir = args.fleet_dir or tempfile.mkdtemp(prefix="repro-fleet-")
    logs_dir = os.path.join(fleet_dir, "logs")
    leases_dir = os.path.join(fleet_dir, "leases")
    os.makedirs(logs_dir, exist_ok=True)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base_env = dict(os.environ)
    base_env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), base_env.get("PYTHONPATH", "")]
    )
    base_env["REPRO_MH_NUM_PROCESSES"] = str(args.procs)
    base_env["REPRO_MH_FLEET_DIR"] = fleet_dir
    xla = base_env.get("XLA_FLAGS", "")
    base_env["XLA_FLAGS"] = (
        f"{xla} --xla_force_host_platform_device_count="
        f"{args.devices_per_proc}"
    ).strip()
    base_env.setdefault("JAX_PLATFORMS", "cpu")

    procs: list[subprocess.Popen] = []
    logs: list[str] = []
    for pid in range(args.procs):
        env = dict(base_env)
        env["REPRO_MH_PROCESS_ID"] = str(pid)
        log_path = os.path.join(logs_dir, f"proc{pid}.log")
        logs.append(log_path)
        log_f = open(log_path, "w")
        procs.append(subprocess.Popen(
            [sys.executable, "-u", "-m", "repro.launch.train"] + train_args,
            env=env, stdout=log_f, stderr=subprocess.STDOUT,
        ))
    print(f"[multihost-launch] {args.procs} processes, fleet_dir={fleet_dir}",
          flush=True)

    deadline = time.monotonic() + args.timeout
    killed = False
    timed_out = False
    while True:
        alive = [p for p in procs if p.poll() is None]
        if not alive:
            break
        if time.monotonic() > deadline:
            timed_out = True
            for p in alive:
                p.kill()
            break
        if (args.kill_proc >= 0 and not killed
                and procs[args.kill_proc].poll() is None
                and _read_megabatch(leases_dir, args.kill_proc)
                >= args.kill_after_mb):
            print(f"[multihost-launch] SIGKILL proc {args.kill_proc} "
                  f"(lease mb >= {args.kill_after_mb})", flush=True)
            procs[args.kill_proc].send_signal(signal.SIGKILL)
            killed = True
        time.sleep(0.1)

    failed = False
    for pid, p in enumerate(procs):
        rc = p.wait()
        deliberate = killed and pid == args.kill_proc
        status = "killed" if deliberate else f"rc={rc}"
        print(f"[multihost-launch] proc {pid}: {status}", flush=True)
        if not deliberate and rc != 0:
            failed = True
    if timed_out:
        print(f"[multihost-launch] TIMEOUT after {args.timeout}s", flush=True)
        failed = True
    if args.kill_proc >= 0 and not killed:
        print("[multihost-launch] kill never triggered (target exited or"
              " lease stalled before --kill-after-mb)", flush=True)
        failed = True
    for pid, path in enumerate(logs):
        print(f"--- proc {pid} tail ({path}) ---\n{_tail(path)}", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
