"""One-shot report over everything under results/: dry-run coverage,
roofline headline, and §Perf before/after deltas.

  PYTHONPATH=src python scripts/summarize_results.py
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import roofline_terms  # noqa: E402


def main():
    dr = sorted(glob.glob("results/dryrun/*.json"))
    by_mesh = {}
    for p in dr:
        mesh = p.rsplit("__", 1)[1].split(".")[0]
        by_mesh[mesh] = by_mesh.get(mesh, 0) + 1
    print(f"dry-run artifacts: {len(dr)} ({by_mesh}) — expected 80 (40+40)")

    rows = [roofline_terms(json.load(open(p))) for p in dr
            if "singlepod" in p]
    bn = {}
    for r in rows:
        bn[r["bottleneck"]] = bn.get(r["bottleneck"], 0) + 1
    worst = min(rows, key=lambda r: r["useful_ratio"])
    most_coll = max(rows, key=lambda r: r["t_collective_s"])
    print(f"roofline (singlepod): bottleneck split {bn}")
    print(f"  worst useful-ratio : {worst['arch']} x {worst['shape']} "
          f"({worst['useful_ratio']:.3f})")
    print(f"  most collective    : {most_coll['arch']} x {most_coll['shape']} "
          f"({most_coll['t_collective_s']:.1f} s/step)")

    print("\nperf experiments (results/perf):")
    for p in sorted(glob.glob("results/perf/*.json")):
        rec = json.load(open(p))
        tag = os.path.basename(p).replace(".json", "")
        base_tag = tag.split("__")
        base_path = os.path.join("results/dryrun",
                                 "__".join(base_tag[:3]) + ".json")
        step_name = rec["mode"] if rec["mode"] != "train" else "train"
        step = rec["steps"][step_name]
        line = (f"  {tag}: coll={sum(step['collectives']['bytes'].values()):.3e} "
                f"hbm={step['hbm_bytes']:.3e} flops={step['flops']:.3e}")
        if os.path.exists(base_path):
            b = json.load(open(base_path))["steps"][step_name]
            bc = sum(b["collectives"]["bytes"].values())
            oc = sum(step["collectives"]["bytes"].values())
            if oc > 0:
                line += f"  [coll x{bc / oc:.2f} vs baseline]"
        print(line)


if __name__ == "__main__":
    main()
