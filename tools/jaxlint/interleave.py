"""InterleaveSentinel: deterministic exploration of thread interleavings.

Runtime half of the concurrency family (DESIGN.md §11), in the spirit of
:class:`tools.jaxlint.sentinel.RetraceSentinel`: where the static rules
prove lock *discipline*, this sentinel explores lock *schedules*. It is a
cooperative scheduler over real ``threading`` threads — at any moment at
most one managed thread runs; at every yield point it parks itself and a
seeded RNG picks the next runnable thread. Same seed → same schedule →
same outcome, so a race is a reproducible failing test instead of an OS
scheduling coincidence.

Yield points (all recorded in :attr:`InterleaveSentinel.schedule`):

* every ``line`` event in modules matching the ``trace`` patterns
  (installed per-thread via ``sys.settrace`` — line granularity, so a
  check-then-act window of two source lines is a real interleaving point);
* every operation on sentinel-provided primitives (:meth:`lock`,
  :meth:`event`) — their blocking operations park the thread *cooperatively*
  so the scheduler keeps control (replace a unit's ``threading.Lock`` with
  ``sentinel.lock()`` before running);
* explicit :meth:`yield_point` calls in test bodies.

Cautions: a managed thread must not block on a *real* primitive while
traced (the scheduler would time out — swap locks for sentinel locks), and
a sentinel event's *timed* wait returns immediately (virtual time: the
timeout is deemed elapsed) so renewal-style loops terminate.

Stdlib-only and jax-free: importable from the lint job and from tier-1
tests alike.
"""
from __future__ import annotations

import os
import random
import sys
import threading
from typing import Any, Callable, Optional

__all__ = [
    "InterleaveError",
    "InterleaveSentinel",
    "SentinelEvent",
    "SentinelLock",
]


class InterleaveError(AssertionError):
    """Deadlock, schedule-budget exhaustion, or scheduler timeout."""


class _Abort(BaseException):
    """Internal: unwind managed threads after a scheduler abort (derives
    from BaseException so user ``except Exception`` blocks can't eat it)."""


class SentinelLock:
    """Cooperative mutex: blocking acquire parks the thread in the
    scheduler instead of the OS. State mutations are race-free because
    only one managed thread ever runs at a time."""

    def __init__(self, sentinel: "InterleaveSentinel", name: str):
        self._s = sentinel
        self.name = name
        self._owner: Optional[str] = None

    def acquire(self) -> bool:
        self._s._op(("lock", self.name, "acquire"))
        while self._owner is not None:
            self._s._block(self)
        self._owner = self._s._current_name()
        return True

    def release(self) -> None:
        me = self._s._current_name()
        if self._owner != me:
            raise InterleaveError(
                f"lock {self.name!r} released by {me!r} but held by "
                f"{self._owner!r}"
            )
        self._owner = None
        self._s._wake_waiters(self)
        self._s._op(("lock", self.name, "release"))

    def locked(self) -> bool:
        return self._owner is not None

    def __enter__(self) -> "SentinelLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SentinelEvent:
    """Cooperative event. ``wait(timeout)`` with a timeout never parks:
    sentinel time is virtual, so the timeout is deemed to have elapsed —
    this is what lets ``Event.wait(interval)``-paced renewal loops make
    progress under the scheduler."""

    def __init__(self, sentinel: "InterleaveSentinel", name: str):
        self._s = sentinel
        self.name = name
        self._flag = False

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        self._flag = True
        self._s._wake_waiters(self)
        self._s._op(("event", self.name, "set"))

    def clear(self) -> None:
        self._flag = False

    def wait(self, timeout: Optional[float] = None) -> bool:
        self._s._op(("event", self.name, "wait"))
        if timeout is not None:
            return self._flag
        while not self._flag:
            self._s._block(self)
        return True


class InterleaveSentinel:
    """Seeded, deterministic scheduler for a set of spawned thread bodies.

    Usage::

        sent = InterleaveSentinel(seed=3, trace=("repro/core/fleet.py",))
        unit._lock = sent.lock("unit")       # swap in cooperative lock
        sent.spawn("announce", unit.announce, "leaving")
        sent.spawn("daemon", unit.renew)
        sent.run()                           # raises on deadlock/thread error
        assert <post-state invariant>

    ``run`` replays identically for a given (seed, bodies) pair; iterate
    seeds to explore distinct interleavings. ``schedule`` records every
    context switch as ``(thread, kind, detail...)`` tuples.
    """

    def __init__(self, seed: int = 0, trace: tuple[str, ...] = (),
                 max_switches: int = 50_000):
        self.seed = int(seed)
        self.trace_patterns = tuple(
            p.replace(os.sep, "/") for p in trace
        )
        self.max_switches = int(max_switches)
        self.schedule: list[tuple] = []
        self.results: dict[str, Any] = {}
        self._rng = random.Random(self.seed)
        self._cond = threading.Condition()
        self._recs: dict[str, dict] = {}
        self._order: list[str] = []
        self._current: Optional[str] = None
        self._abort: Optional[str] = None
        self._ran = False

    # -- test-facing API ----------------------------------------------------

    def spawn(self, name: str, fn: Callable, *args, **kwargs) -> None:
        """Register a thread body; all bodies start when :meth:`run` runs."""
        if self._ran:
            raise InterleaveError("spawn() after run(): make a new sentinel")
        if name in self._recs:
            raise InterleaveError(f"duplicate thread name {name!r}")
        self._recs[name] = {
            "fn": fn, "args": args, "kwargs": kwargs,
            "state": "new", "blocker": None, "error": None,
            "thread": None,
        }
        self._order.append(name)

    def lock(self, name: str = "lock") -> SentinelLock:
        return SentinelLock(self, name)

    def event(self, name: str = "event") -> SentinelEvent:
        return SentinelEvent(self, name)

    def yield_point(self, label: str = "") -> None:
        """Explicit switch point for hand-instrumented test bodies."""
        self._op(("yield", str(label)))

    def run(self, timeout: float = 30.0) -> dict[str, Any]:
        """Drive all spawned bodies to completion under one seeded
        schedule. Returns ``{name: result}``; re-raises the first (spawn
        order) thread exception; raises :class:`InterleaveError` on
        deadlock, budget exhaustion, or a thread stuck on a real
        (non-sentinel) block."""
        if self._ran:
            raise InterleaveError("run() called twice: make a new sentinel")
        self._ran = True
        for name in self._order:
            rec = self._recs[name]
            t = threading.Thread(
                target=self._main, args=(name,),
                name=f"interleave-{name}", daemon=True,
            )
            t._sentinel_name = name
            rec["thread"] = t
            rec["state"] = "runnable"
            t.start()
        try:
            self._schedule_loop(timeout)
        except BaseException:
            self._do_abort("aborted")
            raise
        for name in self._order:
            err = self._recs[name]["error"]
            if err is not None:
                raise err
        return dict(self.results)

    # -- scheduler core -----------------------------------------------------

    def _schedule_loop(self, timeout: float) -> None:
        with self._cond:
            while True:
                states = {n: r["state"] for n, r in self._recs.items()}
                if all(s == "done" for s in states.values()):
                    return
                runnable = [n for n in self._order
                            if states[n] == "runnable"]
                if not runnable:
                    blocked = {
                        n: getattr(self._recs[n]["blocker"], "name", "?")
                        for n in self._order if states[n] == "blocked"
                    }
                    self._do_abort("deadlock", locked=True)
                    raise InterleaveError(
                        f"deadlock: every live thread is blocked {blocked} "
                        f"(schedule so far: {len(self.schedule)} switches)"
                    )
                if len(self.schedule) > self.max_switches:
                    self._do_abort("budget", locked=True)
                    raise InterleaveError(
                        f"schedule exceeded {self.max_switches} switches — "
                        "runaway loop under the sentinel?"
                    )
                pick = (runnable[0] if len(runnable) == 1
                        else runnable[self._rng.randrange(len(runnable))])
                self._current = pick
                self._cond.notify_all()
                ok = self._cond.wait_for(
                    lambda: self._current is None, timeout=timeout
                )
                if not ok:
                    self._do_abort("timeout", locked=True)
                    raise InterleaveError(
                        f"thread {pick!r} did not yield within {timeout}s — "
                        "is it blocked on a real (non-sentinel) primitive?"
                    )

    def _do_abort(self, why: str, locked: bool = False) -> None:
        if locked:
            self._abort = self._abort or why
            self._cond.notify_all()
        else:
            with self._cond:
                self._abort = self._abort or why
                self._cond.notify_all()

    def _main(self, name: str) -> None:
        rec = self._recs[name]
        try:
            with self._cond:
                self._cond.wait_for(
                    lambda: self._current == name or self._abort is not None
                )
                if self._abort is not None:
                    raise _Abort()
            if self.trace_patterns:
                sys.settrace(self._global_tracer)
            try:
                self.results[name] = rec["fn"](*rec["args"], **rec["kwargs"])
            finally:
                sys.settrace(None)
        except _Abort:
            pass
        except BaseException as e:
            rec["error"] = e
        finally:
            with self._cond:
                rec["state"] = "done"
                if self._current == name:
                    self._current = None
                self._cond.notify_all()

    def _current_name(self) -> Optional[str]:
        return getattr(threading.current_thread(), "_sentinel_name", None)

    def _op(self, label: tuple) -> None:
        """Yield the turn back to the scheduler and wait to be re-picked."""
        name = self._current_name()
        if name is None:
            return  # unmanaged thread touching a sentinel primitive
        with self._cond:
            if self._abort is not None:
                raise _Abort()
            self.schedule.append((name,) + label)
            self._current = None
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: self._current == name or self._abort is not None
            )
            if self._abort is not None:
                raise _Abort()

    def _block(self, primitive) -> None:
        """Park the current thread until ``primitive`` wakes it."""
        name = self._current_name()
        if name is None:
            raise InterleaveError(
                "a non-spawned thread blocked on a sentinel primitive"
            )
        with self._cond:
            if self._abort is not None:
                raise _Abort()
            rec = self._recs[name]
            rec["state"] = "blocked"
            rec["blocker"] = primitive
            self.schedule.append(
                (name, "block", getattr(primitive, "name", "?"))
            )
            self._current = None
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: self._current == name or self._abort is not None
            )
            if self._abort is not None:
                raise _Abort()

    def _wake_waiters(self, primitive) -> None:
        with self._cond:
            for rec in self._recs.values():
                if rec["state"] == "blocked" and rec["blocker"] is primitive:
                    rec["state"] = "runnable"
                    rec["blocker"] = None

    # -- settrace line-granularity yield points ------------------------------

    def _global_tracer(self, frame, event, arg):
        fname = frame.f_code.co_filename.replace(os.sep, "/")
        if any(p in fname for p in self.trace_patterns):
            return self._line_tracer
        return None

    def _line_tracer(self, frame, event, arg):
        if event == "line":
            fname = frame.f_code.co_filename.replace(os.sep, "/")
            self._op(("line", fname.rsplit("/", 1)[-1], frame.f_lineno))
        return self._line_tracer
