"""jaxlint configuration: the repo-specific contract surface.

Every constant here names a *real* invariant from DESIGN.md — the rules in
rules.py are generic AST passes parameterized by this module, so the checker
stays honest about what is convention (this file) vs. what is analysis
(rules.py). Adjust these when the trainer's contract surface moves.
"""
from __future__ import annotations

# ---------------------------------------------------------------------------
# JL001/JL002 — the traced surface (DESIGN.md §1/§4)
# ---------------------------------------------------------------------------

#: Call-graph roots that trace inside the engine's jitted device programs.
#: Everything statically reachable from these (plus the callables an
#: algorithm's ``round_transforms`` hook hands to ``RoundTransforms``) must
#: obey the jit rules: no host syncs, no Python branching on tracer values.
TRACED_ROOT_NAMES: tuple[str, ...] = ("round_body", "megabatch_fn")

#: Methods whose returned ``RoundTransforms(...)`` members are traced
#: (DESIGN.md §4 hook contract).
TRANSFORM_FACTORY_NAME = "round_transforms"

#: The frozen static-jit-arg container those factories must construct.
TRANSFORM_CLASS_NAME = "RoundTransforms"

#: ``float()``/``int()``/``bool()`` on these attributes is static metadata,
#: not a device sync (shapes and ranks are Python values at trace time).
STATIC_SCALAR_ATTRS: frozenset[str] = frozenset({"ndim", "shape", "size", "dtype"})

#: Array-reduction method names whose appearance in an ``if``/``while`` test
#: inside traced code means Python is branching on a tracer (JL002).
REDUCTION_METHOD_NAMES: frozenset[str] = frozenset(
    {"sum", "max", "min", "mean", "any", "all", "prod", "item"}
)

#: Module roots whose calls produce/consume tracer values: a call into any
#: of these inside an ``if``/``while`` test is Python branching on a tracer.
JAX_MODULE_ROOTS: tuple[str, ...] = ("jax",)

# ---------------------------------------------------------------------------
# JL003 — buffer donation (DESIGN.md §1: scan engine donates replica/momentum)
# ---------------------------------------------------------------------------

#: Donation registry: callables whose donated positional argument indices
#: cannot be recovered statically (``donate_argnums`` is computed, e.g.
#: backend-gated in trainer._build_jits). Maps the callable's terminal name
#: (``self._megabatch`` -> ``_megabatch``) to its donated positions. Literal
#: ``donate_argnums=(...)`` sites are discovered without registry help.
DONATED_CALLABLES: dict[str, tuple[int, ...]] = {
    # trainer's scan-engine entry points: replicas (0) and momentum (1) are
    # donated on TPU/GPU backends (trainer.py _build_jits / shard wrappers)
    "_megabatch": (0, 1),
    "jit_megabatch": (0, 1),
}

# ---------------------------------------------------------------------------
# JL006 — host callbacks (DESIGN.md §8: measured timing only)
# ---------------------------------------------------------------------------

#: Modules (path suffixes, POSIX separators) allowed to use
#: ``jax.debug.callback`` / ``io_callback``: the measured-speed timing layer.
#: Anywhere else, a callback in the hot loop is a hidden host round-trip —
#: take an inline ``# jaxlint: disable=JL006 — <reason>`` if intentional.
APPROVED_CALLBACK_MODULE_SUFFIXES: tuple[str, ...] = (
    "core/heterogeneity.py",
)

#: Fully-qualified callback entry points the rule recognizes.
CALLBACK_QUALNAMES: frozenset[str] = frozenset(
    {
        "jax.debug.callback",
        "jax.experimental.io_callback",
        "jax.pure_callback",
    }
)

#: Bare names that count when imported from jax (``from jax.experimental
#: import io_callback``).
CALLBACK_BARE_NAMES: frozenset[str] = frozenset({"io_callback"})

# ---------------------------------------------------------------------------
# JL005 — pytree dataclasses (DESIGN.md §3: RowSparseGrad is the exemplar)
# ---------------------------------------------------------------------------

#: ``tree_util`` entry points whose pytree arguments must be registered
#: containers (a freshly constructed unregistered dataclass passed here is
#: silently treated as a leaf — or crashes — depending on the op).
TREE_OP_NAMES: frozenset[str] = frozenset(
    {
        "tree_map", "tree_leaves", "tree_flatten", "tree_unflatten",
        "tree_all", "tree_reduce", "tree_structure", "tree_map_with_path",
    }
)

# ---------------------------------------------------------------------------
# JL007 — checkpoint payload completeness (DESIGN.md §7, the PR 6 bug class)
# ---------------------------------------------------------------------------

#: The trainer-state dataclass whose fields the payload must cover.
STATE_CLASS_NAME = "ElasticState"

#: State fields that are process-local and intentionally NOT serialized
#: (none today; list field names here if that ever changes).
STATE_FIELD_EXEMPTIONS: frozenset[str] = frozenset()

#: Function-name convention the cross-check keys on: ``checkpoint_payload``
#: builds dict literals named ``tree`` and ``metadata``; the restore side
#: builds ``like`` and subscripts the loaded ``tree``.
CHECKPOINT_PAYLOAD_NAME = "checkpoint_payload"
CHECKPOINT_RESTORE_NAME = "restore_checkpoint"
PAYLOAD_TREE_VAR = "tree"
PAYLOAD_META_VAR = "metadata"
RESTORE_LIKE_VAR = "like"
RESTORE_TREE_VARS: tuple[str, ...] = ("tree",)

# ---------------------------------------------------------------------------
# JL101–JL106 — concurrency/protocol family (DESIGN.md §11)
# ---------------------------------------------------------------------------

#: ``threading`` constructors that create a mutual-exclusion lock; a
#: ``self.X = threading.Lock()`` attribute defines a class's guarded regions
#: (``with self.X:``) for JL101/JL104/JL106.
LOCK_CTOR_NAMES: frozenset[str] = frozenset({"Lock", "RLock"})

#: All ``threading`` synchronization-primitive constructors. Attributes
#: holding these are themselves thread-safe and exempt from JL101's
#: guarded-access requirement (an Event IS the synchronization).
SYNC_PRIMITIVE_CTOR_NAMES: frozenset[str] = frozenset(
    {"Lock", "RLock", "Event", "Condition", "Semaphore",
     "BoundedSemaphore", "Barrier"}
)

#: Modules (path suffixes, POSIX separators) whose on-disk writes are
#: *publishes* read concurrently by other threads/processes: heartbeat
#: leases, exchange files, checkpoints. JL102 requires every write-mode
#: ``open()`` here to stage through a tmp sibling + ``os.replace``.
PUBLISH_MODULE_SUFFIXES: tuple[str, ...] = (
    "core/fleet.py",
    "checkpoint/store.py",
    "launch/multihost.py",
)

#: A path expression counts as staged (not a direct publish) when it
#: mentions an identifier containing one of these markers, or a tempfile
#: call (``tempfile.mkdtemp`` / ``mkstemp`` / ``NamedTemporaryFile``).
TMP_PATH_MARKERS: tuple[str, ...] = ("tmp",)

#: The atomic-rename entry points that turn a staged file into a publish.
PUBLISH_RENAME_QUALNAMES: frozenset[str] = frozenset(
    {"os.replace", "os.rename"}
)

#: Calls that block (or do I/O) and therefore must not run while a lock is
#: held (JL104). ``open()`` and zero-positional-arg ``.join()``/``.wait()``
#: method calls are matched structurally in the rule, not listed here.
BLOCKING_CALL_QUALNAMES: frozenset[str] = frozenset(
    {"time.sleep", "os.replace", "os.rename", "os.fsync",
     "subprocess.run", "subprocess.check_call", "subprocess.check_output",
     "shutil.rmtree", "shutil.copytree", "shutil.copy"}
)

#: Modules (path suffixes) implementing liveness/exchange timing, where
#: every clock read and sleep must go through an injectable attribute so
#: tests drive time deterministically (JL105). Wall-clock *measurement*
#: (benchmarks, logging) is deliberately out of scope.
CLOCKED_MODULE_SUFFIXES: tuple[str, ...] = (
    "core/fleet.py",
    "core/heterogeneity.py",
    "launch/multihost.py",
)

#: The bare time calls JL105 flags inside the clocked modules. References
#: (``clock=time.monotonic`` defaults) are fine — only *calls* hard-wire
#: the wall clock.
TIME_CALL_QUALNAMES: frozenset[str] = frozenset(
    {"time.time", "time.monotonic", "time.perf_counter", "time.sleep"}
)
