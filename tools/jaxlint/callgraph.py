"""Static call graph over the analyzed files.

Purpose-built for one question: *which functions trace inside the engine's
jitted device programs?* (DESIGN.md §1/§4). Roots are the engine bodies
(``round_body`` / ``megabatch_fn``) and every callable an algorithm's
``round_transforms`` hook hands to ``RoundTransforms``; the closure follows

* direct calls to names resolvable in the lexical scope chain (sibling
  nested defs, enclosing functions, module top level),
* ``from m import f`` / ``import m as alias`` edges into other analyzed
  modules (``tu.tree_map`` -> repro.utils.tree.tree_map), including
  relative imports,
* functions passed as arguments to tracing combinators
  (``jax.lax.scan(body, ...)``, ``jax.vmap(f)``, ``shard_map(f, ...)``,
  ``functools.partial(f, ...)``),
* every function/lambda lexically nested inside a traced function
  (closures trace with their parent).

Method calls through objects (``self.x()``, ``obj.m()``) are not resolved —
receiver types are unknowable without inference, and the traced surface the
trainer contract cares about is reachable through the cases above.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from . import config
from .engine import Module, Project

#: combinators whose function-valued arguments trace
_TRACING_COMBINATORS = frozenset(
    {"scan", "vmap", "pmap", "jit", "shard_map", "partial", "custom_vjp",
     "checkpoint", "remat", "while_loop", "fori_loop", "cond", "switch",
     "grad", "value_and_grad"}
)

FuncNode = ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda


@dataclasses.dataclass
class FuncInfo:
    qualname: str                  # "repro.core.trainer:_build_jits.round_body"
    module: Module
    node: FuncNode
    name: str                      # terminal name ("<lambda>" for lambdas)
    parent: Optional["FuncInfo"]
    children: list["FuncInfo"] = dataclasses.field(default_factory=list)
    local_defs: dict[str, "FuncInfo"] = dataclasses.field(default_factory=dict)

    def __hash__(self):
        return id(self.node)

    def __eq__(self, other):
        return self is other


@dataclasses.dataclass
class ModuleScope:
    module: Module
    #: top-level function name -> FuncInfo
    defs: dict[str, FuncInfo]
    #: import alias -> dotted module path ("tu" -> "repro.utils.tree")
    import_mods: dict[str, str]
    #: imported name -> (dotted module, attr) ("sgd_update" ->
    #: ("repro.optim.sgd", "sgd_update"))
    import_names: dict[str, tuple[str, str]]


def _resolve_relative(module_name: str, level: int, target: str | None) -> str:
    """``from ..x import y`` in package context -> absolute dotted path."""
    parts = module_name.split(".")
    # module_name refers to the *module*; level=1 means its package
    base = parts[: len(parts) - level] if level <= len(parts) else []
    if target:
        base = base + target.split(".")
    return ".".join(base)


def _collect_imports(module: Module) -> tuple[dict[str, str], dict[str, tuple[str, str]]]:
    mods: dict[str, str] = {}
    names: dict[str, tuple[str, str]] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mods[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0]
                )
                if alias.asname:
                    mods[alias.asname] = alias.name
        elif isinstance(node, ast.ImportFrom):
            src = node.module or ""
            if node.level:
                src = _resolve_relative(module.name, node.level, node.module)
            for alias in node.names:
                if alias.name == "*":
                    continue
                names[alias.asname or alias.name] = (src, alias.name)
    return mods, names


class CallGraph:
    def __init__(self):
        self.funcs: dict[int, FuncInfo] = {}          # id(node) -> info
        self.scopes: dict[str, ModuleScope] = {}      # module name -> scope
        self.edges: dict[FuncInfo, set[FuncInfo]] = {}
        self.traced_roots: list[FuncInfo] = []

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, project: Project) -> "CallGraph":
        g = cls()
        for module in project.modules:
            mods, names = _collect_imports(module)
            scope = ModuleScope(module=module, defs={}, import_mods=mods,
                                import_names=names)
            g.scopes[module.name] = scope
            g._register_functions(module, scope)
        for info in list(g.funcs.values()):
            g.edges[info] = g._call_targets(info)
        g._find_roots()
        return g

    def _register_functions(self, module: Module, scope: ModuleScope) -> None:
        def visit(node: ast.AST, parent: Optional[FuncInfo], prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    info = FuncInfo(
                        qualname=f"{module.name}:{qual}",
                        module=module, node=child, name=child.name,
                        parent=parent,
                    )
                    self.funcs[id(child)] = info
                    if parent is not None:
                        parent.children.append(info)
                        parent.local_defs[child.name] = info
                    else:
                        scope.defs.setdefault(child.name, info)
                    visit(child, info, f"{qual}.")
                elif isinstance(child, ast.Lambda):
                    info = FuncInfo(
                        qualname=f"{module.name}:{prefix}<lambda@L{child.lineno}>",
                        module=module, node=child, name="<lambda>",
                        parent=parent,
                    )
                    self.funcs[id(child)] = info
                    if parent is not None:
                        parent.children.append(info)
                    visit(child, info, prefix)
                elif isinstance(child, ast.ClassDef):
                    visit(child, parent, f"{prefix}{child.name}.")
                else:
                    visit(child, parent, prefix)

        visit(module.tree, None, "")

    # -- resolution ---------------------------------------------------------

    def _resolve_name(self, name: str, ctx: FuncInfo) -> Optional[FuncInfo]:
        """Resolve a bare name in a function's lexical scope chain."""
        cur = ctx.parent
        while cur is not None:
            if name in cur.local_defs:
                return cur.local_defs[name]
            cur = cur.parent
        scope = self.scopes[ctx.module.name]
        if name in scope.defs:
            return scope.defs[name]
        if name in scope.import_names:
            mod, attr = scope.import_names[name]
            target_scope = self.scopes.get(mod)
            if target_scope and attr in target_scope.defs:
                return target_scope.defs[attr]
        return None

    def _resolve_attr(self, node: ast.Attribute, ctx: FuncInfo) -> Optional[FuncInfo]:
        """Resolve ``alias.f`` where alias is an imported analyzed module."""
        if not isinstance(node.value, ast.Name):
            return None
        scope = self.scopes[ctx.module.name]
        target = scope.import_mods.get(node.value.id)
        if target is None and node.value.id in scope.import_names:
            mod, attr = scope.import_names[node.value.id]
            target = f"{mod}.{attr}" if mod else attr
        if target is None:
            return None
        target_scope = self.scopes.get(target)
        if target_scope and node.attr in target_scope.defs:
            return target_scope.defs[node.attr]
        return None

    def resolve_call(self, func_expr: ast.AST, ctx: FuncInfo) -> Optional[FuncInfo]:
        if isinstance(func_expr, ast.Name):
            return self._resolve_name(func_expr.id, ctx)
        if isinstance(func_expr, ast.Attribute):
            return self._resolve_attr(func_expr, ctx)
        return None

    def _call_targets(self, info: FuncInfo) -> set[FuncInfo]:
        targets: set[FuncInfo] = set()
        for node in iter_body_nodes(info.node):
            if not isinstance(node, ast.Call):
                continue
            t = self.resolve_call(node.func, info)
            if t is not None:
                targets.add(t)
            # combinator args: jax.lax.scan(body, ...), jax.vmap(f), ...
            callee_name = None
            if isinstance(node.func, ast.Attribute):
                callee_name = node.func.attr
            elif isinstance(node.func, ast.Name):
                callee_name = node.func.id
            if callee_name in _TRACING_COMBINATORS:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Name):
                        t = self._resolve_name(arg.id, info)
                        if t is not None:
                            targets.add(t)
                    elif isinstance(arg, ast.Attribute):
                        t = self._resolve_attr(arg, info)
                        if t is not None:
                            targets.add(t)
        return targets

    # -- traced surface ------------------------------------------------------

    def _find_roots(self) -> None:
        roots: list[FuncInfo] = []
        for info in self.funcs.values():
            if info.name in config.TRACED_ROOT_NAMES:
                roots.append(info)
        # callables handed to RoundTransforms(...) inside round_transforms
        for info in self.funcs.values():
            if info.name != config.TRANSFORM_FACTORY_NAME:
                continue
            for node in iter_body_nodes(info.node):
                if not (isinstance(node, ast.Call)
                        and _terminal_name(node.func) == config.TRANSFORM_CLASS_NAME):
                    continue
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        lam = self.funcs.get(id(arg))
                        if lam is not None:
                            roots.append(lam)
                    elif isinstance(arg, (ast.Name, ast.Attribute)):
                        t = self.resolve_call(arg, info)
                        if t is not None:
                            roots.append(t)
        self.traced_roots = roots

    def traced_functions(self) -> set[FuncInfo]:
        """Closure of the traced roots over call edges + lexical nesting."""
        seen: set[FuncInfo] = set()
        stack = list(self.traced_roots)
        while stack:
            info = stack.pop()
            if info in seen:
                continue
            seen.add(info)
            stack.extend(self.edges.get(info, ()))
            stack.extend(info.children)   # closures trace with their parent
        return seen


# ---------------------------------------------------------------------------
# AST helpers shared with the rules
# ---------------------------------------------------------------------------


def iter_body_nodes(func: FuncNode):
    """Walk a function's own body, NOT descending into nested function
    definitions or lambdas (those are separate FuncInfos)."""
    if isinstance(func, ast.Lambda):
        todo: list[ast.AST] = [func.body]
    else:
        todo = list(func.body)
    while todo:
        node = todo.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue  # separate FuncInfo — don't attribute its body here
        todo.extend(ast.iter_child_nodes(node))


def _terminal_name(expr: ast.AST) -> Optional[str]:
    if isinstance(expr, ast.Name):
        return expr.id
    if isinstance(expr, ast.Attribute):
        return expr.attr
    return None


def terminal_name(expr: ast.AST) -> Optional[str]:
    return _terminal_name(expr)


def dotted_name(expr: ast.AST) -> Optional[str]:
    """'a.b.c' for a pure Name/Attribute chain, else None."""
    parts: list[str] = []
    cur = expr
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None
