"""CLI: ``python -m tools.jaxlint [paths...]``.

Exit codes: 0 clean (or all findings suppressed/baselined), 1 findings,
2 usage/parse errors. Must stay importable without jax installed (the CI
lint job has no project deps).
"""
from __future__ import annotations

import argparse
import os
import sys

from . import engine, rules

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.txt")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.jaxlint",
        description="AST-based JAX contract checker (rules JL001-JL007; "
        "see DESIGN.md §9)",
    )
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--root", default=os.getcwd(),
                        help="repo root paths are resolved against")
    parser.add_argument("--select", action="append", default=None,
                        metavar="JLxxx", help="run only these rules")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline file of accepted findings")
    parser.add_argument("--write-baseline", action="store_true",
                        help="rewrite the baseline with current findings "
                        "and exit 0 (policy: keep it empty — prefer inline "
                        "disables with reasons)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress the summary line")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code, rule_cls in sorted(rules.RULES.items()):
            print(f"{code}  {rule_cls.summary}")
        return 0

    baseline = engine.load_baseline(args.baseline)
    result = engine.lint(
        args.paths, root=args.root, select=args.select,
        baseline=None if args.write_baseline else baseline,
    )
    for err in result.errors:
        print(f"error: {err}", file=sys.stderr)
    if result.errors:
        return 2

    if args.write_baseline:
        engine.write_baseline(args.baseline, result.findings)
        print(f"wrote {len(result.findings)} baseline entries to "
              f"{args.baseline}")
        return 0

    for f in result.findings:
        print(f.render())
    if not args.quiet:
        parts = [f"{len(result.findings)} finding(s)",
                 f"{result.n_files} file(s)"]
        if result.suppressed:
            parts.append(f"{len(result.suppressed)} suppressed inline")
        if result.baselined:
            parts.append(f"{len(result.baselined)} baselined")
        print("jaxlint: " + ", ".join(parts), file=sys.stderr)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
