"""CLI: ``python -m tools.jaxlint [paths...]``.

Exit codes: 0 clean (or all findings suppressed/baselined), 1 findings,
2 usage/parse errors, 3 a rule crashed (internal error — results are
incomplete, which CI must distinguish from a real regression). Must stay
importable without jax installed (the CI lint job has no project deps).
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys

from . import engine, rules

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.txt")
FIXTURES_DIR = os.path.join(os.path.dirname(__file__), "fixtures")


def _fixture_paths(code: str, kind: str) -> list[str]:
    """Files of one rule's fixture: ``JLxxx_<kind>.py``, or every .py under
    a ``jlxxx_<kind>/`` directory for the path-based rules."""
    flat = os.path.join(FIXTURES_DIR, f"{code}_{kind}.py")
    if os.path.isfile(flat):
        return [flat]
    d = os.path.join(FIXTURES_DIR, f"{code.lower()}_{kind}")
    out: list[str] = []
    if os.path.isdir(d):
        for dirpath, _, filenames in os.walk(d):
            out.extend(
                os.path.join(dirpath, f)
                for f in sorted(filenames) if f.endswith(".py")
            )
    return out


def _explain(code: str) -> int:
    rule_cls = rules.RULES.get(code)
    if rule_cls is None:
        print(f"error: unknown rule {code!r} (see --list-rules)",
              file=sys.stderr)
        return 2
    print(f"{code} [{engine.rule_family(code)}]  {rule_cls.summary}\n")
    doc = inspect.cleandoc(rule_cls.__doc__ or "").strip()
    if doc:
        print(doc + "\n")
    for kind, label in (("good", "passes"), ("bad", "is flagged")):
        paths = _fixture_paths(code, kind)
        if not paths:
            continue
        for p in paths:
            rel = os.path.relpath(p, os.path.dirname(FIXTURES_DIR))
            print(f"--- {kind} fixture ({label}): {rel} ---")
            with open(p, encoding="utf-8") as f:
                print(f.read().rstrip())
            print()
    return 0


def _render_github(f: engine.Finding) -> str:
    # GitHub workflow-command annotation: shows inline on the PR diff
    return (f"::error file={f.path},line={f.line},col={f.col + 1},"
            f"title=jaxlint {f.rule}::{f.message}")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.jaxlint",
        description="AST-based contract checker: jit family JL001-JL007 "
        "(DESIGN.md §9) + concurrency family JL101-JL106 (DESIGN.md §11)",
    )
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--root", default=os.getcwd(),
                        help="repo root paths are resolved against")
    parser.add_argument("--select", action="append", default=None,
                        metavar="JLxxx", help="run only these rules")
    parser.add_argument("--family", choices=("jit", "concurrency", "all"),
                        default="all",
                        help="run only one rule family (default: all)")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline file of accepted findings")
    parser.add_argument("--write-baseline", action="store_true",
                        help="rewrite the baseline with current findings "
                        "and exit 0 (policy: keep it empty — prefer inline "
                        "disables with reasons)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    parser.add_argument("--explain", metavar="JLxxx", default=None,
                        help="print a rule's contract plus its good/bad "
                        "fixtures and exit")
    parser.add_argument("--format", choices=("text", "github"),
                        default="text",
                        help="finding output format (github = workflow-"
                        "command annotations shown inline on PRs)")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress the summary line")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code, rule_cls in sorted(rules.RULES.items()):
            fam = engine.rule_family(code)
            print(f"{code}  [{fam:<11}]  {rule_cls.summary}")
        return 0
    if args.explain:
        return _explain(args.explain)

    baseline = engine.load_baseline(args.baseline)
    result = engine.lint(
        args.paths, root=args.root, select=args.select,
        baseline=None if args.write_baseline else baseline,
        family=args.family,
    )
    for err in result.errors:
        print(f"error: {err}", file=sys.stderr)
    if result.errors:
        return 2
    for err in result.internal_errors:
        print(f"internal error: {err}", file=sys.stderr)
    if result.internal_errors:
        return 3

    if args.write_baseline:
        engine.write_baseline(args.baseline, result.findings)
        print(f"wrote {len(result.findings)} baseline entries to "
              f"{args.baseline}")
        return 0

    for f in result.findings:
        print(_render_github(f) if args.format == "github" else f.render())
    if not args.quiet:
        parts = [f"{len(result.findings)} finding(s)",
                 f"{result.n_files} file(s)",
                 f"family={args.family}"]
        if result.suppressed:
            parts.append(f"{len(result.suppressed)} suppressed inline")
        if result.baselined:
            parts.append(f"{len(result.baselined)} baselined")
        print("jaxlint: " + ", ".join(parts), file=sys.stderr)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
