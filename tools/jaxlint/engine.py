"""jaxlint engine: file discovery, suppression parsing, baseline, driver.

Stdlib-only (``ast`` + ``re``): the linter must run in the CI lint job,
which installs no project dependencies — importing jax (or repro) from the
static-analysis path is itself a layering bug.

Suppression syntax (checked, not stringly-matched elsewhere):

* inline  — ``some_code()  # jaxlint: disable=JL001`` silences the named
  rule(s) on that physical line (comma-separated; a trailing ``— reason``
  is encouraged and ignored by the parser).
* file    — ``# jaxlint: disable-file=JL006`` anywhere at module top level
  (first 10 lines) silences the rule(s) for the whole file.
* baseline — a checked-in file of known findings (``path::rule::code``)
  that the CLI subtracts before failing. The shipped baseline is empty and
  the self-check test keeps it that way: new debt needs an inline disable
  with a reason, not a baseline entry (ISSUE 8 policy).
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Optional

_SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Z0-9, ]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*jaxlint:\s*disable-file=([A-Z0-9, ]+)")
_FILE_DIRECTIVE_SCAN_LINES = 10


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source line."""

    path: str      # POSIX relpath from the lint root
    line: int      # 1-based
    col: int       # 0-based
    rule: str      # "JL001"
    message: str
    code: str = "" # stripped source of the flagged line (baseline fingerprint)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def baseline_key(self) -> str:
        # line numbers churn; (path, rule, code) survives unrelated edits
        return f"{self.path}::{self.rule}::{self.code}"


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    path: str          # absolute
    rel: str           # POSIX relpath from the lint root
    name: str          # dotted module name ("repro.core.trainer")
    tree: ast.Module
    lines: list[str]   # raw source lines (1-based access via lines[i-1])

    def line_source(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


@dataclasses.dataclass
class Project:
    """All modules under analysis plus shared lazily-built artifacts."""

    root: str
    modules: list[Module]
    errors: list[str]
    _callgraph: Optional[object] = None

    def by_rel(self, rel: str) -> Optional[Module]:
        for m in self.modules:
            if m.rel == rel:
                return m
        return None

    @property
    def callgraph(self):
        if self._callgraph is None:
            from . import callgraph

            self._callgraph = callgraph.CallGraph.build(self)
        return self._callgraph


def module_name_for(rel: str) -> str:
    """Dotted module name for a POSIX relpath; mirrors the repo layout where
    importable code lives under ``src/`` (``src/repro/x.py`` -> ``repro.x``)
    and top-level dirs (benchmarks/, scripts/) are packages of their own."""
    parts = rel.split("/")
    if parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][: -len(".py")]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(p for p in parts if p)


def iter_py_files(paths: Iterable[str], root: str) -> list[str]:
    """Expand files/dirs into a sorted list of absolute .py paths."""
    out: set[str] = set()
    for p in paths:
        # cwd-relative (usual CLI case), falling back to root-relative
        ap = p if os.path.isabs(p) or os.path.exists(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.add(os.path.abspath(ap))
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.add(os.path.abspath(os.path.join(dirpath, fn)))
    return sorted(out)


def load_project(paths: Iterable[str], root: str) -> Project:
    root = os.path.abspath(root)
    modules, errors = [], []
    for path in iter_py_files(paths, root):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                src = f.read()
            tree = ast.parse(src, filename=path)
        except (OSError, SyntaxError) as e:
            errors.append(f"{rel}: cannot parse: {e}")
            continue
        modules.append(
            Module(
                path=path,
                rel=rel,
                name=module_name_for(rel),
                tree=tree,
                lines=src.splitlines(),
            )
        )
    return Project(root=root, modules=modules, errors=errors)


# ---------------------------------------------------------------------------
# suppression
# ---------------------------------------------------------------------------


def _parse_rule_list(blob: str) -> set[str]:
    return {r.strip() for r in blob.split(",") if r.strip()}


def suppressed_rules(module: Module) -> tuple[dict[int, set[str]], set[str]]:
    """(line -> rules disabled inline, rules disabled file-wide)."""
    by_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    for i, line in enumerate(module.lines, start=1):
        if "jaxlint" not in line:
            continue
        m = _SUPPRESS_RE.search(line)
        if m:
            by_line.setdefault(i, set()).update(_parse_rule_list(m.group(1)))
        m = _SUPPRESS_FILE_RE.search(line)
        if m and i <= _FILE_DIRECTIVE_SCAN_LINES:
            file_wide.update(_parse_rule_list(m.group(1)))
    return by_line, file_wide


def split_suppressed(
    findings: list[Finding], project: Project
) -> tuple[list[Finding], list[Finding]]:
    """Partition findings into (active, inline/file-suppressed)."""
    cache: dict[str, tuple[dict[int, set[str]], set[str]]] = {}
    active, suppressed = [], []
    for f in findings:
        mod = project.by_rel(f.path)
        if mod is None:
            active.append(f)
            continue
        if mod.rel not in cache:
            cache[mod.rel] = suppressed_rules(mod)
        by_line, file_wide = cache[mod.rel]
        if f.rule in file_wide or f.rule in by_line.get(f.line, ()):
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def load_baseline(path: str) -> set[str]:
    """Baseline entries (``path::rule::code`` lines; comments/blank ignored)."""
    entries: set[str] = set()
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                entries.add(line)
    return entries


def write_baseline(path: str, findings: list[Finding]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write(
            "# jaxlint baseline — one `path::rule::code` entry per accepted\n"
            "# finding. Policy (DESIGN.md §9): keep this file EMPTY; new\n"
            "# exceptions take an inline `# jaxlint: disable=JLxxx — reason`.\n"
        )
        for key in sorted({fi.baseline_key() for fi in findings}):
            f.write(key + "\n")


def split_baselined(
    findings: list[Finding], baseline: set[str]
) -> tuple[list[Finding], list[Finding]]:
    active = [f for f in findings if f.baseline_key() not in baseline]
    known = [f for f in findings if f.baseline_key() in baseline]
    return active, known


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]            # active (reportable) findings
    suppressed: list[Finding]          # silenced by inline/file directives
    baselined: list[Finding]           # silenced by the baseline file
    errors: list[str]                  # parse failures (always fatal)
    n_files: int = 0
    #: a rule that *crashed* (vs. one that found something): CI must tell
    #: a regression from a broken linter — distinct exit code 3
    internal_errors: list[str] = dataclasses.field(default_factory=list)


FAMILIES = ("jit", "concurrency")


def rule_family(code: str) -> str:
    """JL0xx = jit-contract family, JL1xx = concurrency/protocol family."""
    try:
        return "concurrency" if int(code[2:]) >= 100 else "jit"
    except ValueError:
        return "jit"


def lint(
    paths: Iterable[str],
    root: str,
    select: Optional[Iterable[str]] = None,
    baseline: Optional[set[str]] = None,
    family: Optional[str] = None,
) -> LintResult:
    """Run every (selected) rule over ``paths``; returns the partitioned
    findings. ``baseline`` is a pre-loaded entry set (see load_baseline);
    ``family`` restricts to one rule family ("jit"/"concurrency";
    None/"all" runs both)."""
    from . import rules

    project = load_project(paths, root)
    wanted = set(select) if select else None
    findings: list[Finding] = []
    internal: list[str] = []
    for code, rule_cls in sorted(rules.RULES.items()):
        if wanted is not None and code not in wanted:
            continue
        if family and family != "all" and rule_family(code) != family:
            continue
        try:
            findings.extend(rule_cls().run(project))
        except Exception as e:  # noqa: BLE001 — a broken rule is exit 3
            internal.append(f"{code}: rule crashed: {e!r}")
    # attach source fingerprints (rules only know positions)
    with_code: list[Finding] = []
    for f in findings:
        mod = project.by_rel(f.path)
        code_line = mod.line_source(f.line) if mod else ""
        with_code.append(dataclasses.replace(f, code=code_line))
    with_code.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    active, suppressed = split_suppressed(with_code, project)
    if baseline:
        active, known = split_baselined(active, baseline)
    else:
        known = []
    return LintResult(
        findings=active,
        suppressed=suppressed,
        baselined=known,
        errors=project.errors,
        n_files=len(project.modules),
        internal_errors=internal,
    )
