"""jaxlint: repo-specific static analysis for the trainer's JAX contracts.

Usage: ``python -m tools.jaxlint src benchmarks scripts`` (see
tools/README.md and DESIGN.md §9). The package is stdlib-only by design —
``sentinel`` (the runtime retrace counter) is the one jax-importing module
and is deliberately NOT imported here so the CLI works in the dependency-free
CI lint job.
"""
from .engine import Finding, LintResult, lint, load_baseline

__all__ = ["Finding", "LintResult", "lint", "load_baseline"]
