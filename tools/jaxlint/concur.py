"""jaxlint rules JL101–JL106: the concurrency/protocol family.

Static half of the two-family analyzer (DESIGN.md §11). Same machinery as
the jit family — stdlib-AST passes over the :class:`engine.Project`,
parameterized by config.py — but aimed at the host-side thread and
exchange-protocol contracts: lock discipline, atomic publish, thread
lifecycle, no-blocking-while-locked, injectable time, and callback-thread
write confinement. The runtime half is :mod:`tools.jaxlint.interleave`.

The shared substrate is :class:`ClassScan`: a per-class inventory of lock
attributes, every ``self.X`` access (read/write, lexically lock-guarded or
not), and the call graph reachable from ``threading.Thread`` targets.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from . import config
from .callgraph import FuncNode, dotted_name, terminal_name
from .engine import Finding, Module, Project
from .rules import _finding, qualify

# ---------------------------------------------------------------------------
# per-class concurrency inventory
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Access:
    """One ``self.X`` touch: where, which method, read-or-write, guarded."""

    attr: str
    node: ast.AST          # anchor for the finding (the Attribute node)
    method: str            # top-level method name the access lives in
    func: FuncNode         # innermost enclosing function (method or nested)
    write: bool
    guarded: bool          # lexically inside ``with self.<lock>:``


@dataclasses.dataclass
class ClassScan:
    module: Module
    node: ast.ClassDef
    self_name: str
    lock_attrs: set[str]
    primitive_attrs: set[str]           # incl. locks: thread-safe by nature
    init_writes: set[str]
    writes_outside_init: set[str]
    accesses: list[Access]
    methods: dict[str, ast.FunctionDef]
    #: thread targets: method names (``target=self._run``) and nested
    #: function defs (``target=_loop`` closed over self)
    thread_target_methods: set[str]
    thread_target_funcs: list[FuncNode]

    def guarded_write_attrs(self) -> set[str]:
        return {a.attr for a in self.accesses if a.write and a.guarded}

    def thread_graph_attrs(self) -> set[str]:
        """Attrs touched in the call graph rooted at the thread targets,
        following ``self.m()`` calls within the class (fixpoint)."""
        reach: set[str] = set()
        queue = list(self.thread_target_methods)
        for fn in self.thread_target_funcs:
            queue.extend(self._self_calls(fn))
        while queue:
            m = queue.pop()
            if m in reach or m not in self.methods:
                continue
            reach.add(m)
            queue.extend(self._self_calls(self.methods[m]))
        funcs = {id(fn) for fn in self.thread_target_funcs}
        return {
            a.attr for a in self.accesses
            if a.method in reach or id(a.func) in funcs
        }

    def _self_calls(self, fn: FuncNode) -> set[str]:
        out: set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == self.self_name):
                out.add(node.func.attr)
        return out


def _is_primitive_ctor(value: ast.expr, module_scope) -> tuple[bool, bool]:
    """(is a threading sync primitive, is a lock) for an assigned value."""
    if not isinstance(value, ast.Call):
        return False, False
    t = terminal_name(value.func)
    if t not in config.SYNC_PRIMITIVE_CTOR_NAMES:
        return False, False
    d = dotted_name(value.func)
    if d and module_scope is not None:
        q = qualify(d, module_scope)
        if "." in q and not q.startswith("threading."):
            return False, False
    return True, t in config.LOCK_CTOR_NAMES


def _self_attr(node: ast.expr, self_name: str) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == self_name):
        return node.attr
    return None


def _lock_ctx_attrs(stmt: ast.With, self_name: str,
                    lock_attrs: set[str]) -> bool:
    for item in stmt.items:
        attr = _self_attr(item.context_expr, self_name)
        if attr is not None and attr in lock_attrs:
            return True
    return False


def scan_class(module: Module, node: ast.ClassDef, scope) -> ClassScan:
    methods = {
        s.name: s for s in node.body
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    # pass 1: lock/primitive attributes (any method may create them)
    lock_attrs: set[str] = set()
    primitive_attrs: set[str] = set()
    for fn in methods.values():
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Assign):
                continue
            prim, lock = _is_primitive_ctor(sub.value, scope)
            if not prim:
                continue
            for t in sub.targets:
                attr = _self_attr(t, "self")
                if attr:
                    primitive_attrs.add(attr)
                    if lock:
                        lock_attrs.add(attr)

    scan = ClassScan(
        module=module, node=node, self_name="self",
        lock_attrs=lock_attrs, primitive_attrs=primitive_attrs,
        init_writes=set(), writes_outside_init=set(), accesses=[],
        methods=methods, thread_target_methods=set(),
        thread_target_funcs=[],
    )

    # pass 2: accesses, guardedness, thread targets
    for mname, fn in methods.items():
        self_name = "self"
        if fn.args.args:
            self_name = fn.args.args[0].arg
        _walk_accesses(scan, fn, fn, mname, self_name, guarded=False)
    return scan


def _record(scan: ClassScan, attr: str, node: ast.AST, method: str,
            func: FuncNode, write: bool, guarded: bool) -> None:
    scan.accesses.append(Access(
        attr=attr, node=node, method=method, func=func,
        write=write, guarded=guarded,
    ))
    if write:
        if method == "__init__":
            scan.init_writes.add(attr)
        else:
            scan.writes_outside_init.add(attr)


def _walk_accesses(scan: ClassScan, fn: FuncNode, stmt_owner: FuncNode,
                   method: str, self_name: str, guarded: bool) -> None:
    """Recursive statement walk tracking lexical with-lock containment.

    Nested defs/lambdas are walked too (their accesses belong to the same
    class), but the guard flag resets — a closure *defined* inside a
    ``with`` block runs later, outside it.
    """

    def visit(node: ast.AST, owner: FuncNode, guarded: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                visit(child, child, False)
                continue
            if isinstance(child, ast.With):
                inner = guarded or _lock_ctx_attrs(
                    child, self_name, scan.lock_attrs
                )
                for item in child.items:
                    visit(item, owner, guarded)
                for stmt in child.body:
                    visit(stmt, owner, inner)
                continue
            _classify(child, owner, guarded)
            visit(child, owner, guarded)

    def _classify(node: ast.AST, owner: FuncNode, guarded: bool) -> None:
        # writes: plain/aug/ann assignments to self.X, subscript stores
        # through self.X, and mutator method calls on self.X
        if isinstance(node, ast.Attribute):
            attr = _self_attr(node, self_name)
            if attr is None:
                return
            if isinstance(node.ctx, (ast.Store, ast.Del)):
                _record(scan, attr, node, method, owner, True, guarded)
            elif isinstance(node.ctx, ast.Load):
                _record(scan, attr, node, method, owner, False, guarded)
            return
        if isinstance(node, ast.Subscript) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            attr = _self_attr(node.value, self_name)
            if attr is not None:
                # count the container itself as written (the Load on
                # node.value is recorded separately by the Attribute case)
                _record(scan, attr, node, method, owner, True, guarded)
            return
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute):
                attr = _self_attr(node.func.value, self_name)
                if attr is not None and node.func.attr in _MUTATOR_METHODS:
                    _record(scan, attr, node, method, owner, True, guarded)
            # thread targets: threading.Thread(target=...)
            if terminal_name(node.func) == "Thread":
                for kw in node.keywords:
                    if kw.arg != "target":
                        continue
                    tattr = _self_attr(kw.value, self_name)
                    if tattr is not None:
                        scan.thread_target_methods.add(tattr)
                    elif isinstance(kw.value, ast.Name):
                        local = _find_local_def(fn, kw.value.id)
                        if local is not None:
                            scan.thread_target_funcs.append(local)

    visit(fn, stmt_owner, guarded)


#: container-mutating method names counted as writes of the receiver attr
_MUTATOR_METHODS = frozenset(
    {"append", "add", "update", "pop", "popleft", "setdefault", "remove",
     "discard", "clear", "extend", "insert"}
)


def _find_local_def(fn: FuncNode, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(fn):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def iter_class_scans(project: Project):
    graph = project.callgraph
    for module in project.modules:
        scope = graph.scopes.get(module.name)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                yield module, scan_class(module, node, scope)


# ---------------------------------------------------------------------------
# JL101 — lock discipline
# ---------------------------------------------------------------------------


class LockDiscipline:
    """An attribute is *protected* once it is ever written under ``with
    self._lock:`` or touched in a ``threading.Thread`` target's call graph
    (and written outside ``__init__``); every other access site must then
    hold the lock too. A half-guarded attribute is worse than an unguarded
    one — the lock documents an intent the unguarded sites silently break.
    ``__init__`` accesses (no thread exists yet), threading primitives,
    and attrs only ever written in ``__init__`` (immutable config) are
    exempt."""

    code = "JL101"
    summary = "attr shared with a thread/lock is accessed without the lock"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for module, scan in iter_class_scans(project):
            protected = set(scan.guarded_write_attrs())
            if scan.thread_target_methods or scan.thread_target_funcs:
                protected |= (
                    scan.thread_graph_attrs() & scan.writes_outside_init
                )
            protected -= scan.primitive_attrs
            if not protected:
                continue
            for a in scan.accesses:
                if (a.attr in protected and not a.guarded
                        and a.method != "__init__"):
                    kind = "written" if a.write else "read"
                    findings.append(_finding(
                        module, a.node, self.code,
                        f"{scan.node.name}.{a.attr} is lock-protected "
                        f"(guarded writes or thread-shared) but {kind} "
                        f"without the lock in {a.method}()",
                    ))
        return findings


# ---------------------------------------------------------------------------
# JL102 — atomic-publish discipline
# ---------------------------------------------------------------------------


def _is_write_mode(call: ast.Call) -> bool:
    mode = None
    if len(call.args) >= 2 and isinstance(call.args[1], ast.Constant):
        mode = call.args[1].value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    return isinstance(mode, str) and any(c in mode for c in "wxa")


def _path_is_staged(expr: ast.expr) -> bool:
    for node in ast.walk(expr):
        ident = None
        if isinstance(node, ast.Name):
            ident = node.id
        elif isinstance(node, ast.Attribute):
            ident = node.attr
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            ident = node.value
        elif isinstance(node, ast.Call):
            t = terminal_name(node.func)
            if t in ("mkdtemp", "mkstemp", "NamedTemporaryFile"):
                return True
        if ident and any(
            m in ident.lower() for m in config.TMP_PATH_MARKERS
        ):
            return True
    return False


def _functions_of(module: Module):
    """(function node, enclosing name) for every def, plus the module."""
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


class AtomicPublish:
    """In the publish-path modules (leases, exchange files, checkpoints —
    config.PUBLISH_MODULE_SUFFIXES), a write-mode ``open()`` must target a
    tmp-staged sibling, and the staging function must ``os.replace``/
    ``os.rename`` it into place. A bare ``open(final_path, "w")`` means a
    concurrent reader can observe a torn file."""

    code = "JL102"
    summary = "publish-path write is not tmp-staged + os.replace'd"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        graph = project.callgraph
        findings: list[Finding] = []
        for module in project.modules:
            if not module.rel.endswith(config.PUBLISH_MODULE_SUFFIXES):
                continue
            scope = graph.scopes.get(module.name)
            for fn in _functions_of(module):
                findings.extend(self._check_function(module, fn, scope))
        return findings

    def _check_function(self, module, fn, scope) -> list[Finding]:
        has_rename = False
        opens: list[ast.Call] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            qual = qualify(d, scope) if d and scope else d
            if qual in config.PUBLISH_RENAME_QUALNAMES:
                has_rename = True
            if (isinstance(node.func, ast.Name) and node.func.id == "open"
                    and node.args and _is_write_mode(node)):
                opens.append(node)
        findings = []
        for node in opens:
            if not _path_is_staged(node.args[0]):
                findings.append(_finding(
                    module, node, self.code,
                    "write-mode open() on a publish path writes in place; "
                    "stage to a tmp sibling and os.replace() it "
                    "(readers must never see a torn file)",
                ))
            elif not has_rename:
                findings.append(_finding(
                    module, node, self.code,
                    "staged tmp file is never published: no os.replace/"
                    "os.rename in this function",
                ))
        return findings


# ---------------------------------------------------------------------------
# JL103 — thread lifecycle
# ---------------------------------------------------------------------------


def _enclosing_class(module: Module, node: ast.AST) -> Optional[ast.ClassDef]:
    for cls in ast.walk(module.tree):
        if isinstance(cls, ast.ClassDef):
            for sub in ast.walk(cls):
                if sub is node:
                    return cls
    return None


def _joined_names(scope_node: ast.AST) -> set[str]:
    """Receiver dotted names of zero-positional-arg ``.join()`` calls."""
    out: set[str] = set()
    for node in ast.walk(scope_node):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "join" and not node.args):
            d = dotted_name(node.func.value)
            if d:
                out.add(d)
    return out


class ThreadLifecycle:
    """Every ``threading.Thread`` must pick its daemon-ness explicitly
    (``daemon=`` kwarg — an implicit non-daemon thread can hang process
    exit; an accidental daemon can be killed mid-write), and a thread
    stored on ``self`` must be joined somewhere in its owning class (a
    local thread, in its creating function)."""

    code = "JL103"
    summary = "threading.Thread without explicit daemon= or never joined"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        graph = project.callgraph
        findings: list[Finding] = []
        for module in project.modules:
            scope = graph.scopes.get(module.name)
            for fn in _functions_of(module):
                findings.extend(self._check_function(module, fn, scope))
        return findings

    def _is_thread_ctor(self, node: ast.Call, scope) -> bool:
        d = dotted_name(node.func)
        if not d:
            return False
        qual = qualify(d, scope) if scope else d
        return qual == "threading.Thread" or (
            terminal_name(node.func) == "Thread"
            and qual.startswith("threading")
        )

    def _check_function(self, module, fn, scope) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not (isinstance(call, ast.Call)
                    and self._is_thread_ctor(call, scope)):
                continue
            if not any(kw.arg == "daemon" for kw in call.keywords):
                findings.append(_finding(
                    module, call, self.code,
                    "threading.Thread without explicit daemon=: decide "
                    "whether process exit may orphan or kill this thread",
                ))
            for target in node.targets:
                d = dotted_name(target)
                if d is None:
                    continue
                if d.startswith("self."):
                    cls = _enclosing_class(module, node)
                    joined = _joined_names(cls) if cls is not None else set()
                else:
                    joined = _joined_names(fn)
                if d not in joined:
                    where = ("its owning class" if d.startswith("self.")
                             else "its creating function")
                    findings.append(_finding(
                        module, call, self.code,
                        f"thread `{d}` is never joined in {where}; the "
                        "owner's stop/close path must join it",
                    ))
        return findings


# ---------------------------------------------------------------------------
# JL104 — no blocking while locked
# ---------------------------------------------------------------------------


def _blocking_call_reason(node: ast.Call, scope) -> Optional[str]:
    d = dotted_name(node.func)
    qual = qualify(d, scope) if d and scope else d
    if qual in config.BLOCKING_CALL_QUALNAMES:
        return f"{qual}() blocks"
    if isinstance(node.func, ast.Name) and node.func.id == "open":
        return "file I/O blocks"
    if isinstance(node.func, ast.Attribute):
        # x.join() with zero positional args is a thread join (str.join
        # always takes exactly one); .wait()/.acquire() block outright
        if node.func.attr == "join" and not node.args:
            return ".join() blocks on another thread"
        if node.func.attr in ("wait", "acquire"):
            return f".{node.func.attr}() blocks"
    return None


class NoBlockingWhileLocked:
    """Inside a ``with self._lock:`` region nothing may sleep, join, wait,
    or do file I/O — a blocked lock-holder stalls every thread that needs
    the lock (the heartbeat renew thread starving liveness is the failure
    mode this guards). Checks the lexical with-body plus one level of
    same-module/same-class calls made from it."""

    code = "JL104"
    summary = "blocking call (sleep/join/wait/IO) while holding a lock"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        graph = project.callgraph
        findings: list[Finding] = []
        for module, scan in iter_class_scans(project):
            if not scan.lock_attrs:
                continue
            scope = graph.scopes.get(module.name)
            for fn in scan.methods.values():
                for stmt in ast.walk(fn):
                    if not isinstance(stmt, ast.With):
                        continue
                    if not _lock_ctx_attrs(stmt, "self", scan.lock_attrs):
                        continue
                    findings.extend(self._check_locked_body(
                        module, scan, scope, stmt
                    ))
        return findings

    def _iter_locked_nodes(self, stmt: ast.With):
        todo: list[ast.AST] = list(stmt.body)
        while todo:
            node = todo.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue  # closures run later, outside the lock
            todo.extend(ast.iter_child_nodes(node))

    def _check_locked_body(self, module, scan, scope, stmt) -> list[Finding]:
        findings: list[Finding] = []
        for node in self._iter_locked_nodes(stmt):
            if not isinstance(node, ast.Call):
                continue
            reason = _blocking_call_reason(node, scope)
            if reason is not None:
                findings.append(_finding(
                    module, node, self.code,
                    f"{reason} while `self.{sorted(scan.lock_attrs)[0]}` "
                    "is held; move it outside the critical section",
                ))
                continue
            callee = self._resolve_one_level(node, scan, scope)
            if callee is None:
                continue
            for sub in ast.walk(callee):
                if isinstance(sub, ast.Call):
                    sub_reason = _blocking_call_reason(sub, scope)
                    if sub_reason is not None:
                        findings.append(_finding(
                            module, node, self.code,
                            f"call into `{callee.name}()` {sub_reason} "
                            "(line "
                            f"{getattr(sub, 'lineno', '?')}) while the "
                            "lock is held",
                        ))
                        break
        return findings

    def _resolve_one_level(self, node, scan, scope) -> Optional[FuncNode]:
        if (isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"):
            return scan.methods.get(node.func.attr)
        if isinstance(node.func, ast.Name) and scope is not None:
            info = scope.defs.get(node.func.id)
            if info is not None:
                return info.node
        return None


# ---------------------------------------------------------------------------
# JL105 — injectable time
# ---------------------------------------------------------------------------


class InjectableTime:
    """In liveness/exchange/timing modules, a bare ``time.time()`` /
    ``monotonic()`` / ``perf_counter()`` / ``sleep()`` hard-wires the wall
    clock into logic that the fake-clock test suites must drive
    deterministically. Hold the callable on an injectable attribute
    (``self._clock = time.monotonic`` — a reference, not a call) and call
    that instead."""

    code = "JL105"
    summary = "bare wall-clock call in liveness/timing code"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        graph = project.callgraph
        findings: list[Finding] = []
        for module in project.modules:
            if not module.rel.endswith(config.CLOCKED_MODULE_SUFFIXES):
                continue
            scope = graph.scopes.get(module.name)
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted_name(node.func)
                qual = qualify(d, scope) if d and scope else d
                if qual in config.TIME_CALL_QUALNAMES:
                    findings.append(_finding(
                        module, node, self.code,
                        f"bare {qual}() in a liveness/timing module; use "
                        "an injectable clock/sleep attribute so tests "
                        "control time",
                    ))
        return findings


# ---------------------------------------------------------------------------
# JL106 — callback-thread writes
# ---------------------------------------------------------------------------


def _callback_target_names(project: Project) -> set[str]:
    """Terminal method names registered as jax host callbacks anywhere in
    the project — through a direct reference or a wrapping lambda."""
    graph = project.callgraph
    names: set[str] = set()
    for module in project.modules:
        scope = graph.scopes.get(module.name)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            d = dotted_name(node.func)
            qual = qualify(d, scope) if d and scope else d
            if qual not in config.CALLBACK_QUALNAMES:
                continue
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                for sub in ast.walk(target.body):
                    if isinstance(sub, ast.Call):
                        t = terminal_name(sub.func)
                        if t:
                            names.add(t)
            else:
                t = terminal_name(target)
                if t:
                    names.add(t)
    return names


class CallbackThreadWrites:
    """Methods invoked from ``jax.debug.callback`` run on the runtime's
    callback threads, concurrently with the host loop. Any ``self`` state
    they mutate must be lock-guarded (or the class inline-disables with a
    single-writer justification) — the ShardWindowTimer marker dicts are
    the exemplar surface (DESIGN.md §8)."""

    code = "JL106"
    summary = "callback-thread method mutates state outside a lock"
    family = "concurrency"

    def run(self, project: Project) -> list[Finding]:
        targets = _callback_target_names(project)
        if not targets:
            return []
        findings: list[Finding] = []
        for module, scan in iter_class_scans(project):
            hit_methods = targets & set(scan.methods)
            if not hit_methods:
                continue
            for a in scan.accesses:
                if (a.method in hit_methods and a.write and not a.guarded
                        and a.attr not in scan.primitive_attrs):
                    findings.append(_finding(
                        module, a.node, self.code,
                        f"{scan.node.name}.{a.method}() runs on a jax "
                        f"callback thread but writes self.{a.attr} "
                        "without a lock",
                    ))
        return findings


# ---------------------------------------------------------------------------
# registry (merged into rules.RULES by rules.py)
# ---------------------------------------------------------------------------

RULES: dict[str, type] = {
    r.code: r
    for r in (
        LockDiscipline,
        AtomicPublish,
        ThreadLifecycle,
        NoBlockingWhileLocked,
        InjectableTime,
        CallbackThreadWrites,
    )
}
