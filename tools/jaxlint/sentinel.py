"""RetraceSentinel: the runtime complement to the static rules.

jaxlint's JL rules prove properties of the *source*; the sentinel asserts
the property the paper's hot loop actually depends on at *runtime* — that a
region of code compiles at most ``budget`` new XLA programs (DESIGN.md §6:
steady-state mega-batches and revisited-population resizes must hit the jit
cache, budget 0).

Implementation: jax publishes a ``/jax/core/compile/backend_compile_duration``
monitoring event for every backend compile (cache hits publish nothing), so
counting those events inside the ``with`` block counts fresh compilations —
including ones hidden behind ``shard_map``/``scan`` wrappers that
``trainer.compile_cache_size()`` style cache introspection can miss. The
listener registry lives in ``jax._src.monitoring``; this module is therefore
the one jax-importing part of tools/jaxlint and is deliberately not imported
by the CLI (the CI lint job has no jax).
"""
from __future__ import annotations

from jax._src import monitoring as _monitoring

#: the event jax's pjit/xla_bridge layer records once per backend compile
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class RetraceBudgetExceeded(AssertionError):
    """More fresh compilations happened than the declared budget allows."""


class RetraceSentinel:
    """Count XLA compilations inside a ``with`` block and enforce a budget.

    >>> with RetraceSentinel(budget=0) as sentinel:
    ...     trainer.run_megabatch(state)       # must hit the jit cache
    >>> sentinel.count
    0

    ``budget=None`` only counts (never raises). The check is skipped when
    the body raises, so the sentinel never masks the original failure.
    """

    def __init__(self, budget: int | None = 0, label: str = ""):
        if budget is not None and budget < 0:
            raise ValueError(f"budget must be >= 0 or None, got {budget}")
        if not hasattr(_monitoring, "register_event_duration_secs_listener"):
            raise RuntimeError(
                "this jax build exposes no monitoring-event listener API; "
                "RetraceSentinel cannot count compilations"
            )
        self.budget = budget
        self.label = label
        self.count = 0
        self._active = False

    def _on_event(self, event: str, duration: float, **kwargs) -> None:
        if self._active and event == COMPILE_EVENT:
            self.count += 1

    def __enter__(self) -> "RetraceSentinel":
        self.count = 0
        self._active = True
        _monitoring.register_event_duration_secs_listener(self._on_event)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._active = False
        self._unregister()
        if exc_type is None and self.budget is not None \
                and self.count > self.budget:
            what = f" [{self.label}]" if self.label else ""
            raise RetraceBudgetExceeded(
                f"RetraceSentinel{what}: {self.count} fresh XLA "
                f"compilation(s) inside the guarded block, budget "
                f"{self.budget} — a shape/static-arg change is defeating "
                "the jit cache (DESIGN.md §6)"
            )

    def _unregister(self) -> None:
        unreg = getattr(
            _monitoring, "_unregister_event_duration_listener_by_callback",
            None,
        )
        if unreg is not None:
            unreg(self._on_event)
        else:  # very old/new jax: at worst the dead listener stays inert
            self._active = False
