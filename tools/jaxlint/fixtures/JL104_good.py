"""JL104 good: the critical section only touches memory; sleeps and I/O
happen outside it."""
import threading
import time


class Publisher:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0

    def tick(self):
        with self._lock:
            self._n += 1
        time.sleep(0.1)

    def snapshot(self, path):
        with self._lock:
            n = self._n
        with open(path, "w") as f:
            f.write(str(n))
