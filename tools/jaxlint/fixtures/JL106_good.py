"""JL106 good: the callback-thread methods take the lock around every
marker mutation."""
import threading

import jax


class WindowTimer:
    def __init__(self):
        self._lock = threading.Lock()
        self._t0 = {}
        self._t1 = {}

    def mark_start(self, shard):
        with self._lock:
            self._t0[int(shard)] = 0.0

    def mark_end(self, shard):
        with self._lock:
            self._t1[int(shard)] = 1.0

    def attach(self, x):
        jax.debug.callback(self.mark_start, x)
        jax.debug.callback(self.mark_end, x)
