"""JL105 bad (path-scoped: lives under a liveness-module suffix) —
2 findings: bare wall-clock reads the fake-clock tests cannot drive."""
import time


def lease_age(published_at):
    return time.monotonic() - published_at  # JL105: bare wall clock


def backoff(poll):
    time.sleep(poll)  # JL105: bare sleep
