"""JL006 bad fixture: host callbacks outside the approved timing modules."""
import jax
from jax.experimental import io_callback


def traced(x, timer):
    jax.debug.callback(lambda v: timer.mark(v), x)
    io_callback(lambda v: timer.log(v), None, x)
    return x
