"""JL002 good fixture: static-config branching and lax-style selects."""
import jax.numpy as jnp


def megabatch_fn(replicas, mask, cfg, momentum=None):
    if cfg.weight_decay:                      # static config flag: fine
        replicas = replicas * (1.0 - cfg.weight_decay)
    if momentum is None:                      # structural None check: fine
        momentum = jnp.zeros_like(replicas)
    if replicas.ndim == 3:                    # shape metadata: fine
        replicas = replicas.reshape(replicas.shape[0], -1)
    # data-dependent gating stays on device
    return jnp.where(mask > 0, replicas + momentum, replicas)
