"""JL002 bad fixture: Python control flow branching on tracer values."""
import jax.numpy as jnp


def megabatch_fn(replicas, mask):
    if jnp.any(mask > 0):                     # tracer in an `if` test
        replicas = replicas + 1.0
    gated = replicas if mask.sum() > 0 else replicas * 0.0   # and in IfExp
    while jnp.max(gated) > 1.0:               # and in `while`
        gated = gated * 0.5
    return gated
