"""JL004 good fixture: frozen-dataclass static args."""
from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class RoundTransforms:
    grad_transform: object = None


@dataclass(frozen=True)
class Options:
    depth: int = 2


def fn(x, transforms=None, opts=None):
    return x


jitted = jax.jit(fn, static_argnames=("transforms", "opts"))


def run(x):
    return jitted(x, transforms=RoundTransforms(), opts=Options(depth=3))
