"""JL103 bad — 2 findings on one constructor: implicit daemon-ness and
a self-stored thread no method of the class ever joins."""
import threading


class Runner:
    def __init__(self):
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._run)
        self._thread.start()

    def _run(self):
        pass
