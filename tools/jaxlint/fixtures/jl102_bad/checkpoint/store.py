"""JL102 bad (path-scoped: lives under a publish-module suffix) —
2 findings: an in-place publish and a staged file that never lands."""
import json
import os


def publish_lease(path, payload):
    with open(path, "w") as f:  # JL102: writes the final path in place
        json.dump(payload, f)


def publish_manifest(directory, payload):
    tmp = os.path.join(directory, "manifest.tmp")
    with open(tmp, "w") as f:  # JL102: staged but never os.replace'd
        json.dump(payload, f)
