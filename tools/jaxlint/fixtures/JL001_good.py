"""JL001 good fixture: the same shapes of code, kept on device."""
import jax.numpy as jnp


def helper(x):
    return jnp.asarray(x)          # jnp, not np: stays on device


def round_body(params, grads, lr):
    loss = jnp.mean(grads)
    rank = float(loss.ndim)        # static metadata, not a sync
    width = int(grads.shape[0])    # ditto
    return helper(params), loss * rank * width


def host_report(metrics):
    # NOT reachable from a traced root: host syncs are fine here
    return float(metrics["loss"]), metrics["acc"].item()
