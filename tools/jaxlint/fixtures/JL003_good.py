"""JL003 good fixture: donated buffers are re-bound before any later read."""
import jax


def step(params, grads):
    return params - 0.1 * grads


train_step = jax.jit(step, donate_argnums=(0,))


def run(state, grads):
    state = state.replace(params=train_step(state.params, grads))
    return state.params.sum()      # `state` was re-bound: fresh buffer
