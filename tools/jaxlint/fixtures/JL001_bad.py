"""JL001 bad fixture: host syncs inside the traced surface (never executed,
only parsed by tests)."""
import jax
import jax.numpy as jnp
import numpy as np


def helper(x):
    # reachable from round_body -> traced; np.asarray is a host materialize
    return np.asarray(x)


def round_body(params, grads, lr):
    loss = jnp.mean(grads)
    scale = float(loss)            # host sync on a tracer
    host = loss.item()             # the canonical sync
    pulled = jax.device_get(grads)
    return helper(params), scale, host, pulled
