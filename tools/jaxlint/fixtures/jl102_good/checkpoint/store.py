"""JL102 good: stage to a tmp sibling, publish with one os.replace."""
import json
import os


def publish_lease(path, payload):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)
