"""JL007 bad fixture: payload / restore / state field sets disagree."""
from dataclasses import dataclass

import numpy as np


@dataclass
class ElasticState:
    replicas: object
    momentum: object
    b: np.ndarray
    lr: np.ndarray                 # never serialized -> silently reset
    megabatch_idx: int = 0


class Trainer:
    def checkpoint_payload(self, state):
        tree = {
            "replicas": state.replicas,
            "momentum": state.momentum,
            "b": state.b,
        }
        metadata = {"megabatch_idx": state.megabatch_idx}
        return tree, metadata

    def restore_checkpoint(self, path):
        like = {
            "replicas": None,
            "b": None,             # "momentum" missing from the template
            "extra": None,         # ...and "extra" is never serialized
        }
        tree, meta = load(path, like)
        return ElasticState(
            replicas=tree["replicas"],
            momentum=None,         # tree["momentum"]/tree["b"] never read
            b=np.zeros(1),
            lr=np.zeros(1),
            megabatch_idx=meta["megabatch_idx"],
        )


def load(path, like):
    return like, {}
