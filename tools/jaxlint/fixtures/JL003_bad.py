"""JL003 bad fixture: a donated buffer is read after the donating call."""
import jax


def step(params, grads):
    return params - 0.1 * grads


train_step = jax.jit(step, donate_argnums=(0,))


def run(state, grads):
    new_params = train_step(state.params, grads)
    stale = state.params.sum()     # donated buffer read without re-binding
    return new_params, stale
