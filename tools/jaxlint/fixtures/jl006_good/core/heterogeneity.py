"""JL006 good fixture: the approved timing module may use callbacks (the
path of this file mirrors src/repro/core/heterogeneity.py)."""
import jax


def timed(x, timer):
    jax.debug.callback(lambda v: timer.mark(v), x)
    return x
