"""JL101 bad: half-guarded attrs — 3 findings.

`_count` is written under the lock but read bare; `_status` is shared
with the renew thread but written bare on both sides.
"""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._status = "idle"
        self._thread = None

    def incr(self):
        with self._lock:
            self._count += 1

    def snapshot(self):
        return self._count  # JL101: unguarded read of a guarded-write attr

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        self._status = "running"  # JL101: thread-side write, no lock

    def stop(self):
        self._status = "stopped"  # JL101: host-side write, no lock
        if self._thread is not None:
            self._thread.join()
