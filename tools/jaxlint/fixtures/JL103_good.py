"""JL103 good: explicit daemon=, and the stop path joins the thread."""
import threading


class Runner:
    def __init__(self):
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _run(self):
        pass
