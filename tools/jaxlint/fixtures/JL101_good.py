"""JL101 good: every access to a protected attr holds the lock."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._status = "idle"
        self._thread = None

    def incr(self):
        with self._lock:
            self._count += 1

    def snapshot(self):
        with self._lock:
            return self._count

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        with self._lock:
            self._status = "running"

    def stop(self):
        with self._lock:
            self._status = "stopped"
        if self._thread is not None:
            self._thread.join()
