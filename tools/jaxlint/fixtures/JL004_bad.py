"""JL004 bad fixture: unhashable / mutable static jit args."""
from dataclasses import dataclass

import jax


@dataclass
class RoundTransforms:            # contract class must be frozen
    grad_transform: object = None


@dataclass
class Options:
    depth: int = 2


def fn(x, transforms=None, opts=None):
    return x


jitted = jax.jit(fn, static_argnames=("transforms", "opts"))


def run(x):
    a = jitted(x, opts={"depth": 2})          # dict literal: unhashable
    b = jitted(x, transforms=Options())       # non-frozen dataclass
    return a, b
