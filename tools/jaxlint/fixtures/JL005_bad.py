"""JL005 bad fixture: unregistered dataclass crossing the jit boundary."""
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SparseGrad:                  # no tree_util registration
    rows: jax.Array
    values: jax.Array


def round_body(w, idx, vals):
    g = SparseGrad(rows=idx, values=vals)     # becomes a jit output pytree
    return g


def host_side(idx, vals):
    return jax.tree_util.tree_map(jnp.square, SparseGrad(idx, vals))
