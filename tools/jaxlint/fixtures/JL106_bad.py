"""JL106 bad — 2 findings: methods registered as jax host callbacks
mutate marker dicts without a lock (they run on runtime callback
threads, concurrently with the host loop)."""
import jax


class WindowTimer:
    def __init__(self):
        self._t0 = {}
        self._t1 = {}

    def mark_start(self, shard):
        self._t0[int(shard)] = 0.0  # JL106: callback-thread write, no lock

    def mark_end(self, shard):
        self._t1[int(shard)] = 1.0  # JL106: callback-thread write, no lock

    def attach(self, x):
        jax.debug.callback(self.mark_start, x)
        jax.debug.callback(self.mark_end, x)
