"""JL104 bad — 4 findings: sleep, file I/O, and a thread join inside the
critical section, plus one blocking call reached through a one-level
helper call."""
import threading
import time


def _flush(path):
    with open(path, "w") as f:
        f.write("x")


class Publisher:
    def __init__(self):
        self._lock = threading.Lock()
        self._thread = None
        self._n = 0

    def tick(self):
        with self._lock:
            self._n += 1
            time.sleep(0.1)  # JL104: sleeping with the lock held
            log = open("log.txt", "w")  # JL104: file I/O with the lock held
            log.close()

    def shutdown(self):
        with self._lock:
            self._thread.join()  # JL104: joining a thread with the lock held

    def publish(self, path):
        with self._lock:
            _flush(path)  # JL104: helper does file I/O with the lock held
