"""JL105 good: the clock and sleep are injectable attributes; holding
``time.monotonic`` as a *reference* is fine — calling it bare is not."""
import time


class Liveness:
    def __init__(self, clock=time.monotonic, sleep=time.sleep):
        self._clock = clock
        self._sleep = sleep

    def lease_age(self, published_at):
        return self._clock() - published_at

    def backoff(self, poll):
        self._sleep(poll)
