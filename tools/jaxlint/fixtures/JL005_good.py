"""JL005 good fixture: the pytree dataclass is registered (the repo's
RowSparseGrad pattern)."""
from dataclasses import dataclass

import jax


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class SparseGrad:
    rows: jax.Array
    values: jax.Array

    def tree_flatten(self):
        return (self.rows, self.values), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def round_body(w, idx, vals):
    return SparseGrad(rows=idx, values=vals)
