"""JL007 good fixture: payload, restore template, reads and state fields
all agree (metadata covers the scalar field)."""
from dataclasses import dataclass

import numpy as np


@dataclass
class ElasticState:
    replicas: object
    momentum: object
    b: np.ndarray
    megabatch_idx: int = 0


class Trainer:
    def checkpoint_payload(self, state):
        tree = {
            "replicas": state.replicas,
            "momentum": state.momentum,
            "b": state.b,
        }
        metadata = {"megabatch_idx": state.megabatch_idx}
        return tree, metadata

    def restore_checkpoint(self, path):
        like = {
            "replicas": None,
            "momentum": None,
            "b": None,
        }
        tree, meta = load(path, like)
        return ElasticState(
            replicas=tree["replicas"],
            momentum=tree["momentum"],
            b=np.asarray(tree["b"]),
            megabatch_idx=meta["megabatch_idx"],
        )


def load(path, like):
    return like, {}
