"""jaxlint rules JL001–JL007.

Each rule is a class with a ``code``, a one-line ``summary`` and a
``run(project) -> list[Finding]``; the ``RULES`` registry at the bottom is
what the engine iterates. Rules are generic AST passes — everything
repo-specific (root names, approved modules, donation registry) lives in
config.py so the analysis stays distinguishable from the convention.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from . import config
from .callgraph import (
    CallGraph,
    FuncInfo,
    ModuleScope,
    dotted_name,
    iter_body_nodes,
    terminal_name,
)
from .engine import Finding, Module, Project

# ---------------------------------------------------------------------------
# shared resolution helpers
# ---------------------------------------------------------------------------


def qualify(dotted: str, scope: ModuleScope) -> str:
    """Expand the leading import alias of a dotted path:
    ``np.asarray`` -> ``numpy.asarray``, ``jnp.where`` -> ``jax.numpy.where``,
    ``io_callback`` -> ``jax.experimental.io_callback``."""
    head, _, rest = dotted.partition(".")
    if head in scope.import_mods:
        head = scope.import_mods[head]
    elif head in scope.import_names:
        mod, attr = scope.import_names[head]
        head = f"{mod}.{attr}" if mod else attr
    return f"{head}.{rest}" if rest else head


def _call_qualname(node: ast.Call, scope: ModuleScope) -> Optional[str]:
    d = dotted_name(node.func)
    return qualify(d, scope) if d else None


@dataclasses.dataclass
class ClassInfo:
    module: Module
    node: ast.ClassDef
    is_dataclass: bool
    frozen: bool
    pytree_registered: bool


def _decorator_terminal(dec: ast.expr) -> Optional[str]:
    if isinstance(dec, ast.Call):
        dec = dec.func
    return terminal_name(dec)


def class_index(project: Project) -> dict[str, dict[str, ClassInfo]]:
    """{module name: {class name: ClassInfo}} with dataclass/frozen/pytree
    registration facts. Registration counts via decorator
    (``@jax.tree_util.register_pytree_node_class`` / ``register_dataclass``)
    or a module-level ``register_pytree_node(Cls, ...)`` call."""
    out: dict[str, dict[str, ClassInfo]] = {}
    for module in project.modules:
        classes: dict[str, ClassInfo] = {}
        registered_by_call: set[str] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                t = terminal_name(node.func)
                if t in ("register_pytree_node", "register_dataclass",
                         "register_pytree_with_keys"):
                    for arg in node.args[:1]:
                        if isinstance(arg, ast.Name):
                            registered_by_call.add(arg.id)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            is_dc = frozen = registered = False
            for dec in node.decorator_list:
                t = _decorator_terminal(dec)
                if t == "dataclass":
                    is_dc = True
                    if isinstance(dec, ast.Call):
                        for kw in dec.keywords:
                            if (kw.arg == "frozen"
                                    and isinstance(kw.value, ast.Constant)
                                    and kw.value.value is True):
                                frozen = True
                elif t in ("register_pytree_node_class", "register_dataclass",
                           "register_static"):
                    registered = True
            if node.name in registered_by_call:
                registered = True
            classes[node.name] = ClassInfo(
                module=module, node=node, is_dataclass=is_dc,
                frozen=frozen, pytree_registered=registered,
            )
        out[module.name] = classes
    return out


def resolve_class(
    name_expr: ast.expr, module: Module, graph: CallGraph,
    index: dict[str, dict[str, ClassInfo]],
) -> Optional[ClassInfo]:
    """Resolve ``Cls`` / ``mod.Cls`` to a project class, through imports."""
    scope = graph.scopes.get(module.name)
    if scope is None:
        return None
    if isinstance(name_expr, ast.Name):
        local = index.get(module.name, {}).get(name_expr.id)
        if local is not None:
            return local
        if name_expr.id in scope.import_names:
            mod, attr = scope.import_names[name_expr.id]
            return index.get(mod, {}).get(attr)
    elif isinstance(name_expr, ast.Attribute) and isinstance(name_expr.value, ast.Name):
        target = scope.import_mods.get(name_expr.value.id)
        if target:
            return index.get(target, {}).get(name_expr.attr)
    return None


def _pos(node: ast.AST) -> tuple[int, int]:
    return (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))


def _end_pos(node: ast.AST) -> tuple[int, int]:
    return (
        getattr(node, "end_lineno", getattr(node, "lineno", 0)),
        getattr(node, "end_col_offset", getattr(node, "col_offset", 0)),
    )


def _finding(module: Module, node: ast.AST, code: str, message: str) -> Finding:
    return Finding(
        path=module.rel, line=getattr(node, "lineno", 1),
        col=getattr(node, "col_offset", 0), rule=code, message=message,
    )


# ---------------------------------------------------------------------------
# JL001 — host syncs inside the traced surface
# ---------------------------------------------------------------------------


def _is_static_expr(expr: ast.AST) -> bool:
    """True when an expression is trace-time metadata (shape/rank/dtype math),
    so ``int()``/``float()``/``bool()`` on it is NOT a device sync."""
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Attribute):
        return expr.attr in config.STATIC_SCALAR_ATTRS
    if isinstance(expr, ast.Subscript):
        return _is_static_expr(expr.value)          # x.shape[0]
    if isinstance(expr, ast.Call):
        return terminal_name(expr.func) == "len"
    if isinstance(expr, ast.BinOp):
        return _is_static_expr(expr.left) and _is_static_expr(expr.right)
    if isinstance(expr, ast.UnaryOp):
        return _is_static_expr(expr.operand)
    if isinstance(expr, ast.Compare):
        return _is_static_expr(expr.left) and all(
            _is_static_expr(c) for c in expr.comparators
        )
    return False


class HostSyncInTracedCode:
    code = "JL001"
    summary = "host-sync primitive inside the jit-traced surface"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        findings: list[Finding] = []
        for info in sorted(graph.traced_functions(), key=lambda f: f.qualname):
            scope = graph.scopes[info.module.name]
            for node in iter_body_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                f = self._check_call(node, scope, info)
                if f is not None:
                    findings.append(_finding(info.module, node, self.code, f))
        return findings

    def _check_call(
        self, node: ast.Call, scope: ModuleScope, info: FuncInfo
    ) -> Optional[str]:
        where = f"(traced via {info.qualname})"
        # x.item() — the canonical device sync
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and not node.args and not node.keywords):
            return f".item() forces a host sync {where}"
        qual = _call_qualname(node, scope)
        if qual in ("jax.device_get", "jax.block_until_ready"):
            return f"{qual}() forces a host sync {where}"
        if qual is not None and qual.split(".", 1)[0] == "numpy" \
                and qual.endswith((".asarray", ".array")):
            return (
                f"{qual}() materializes a device value on host {where}; "
                "use jnp inside traced code"
            )
        # float()/int()/bool() on anything that is not static metadata
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and len(node.args) == 1 and not node.keywords
                and not _is_static_expr(node.args[0])):
            return (
                f"{node.func.id}() on a (potential) tracer forces a host "
                f"sync {where}; keep the value on device or branch on "
                "static metadata only"
            )
        return None


# ---------------------------------------------------------------------------
# JL002 — Python control flow on tracer values
# ---------------------------------------------------------------------------


class TracerControlFlow:
    code = "JL002"
    summary = "Python control flow branching on a tracer value"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        findings: list[Finding] = []
        for info in sorted(graph.traced_functions(), key=lambda f: f.qualname):
            scope = graph.scopes[info.module.name]
            for node in iter_body_nodes(info.node):
                test = None
                kind = None
                if isinstance(node, (ast.If, ast.While)):
                    test, kind = node.test, type(node).__name__.lower()
                elif isinstance(node, ast.IfExp):
                    test, kind = node.test, "conditional expression"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                if test is None:
                    continue
                culprit = self._tracer_call_in(test, scope)
                if culprit is not None:
                    findings.append(_finding(
                        info.module, node, self.code,
                        f"{kind} branches on tracer-valued `{culprit}` "
                        f"(traced via {info.qualname}); use lax.cond/"
                        "lax.select/jnp.where",
                    ))
        return findings

    def _tracer_call_in(self, test: ast.AST, scope: ModuleScope) -> Optional[str]:
        for node in ast.walk(test):
            if not isinstance(node, ast.Call):
                continue
            raw = dotted_name(node.func)
            if raw and "." in raw:
                root = raw.split(".", 1)[0]
                if root in scope.import_mods or root in scope.import_names:
                    # a module-level function call: tracer-valued iff jax
                    qual = qualify(raw, scope)
                    if qual.split(".", 1)[0] in config.JAX_MODULE_ROOTS:
                        return qual
                    continue
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in config.REDUCTION_METHOD_NAMES):
                src = raw or f"<expr>.{node.func.attr}"
                return f"{src}()"
        return None


# ---------------------------------------------------------------------------
# JL003 — donated buffers read after the call
# ---------------------------------------------------------------------------


def _donation_map(module: Module) -> dict[str, tuple[int, ...]]:
    """Terminal callable name -> donated positions, from literal
    ``jax.jit(..., donate_argnums=(...))`` assignments in this module plus
    the config registry (for computed donate_argnums)."""
    out: dict[str, tuple[int, ...]] = dict(config.DONATED_CALLABLES)
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and terminal_name(call.func) == "jit"):
            continue
        donated: tuple[int, ...] = ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums" and isinstance(
                kw.value, (ast.Tuple, ast.List)
            ):
                donated = tuple(
                    e.value for e in kw.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)
                )
        if not donated:
            continue
        for target in node.targets:
            t = terminal_name(target)
            if t:
                out[t] = donated
    return out


class DonatedBufferReuse:
    code = "JL003"
    summary = "donated jit buffer read after the donating call"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        findings: list[Finding] = []
        for module in project.modules:
            donated = _donation_map(module)
            for info in graph.funcs.values():
                if info.module is not module:
                    continue
                findings.extend(self._check_function(module, info, donated))
        return findings

    def _check_function(
        self, module: Module, info: FuncInfo, donated: dict[str, tuple[int, ...]]
    ) -> list[Finding]:
        # (call end position, donated arg dotted path, callable name)
        donations: list[tuple[tuple[int, int], str, str]] = []
        body = list(iter_body_nodes(info.node))
        for node in body:
            if not isinstance(node, ast.Call):
                continue
            callee = terminal_name(node.func)
            if callee not in donated:
                continue
            for idx in donated[callee]:
                if idx >= len(node.args):
                    continue
                path = dotted_name(node.args[idx])
                if path:
                    donations.append((_end_pos(node), path, callee))
        if not donations:
            return []

        rebinds: list[tuple[tuple[int, int], str]] = []
        for node in body:
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign, ast.For)):
                targets = [node.target]
            elif isinstance(node, ast.NamedExpr):
                targets = [node.target]
            for t in targets:
                for el in ast.walk(t):
                    d = dotted_name(el)
                    if d:
                        # a rebind takes effect at statement END: in
                        # `x = f(x.a)` the RHS call precedes the bind
                        rebinds.append((_end_pos(node), d))

        findings: list[Finding] = []
        for node in body:
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            if not isinstance(getattr(node, "ctx", None), ast.Load):
                continue
            d = dotted_name(node)
            if d is None:
                continue
            for call_end, path, callee in donations:
                if d != path or _pos(node) <= call_end:
                    continue
                # a rebind of the path (or of any prefix, e.g. the whole
                # `state` object) between the call and this read clears it
                root_prefixes = {path}
                parts = path.split(".")
                for i in range(1, len(parts)):
                    root_prefixes.add(".".join(parts[:i]))
                cleared = any(
                    call_end < rp <= _pos(node) and rd in root_prefixes
                    for rp, rd in rebinds
                )
                if not cleared:
                    findings.append(_finding(
                        module, node, self.code,
                        f"`{d}` was donated to `{callee}()` (its buffer is "
                        "invalid after the call) but is read again without "
                        "re-binding",
                    ))
        return findings


# ---------------------------------------------------------------------------
# JL004 — static jit args must be hashable frozen dataclasses
# ---------------------------------------------------------------------------

_UNHASHABLE_LITERALS = (
    ast.Dict, ast.List, ast.Set, ast.ListComp, ast.SetComp, ast.DictComp,
    ast.GeneratorExp,
)


def _static_argnames(call: ast.Call) -> frozenset[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames" and isinstance(
            kw.value, (ast.Tuple, ast.List)
        ):
            return frozenset(
                e.value for e in kw.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            )
        if kw.arg == "static_argnames" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return frozenset({kw.value.value})
    return frozenset()


def _static_callables(module: Module) -> dict[str, frozenset[str]]:
    """Terminal callable name -> static argnames, from ``x = jax.jit(f,
    static_argnames=...)`` assignments and ``@functools.partial(jax.jit,
    static_argnames=...)`` decorators."""
    out: dict[str, frozenset[str]] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign):
            call = node.value
            if isinstance(call, ast.Call) and terminal_name(call.func) == "jit":
                statics = _static_argnames(call)
                if statics:
                    for target in node.targets:
                        t = terminal_name(target)
                        if t:
                            out[t] = statics
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if (isinstance(dec, ast.Call)
                        and terminal_name(dec.func) == "partial"
                        and any(terminal_name(a) == "jit" for a in dec.args)):
                    statics = _static_argnames(dec)
                    if statics:
                        out[node.name] = statics
    return out


class StaticArgContract:
    code = "JL004"
    summary = "static jit arg is not a hashable frozen dataclass"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        index = class_index(project)
        findings: list[Finding] = []
        # 1. the contract class itself must be a frozen dataclass
        for classes in index.values():
            info = classes.get(config.TRANSFORM_CLASS_NAME)
            if info is None:
                continue
            if not (info.is_dataclass and info.frozen):
                findings.append(_finding(
                    info.module, info.node, self.code,
                    f"{config.TRANSFORM_CLASS_NAME} is passed as a static "
                    "jit arg and must be @dataclass(frozen=True) "
                    "(hashability is the jit cache key)",
                ))
        # 2. values passed for known static argnames at call sites
        for module in project.modules:
            statics = _static_callables(module)
            if not statics:
                continue
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.Call):
                    continue
                callee = terminal_name(node.func)
                if callee not in statics:
                    continue
                for kw in node.keywords:
                    if kw.arg not in statics[callee]:
                        continue
                    findings.extend(self._check_static_value(
                        module, graph, index, callee, kw
                    ))
        return findings

    def _check_static_value(self, module, graph, index, callee, kw):
        if isinstance(kw.value, _UNHASHABLE_LITERALS):
            return [_finding(
                module, kw.value, self.code,
                f"static jit arg `{kw.arg}` of `{callee}()` is an unhashable "
                f"{type(kw.value).__name__.lower()} literal; use a frozen "
                "dataclass or tuple",
            )]
        if isinstance(kw.value, ast.Call):
            cls = resolve_class(kw.value.func, module, graph, index)
            if cls is not None and cls.is_dataclass and not cls.frozen:
                return [_finding(
                    module, kw.value, self.code,
                    f"static jit arg `{kw.arg}` of `{callee}()` is a "
                    f"non-frozen dataclass {cls.node.name}; mutable "
                    "dataclasses are unhashable",
                )]
        return []


# ---------------------------------------------------------------------------
# JL005 — unregistered dataclasses in pytree positions
# ---------------------------------------------------------------------------


class UnregisteredPytreeDataclass:
    code = "JL005"
    summary = "dataclass used as a pytree without tree_util registration"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        index = class_index(project)
        findings: list[Finding] = []
        traced = graph.traced_functions()
        # constructed inside traced code => it crosses the jit boundary as
        # (part of) an output pytree
        for info in sorted(traced, key=lambda f: f.qualname):
            for node in iter_body_nodes(info.node):
                if not isinstance(node, ast.Call):
                    continue
                cls = resolve_class(node.func, info.module, graph, index)
                if cls is not None and cls.is_dataclass \
                        and not cls.pytree_registered:
                    findings.append(_finding(
                        info.module, node, self.code,
                        f"dataclass {cls.node.name} is constructed inside "
                        f"traced code ({info.qualname}) but is not "
                        "registered with jax.tree_util; jit will treat it "
                        "as an opaque leaf",
                    ))
        # passed straight into a tree op anywhere
        for module in project.modules:
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.Call):
                    continue
                if terminal_name(node.func) not in config.TREE_OP_NAMES:
                    continue
                for arg in node.args:
                    if not isinstance(arg, ast.Call):
                        continue
                    cls = resolve_class(arg.func, module, graph, index)
                    if cls is not None and cls.is_dataclass \
                            and not cls.pytree_registered:
                        findings.append(_finding(
                            module, arg, self.code,
                            f"dataclass {cls.node.name} is passed to "
                            f"{terminal_name(node.func)}() without "
                            "jax.tree_util registration",
                        ))
        return findings


# ---------------------------------------------------------------------------
# JL006 — host callbacks outside the approved timing modules
# ---------------------------------------------------------------------------


class CallbackOutsideTimingModules:
    code = "JL006"
    summary = "host callback outside the approved timing modules"

    def run(self, project: Project) -> list[Finding]:
        graph: CallGraph = project.callgraph
        findings: list[Finding] = []
        for module in project.modules:
            if module.rel.endswith(config.APPROVED_CALLBACK_MODULE_SUFFIXES):
                continue
            scope = graph.scopes[module.name]
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.Call):
                    continue
                qual = _call_qualname(node, scope)
                bare = isinstance(node.func, ast.Name) and node.func.id
                hit = (
                    qual in config.CALLBACK_QUALNAMES
                    or (bare and bare in config.CALLBACK_BARE_NAMES
                        and scope.import_names.get(bare, ("",))[0]
                        .startswith("jax"))
                )
                if hit:
                    findings.append(_finding(
                        module, node, self.code,
                        f"{qual or bare}() is a hidden host round-trip; "
                        "host callbacks belong in "
                        f"{', '.join(config.APPROVED_CALLBACK_MODULE_SUFFIXES)} "
                        "(inline-disable with a reason if intentional)",
                    ))
        return findings


# ---------------------------------------------------------------------------
# JL007 — checkpoint payload completeness
# ---------------------------------------------------------------------------


def _dict_literal_keys(func: ast.AST, var: str) -> Optional[set[str]]:
    """Keys of ``var = {...literal...}`` inside ``func`` plus any later
    ``var["k"] = ...`` augmentations; None when no literal assignment."""
    keys: Optional[set[str]] = None
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == var:
                    keys = {
                        k.value for k in node.value.keys
                        if isinstance(k, ast.Constant) and isinstance(k.value, str)
                    }
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name) and t.value.id == var
                        and isinstance(t.slice, ast.Constant)
                        and isinstance(t.slice.value, str)):
                    if keys is not None:
                        keys.add(t.slice.value)
    return keys


def _subscript_reads(func: ast.AST, var_names: tuple[str, ...]) -> set[str]:
    reads: set[str] = set()
    for node in ast.walk(func):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in var_names
                and isinstance(node.ctx, ast.Load)
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)):
            reads.add(node.slice.value)
    return reads


def _dataclass_fields(cls: ast.ClassDef) -> set[str]:
    fields: set[str] = set()
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            fields.add(stmt.target.id)
    return fields


class CheckpointPayloadCompleteness:
    code = "JL007"
    summary = "checkpoint payload/restore/state field sets disagree"

    def run(self, project: Project) -> list[Finding]:
        index = class_index(project)
        findings: list[Finding] = []
        for module in project.modules:
            payload = restore = None
            for node in ast.walk(module.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if node.name == config.CHECKPOINT_PAYLOAD_NAME:
                        payload = node
                    elif node.name == config.CHECKPOINT_RESTORE_NAME:
                        restore = node
            if payload is None or restore is None:
                continue
            findings.extend(self._check_pair(module, index, payload, restore))
        return findings

    def _check_pair(self, module, index, payload, restore):
        findings: list[Finding] = []
        tree_keys = _dict_literal_keys(payload, config.PAYLOAD_TREE_VAR)
        meta_keys = _dict_literal_keys(payload, config.PAYLOAD_META_VAR) or set()
        like_keys = _dict_literal_keys(restore, config.RESTORE_LIKE_VAR)
        if tree_keys is None or like_keys is None:
            return findings  # convention not followed here; nothing to check
        for k in sorted(tree_keys - like_keys):
            findings.append(_finding(
                module, restore, self.code,
                f"payload serializes tree[{k!r}] but "
                f"{config.CHECKPOINT_RESTORE_NAME}'s "
                f"`{config.RESTORE_LIKE_VAR}` template omits it (the loader "
                "will drop it silently)",
            ))
        for k in sorted(like_keys - tree_keys):
            findings.append(_finding(
                module, restore, self.code,
                f"restore template expects tree[{k!r}] but "
                f"{config.CHECKPOINT_PAYLOAD_NAME} never writes it",
            ))
        reads = _subscript_reads(restore, config.RESTORE_TREE_VARS)
        for k in sorted(tree_keys - reads):
            findings.append(_finding(
                module, restore, self.code,
                f"tree[{k!r}] is serialized and loaded but never read in "
                f"{config.CHECKPOINT_RESTORE_NAME} — restored state loses it",
            ))
        state = index.get(module.name, {}).get(config.STATE_CLASS_NAME)
        if state is not None:
            fields = _dataclass_fields(state.node)
            covered = tree_keys | meta_keys | config.STATE_FIELD_EXEMPTIONS
            for k in sorted(fields - covered):
                findings.append(_finding(
                    module, payload, self.code,
                    f"{config.STATE_CLASS_NAME}.{k} is not serialized by "
                    f"{config.CHECKPOINT_PAYLOAD_NAME} (neither tree nor "
                    "metadata) — restores will silently reset it",
                ))
        return findings


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

RULES: dict[str, type] = {
    r.code: r
    for r in (
        HostSyncInTracedCode,
        TracerControlFlow,
        DonatedBufferReuse,
        StaticArgContract,
        UnregisteredPytreeDataclass,
        CallbackOutsideTimingModules,
        CheckpointPayloadCompleteness,
    )
}
for _r in RULES.values():
    _r.family = "jit"

# the concurrency/protocol family (JL101-JL106) registers itself here so
# the engine keeps iterating one registry; concur.py imports the shared
# helpers from this module, which is why the import sits at the bottom
from . import concur  # noqa: E402

RULES.update(concur.RULES)
