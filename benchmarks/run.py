"""Benchmark harness: one benchmark per paper table/figure + the roofline
table from stored dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run              # all, fast settings
  PYTHONPATH=src python -m benchmarks.run --only fig6
  PYTHONPATH=src python -m benchmarks.run --full       # full sweeps
"""
from __future__ import annotations

import argparse
import time
import traceback


def _print_roofline():
    from . import roofline

    rows = roofline.table()
    if not rows:
        print("(no dry-run artifacts in results/dryrun — "
              "run `python -m repro.launch.dryrun` first)")
        return
    hdr = ["arch", "shape", "step", "compute_s", "memory_s",
           "collective_s", "bottleneck", "useful_ratio"]
    print(",".join(hdr))
    for r in rows:
        print(",".join([
            r["arch"], r["shape"], r["step"],
            f"{r['t_compute_s']:.3e}", f"{r['t_memory_s']:.3e}",
            f"{r['t_collective_s']:.3e}", r["bottleneck"],
            f"{r['useful_ratio']:.3f}",
        ]))
    split = {}
    for r in rows:
        split[r["bottleneck"]] = split.get(r["bottleneck"], 0) + 1
    print(f"# {len(rows)} combos; bottleneck split: {split}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="fig6..fig12 | roofline | all")
    ap.add_argument("--full", action="store_true",
                    help="full sweeps (slower); default is fast settings")
    args = ap.parse_args(argv)

    from .paper_figures import ALL_FIGURES

    jobs = {}
    if args.only == "all":
        jobs.update(ALL_FIGURES)
        jobs["roofline"] = None
    elif args.only == "roofline":
        jobs["roofline"] = None
    else:
        jobs[args.only] = ALL_FIGURES[args.only]

    failures = []
    for name, fn in jobs.items():
        t0 = time.perf_counter()
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        try:
            if name == "roofline":
                _print_roofline()
            else:
                fn(fast=not args.full)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s")

    if failures:
        print(f"\n{len(failures)} benchmark(s) FAILED:")
        for n, e in failures:
            print(" ", n, e[:200])
        raise SystemExit(1)
    print("\nAll benchmarks completed.")


if __name__ == "__main__":
    from .envtune import ensure_tuned_env

    ensure_tuned_env()  # allocator/logging tuning; re-execs once if needed
    main()
