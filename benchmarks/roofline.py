"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch x input-shape x mesh) from the stored dry-run artifacts.

    compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes        / (chips * 819e9  B/s)
    collective = collective_bytes / (chips * 50e9 B/s * links)

FLOPs/bytes come from the dry-run's while-trip-count-corrected HLO roll-up
(launch/hlo_analysis.py) — these are WHOLE-PROGRAM totals, so per-chip terms
divide by the device count. Collective bytes are summed over all
all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute result
shapes in the post-SPMD HLO (already per-device shards). Each chip drives
~4 ICI links on the 2D torus but a given collective is typically
bandwidth-bound on one axis => links=2 effective.

Also reports MODEL_FLOPS = 6*N(_active)*D and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste detector).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LINKS = 2.0

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_records(results_dir: str = RESULTS_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_terms(rec: dict) -> dict:
    """Three terms (seconds) + bottleneck + useful-compute ratio for the
    *primary* step of a record (train / prefill / decode)."""
    step_name = {"train": "train", "prefill": "prefill", "decode": "decode"}[
        rec["mode"]
    ]
    step = rec["steps"][step_name]
    chips = step["n_devices"]

    # the post-SPMD HLO is the PER-DEVICE program: its rolled-up FLOPs,
    # HBM bytes and collective shard bytes are already per-chip quantities.
    t_compute = step["flops"] / PEAK_FLOPS
    t_memory = step["hbm_bytes"] / HBM_BW
    # the rolled HBM count uses CPU-backend kernel granularity (far less
    # fusion than the TPU compiler) => upper bound. XLA's own bytes-accessed
    # (while bodies counted once) is the optimistic lower bound.
    t_memory_lb = step.get("xla_bytes_accessed", 0.0) / HBM_BW
    coll_bytes = sum(step["collectives"]["bytes"].values())
    t_coll = coll_bytes / (ICI_BW * ICI_LINKS)

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS = 6*N(_active)*D total (model_flops_per_token includes the
    # x6 fwd+bwd factor for train; serve steps use a fwd-only 2*N factor)
    model_flops = rec["model_flops_per_token"] * rec["tokens_per_step"]
    if rec["mode"] != "train":
        model_flops /= 3.0  # forward-only: 2*N, not 6*N
    model_per_chip = model_flops / chips
    useful = model_per_chip / max(step["flops"], 1.0)

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "step": step_name,
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_lb_s": t_memory_lb,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "model_flops_per_chip": model_per_chip,
        "hlo_flops": step["flops"],
        "useful_ratio": useful,
        "roofline_s": max(terms.values()),
        "collective_counts": step["collectives"]["counts"],
        "collective_bytes": step["collectives"]["bytes"],
        "memory_per_device": step.get("memory", {}),
    }


def table(results_dir: str = RESULTS_DIR, mesh: str = "singlepod"):
    recs = [r for r in load_records(results_dir) if r["mesh"] == mesh]
    rows = [roofline_terms(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod"])
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()

    rows = table(args.dir, args.mesh)
    hdr = ["arch", "shape", "step", "compute_s", "memory_s", "collective_s",
           "bottleneck", "useful_ratio"]
    print(",".join(hdr))
    for r in rows:
        print(",".join([
            r["arch"], r["shape"], r["step"],
            f"{r['t_compute_s']:.3e}", f"{r['t_memory_s']:.3e}",
            f"{r['t_collective_s']:.3e}", r["bottleneck"],
            f"{r['useful_ratio']:.3f}",
        ]))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)
    n_by_bn = {}
    for r in rows:
        n_by_bn[r["bottleneck"]] = n_by_bn.get(r["bottleneck"], 0) + 1
    print(f"\n# {len(rows)} combos on {args.mesh}; bottleneck split: {n_by_bn}")


if __name__ == "__main__":
    main()
