"""Mega-batch engine benchmark: legacy per-round host loop vs the
device-resident scan-fused engine (DESIGN.md §1).

Two measurements, for R in {1, 2, 4}:

* **engine** — round execution isolated: one mega-batch plan is built (and
  its batches fetched) once, then executed repeatedly. A step = one
  lockstep round over R replicas. This is the path the engine replaces, on
  a deliberately dispatch-bound micro workload: per-round compute is kept
  tiny so the measurement exposes per-round dispatch + host-stack + metric
  sync overhead — the regime the paper's accelerators live in, where a
  round is fast and the host loop is the bottleneck.
* **end_to_end** — full ``run_megabatch`` including scheduling and sample
  packing (identical host work for both engines; dilutes the speedup).

Warmup iterations exclude XLA compile time. Emits ``BENCH_engine.json`` at
the repo root so future PRs have a perf trajectory.

  PYTHONPATH=src python -m benchmarks.megabatch_engine
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace

from .common import Workload, build_trainer

REPLICA_SWEEP = (1, 2, 4)
ENGINES = ("legacy_loop", "scan")

# dispatch-bound micro workload: small enough that a round's compute is a
# fraction of the per-round host overhead it is benchmarked against
MICRO = Workload("engine-micro", n_features=256, n_classes=64, avg_nnz=8,
                 avg_labels=3, n_samples=4096, hidden=16)
B_MAX = 16
MEGA_BATCH = 32


def _make_trainer(engine: str, n_replicas: int, overlap: bool = False):
    trainer, _ = build_trainer(
        MICRO,
        algorithm="elastic",       # static plans: fixed n_rounds, no recompiles
        n_replicas=n_replicas,
        mega_batch=MEGA_BATCH,
        b_max=B_MAX,
        engine=engine,
        overlap=overlap,
        seed=0,
    )
    return trainer


def bench_engine_only(engine: str, n_replicas: int, repeats: int,
                      warmup: int = 2) -> dict:
    """Execute one pre-fetched plan repeatedly: pure round-execution rate."""
    trainer = _make_trainer(engine, n_replicas)
    state = trainer.init_state()
    b_slots = trainer.cfg.b_max

    def fetch(i, take):
        payload = trainer.provider.fetch(take, b_slots)
        return payload, trainer.provider.work_units(payload)

    per_rep = max(1, round(MEGA_BATCH * B_MAX / (n_replicas * state.b[0])))
    plan = trainer.scheduler.plan_static(int(state.b[0]), per_rep, fetch_fn=fetch)
    run = (trainer._run_rounds_legacy if engine == "legacy_loop"
           else trainer._run_rounds_scan)

    def step(state):
        # rebind the returned buffers: on TPU/GPU the scan engine DONATES
        # state.replicas/momentum, so reusing the old state would pass
        # deleted arrays on the next call
        replicas, momentum, _, _ = run(state, plan, b_slots, trainer._transforms)
        return replace(state, replicas=replicas, momentum=momentum)

    for _ in range(warmup):
        state = step(state)
    t0 = time.perf_counter()
    for _ in range(repeats):
        state = step(state)
    dt = time.perf_counter() - t0
    rounds = plan.n_rounds * repeats
    return {
        "mode": "engine",
        "engine": engine,
        "n_replicas": n_replicas,
        "rounds": rounds,
        "wall_s": dt,
        "steps_per_s": rounds / dt,
    }


def bench_end_to_end(engine: str, n_replicas: int, n_megabatches: int,
                     warmup: int = 1, overlap: bool = False) -> dict:
    """Full run_megabatch incl. scheduling + sample packing (host-bound).

    With ``overlap`` the scan engine runs its pipelined variant (DESIGN.md
    §8): mega-batch N+1 is staged — lazy fetch, fused pack into the double
    buffer, batched upload — while N executes, with warmup priming the
    pipeline so the timed loop measures steady state.
    """
    trainer = _make_trainer(engine, n_replicas, overlap=overlap)
    state = trainer.init_state()
    for _ in range(warmup):
        state, info = trainer.run_megabatch(state, prefetch=overlap)
    rounds = 0
    t0 = time.perf_counter()
    for i in range(n_megabatches):
        state, info = trainer.run_megabatch(
            state, prefetch=overlap and i + 1 < n_megabatches
        )
        rounds += info["n_rounds"]
    dt = time.perf_counter() - t0
    return {
        "mode": "end_to_end",
        "engine": engine,
        "overlap": overlap,
        "n_replicas": n_replicas,
        "rounds": rounds,
        "wall_s": dt,
        "steps_per_s": rounds / dt,
        "megabatches_per_s": n_megabatches / dt,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=30,
                    help="plan executions per engine (engine-only mode)")
    ap.add_argument("--megabatches", type=int, default=15,
                    help="mega-batches per engine (end-to-end mode)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'mode':<11} {'engine':<12} {'ovl':<4} {'R':>3} {'rounds':>7} "
          f"{'wall_s':>8} {'steps/s':>9}")

    def emit(row):
        rows.append(row)
        ovl = {True: "on", False: "off"}.get(row.get("overlap"), "-")
        print(f"{row['mode']:<11} {row['engine']:<12} {ovl:<4} "
              f"{row['n_replicas']:>3} {row['rounds']:>7} "
              f"{row['wall_s']:>8.3f} {row['steps_per_s']:>9.1f}")

    for R in REPLICA_SWEEP:
        for engine in ENGINES:
            emit(bench_engine_only(engine, R, args.repeats))
            # overlap-off is the sequential oracle; only the scan engine
            # has a pipelined variant
            variants = (False, True) if engine == "scan" else (False,)
            for overlap in variants:
                emit(bench_end_to_end(engine, R, args.megabatches,
                                      overlap=overlap))

    def pick(mode, engine, R, overlap=None):
        for r in rows:
            if (r["mode"] == mode and r["engine"] == engine
                    and r["n_replicas"] == R
                    and (overlap is None or r.get("overlap") is overlap)):
                return r
        raise KeyError((mode, engine, R, overlap))

    speedups = {}
    for R in REPLICA_SWEEP:
        speedups[f"engine_R{R}"] = (
            pick("engine", "scan", R)["steps_per_s"]
            / pick("engine", "legacy_loop", R)["steps_per_s"]
        )
        # end-to-end headline: the shipped configuration (scan + overlap)
        # against the legacy sequential loop
        speedups[f"end_to_end_R{R}"] = (
            pick("end_to_end", "scan", R, overlap=True)["steps_per_s"]
            / pick("end_to_end", "legacy_loop", R, overlap=False)["steps_per_s"]
        )
    for k, v in speedups.items():
        print(f"scan/legacy speedup {k}: {v:.2f}x")

    # overlap pipeline gain: scan overlap-on vs scan overlap-off, same
    # engine, same plan trajectory (bit-identical states)
    overlap_gain = {
        f"R{R}": (
            pick("end_to_end", "scan", R, overlap=True)["steps_per_s"]
            / pick("end_to_end", "scan", R, overlap=False)["steps_per_s"]
        )
        for R in REPLICA_SWEEP
    }
    for k, v in overlap_gain.items():
        print(f"overlap on/off gain {k}: {v:.2f}x")

    out = {
        "benchmark": "megabatch_engine",
        "workload": MICRO.name,
        "b_max": B_MAX,
        "mega_batch": MEGA_BATCH,
        "rows": rows,
        "speedup_steps_per_s": speedups,
        "overlap_gain": overlap_gain,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    from .envtune import ensure_tuned_env

    ensure_tuned_env()  # allocator/logging tuning; re-execs once if needed
    main()
