"""Mega-batch engine benchmark: legacy per-round host loop vs the
device-resident scan-fused engine (DESIGN.md §1).

Two measurements, for R in {1, 2, 4}:

* **engine** — round execution isolated: one mega-batch plan is built (and
  its batches fetched) once, then executed repeatedly. A step = one
  lockstep round over R replicas. This is the path the engine replaces, on
  a deliberately dispatch-bound micro workload: per-round compute is kept
  tiny so the measurement exposes per-round dispatch + host-stack + metric
  sync overhead — the regime the paper's accelerators live in, where a
  round is fast and the host loop is the bottleneck.
* **end_to_end** — full ``run_megabatch`` including scheduling and sample
  packing (identical host work for both engines; dilutes the speedup).

Warmup iterations exclude XLA compile time. Emits ``BENCH_engine.json`` at
the repo root so future PRs have a perf trajectory.

  PYTHONPATH=src python -m benchmarks.megabatch_engine
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace

from .common import Workload, build_trainer

REPLICA_SWEEP = (1, 2, 4)
ENGINES = ("legacy_loop", "scan")

# dispatch-bound micro workload: small enough that a round's compute is a
# fraction of the per-round host overhead it is benchmarked against
MICRO = Workload("engine-micro", n_features=256, n_classes=64, avg_nnz=8,
                 avg_labels=3, n_samples=4096, hidden=16)
B_MAX = 16
MEGA_BATCH = 32


def _make_trainer(engine: str, n_replicas: int):
    trainer, _ = build_trainer(
        MICRO,
        algorithm="elastic",       # static plans: fixed n_rounds, no recompiles
        n_replicas=n_replicas,
        mega_batch=MEGA_BATCH,
        b_max=B_MAX,
        engine=engine,
        seed=0,
    )
    return trainer


def bench_engine_only(engine: str, n_replicas: int, repeats: int,
                      warmup: int = 2) -> dict:
    """Execute one pre-fetched plan repeatedly: pure round-execution rate."""
    trainer = _make_trainer(engine, n_replicas)
    state = trainer.init_state()
    b_slots = trainer.cfg.b_max

    def fetch(i, take):
        payload = trainer.provider.fetch(take, b_slots)
        return payload, trainer.provider.work_units(payload)

    per_rep = max(1, round(MEGA_BATCH * B_MAX / (n_replicas * state.b[0])))
    plan = trainer.scheduler.plan_static(int(state.b[0]), per_rep, fetch_fn=fetch)
    run = (trainer._run_rounds_legacy if engine == "legacy_loop"
           else trainer._run_rounds_scan)

    def step(state):
        # rebind the returned buffers: on TPU/GPU the scan engine DONATES
        # state.replicas/momentum, so reusing the old state would pass
        # deleted arrays on the next call
        replicas, momentum, _, _ = run(state, plan, b_slots, trainer._transforms)
        return replace(state, replicas=replicas, momentum=momentum)

    for _ in range(warmup):
        state = step(state)
    t0 = time.perf_counter()
    for _ in range(repeats):
        state = step(state)
    dt = time.perf_counter() - t0
    rounds = plan.n_rounds * repeats
    return {
        "mode": "engine",
        "engine": engine,
        "n_replicas": n_replicas,
        "rounds": rounds,
        "wall_s": dt,
        "steps_per_s": rounds / dt,
    }


def bench_end_to_end(engine: str, n_replicas: int, n_megabatches: int,
                     warmup: int = 1) -> dict:
    """Full run_megabatch incl. scheduling + sample packing (host-bound)."""
    trainer = _make_trainer(engine, n_replicas)
    state = trainer.init_state()
    for _ in range(warmup):
        state, info = trainer.run_megabatch(state)
    rounds = 0
    t0 = time.perf_counter()
    for _ in range(n_megabatches):
        state, info = trainer.run_megabatch(state)
        rounds += info["n_rounds"]
    dt = time.perf_counter() - t0
    return {
        "mode": "end_to_end",
        "engine": engine,
        "n_replicas": n_replicas,
        "rounds": rounds,
        "wall_s": dt,
        "steps_per_s": rounds / dt,
        "megabatches_per_s": n_megabatches / dt,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=30,
                    help="plan executions per engine (engine-only mode)")
    ap.add_argument("--megabatches", type=int, default=15,
                    help="mega-batches per engine (end-to-end mode)")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'mode':<11} {'engine':<12} {'R':>3} {'rounds':>7} "
          f"{'wall_s':>8} {'steps/s':>9}")
    for R in REPLICA_SWEEP:
        for engine in ENGINES:
            for fn, n in (
                (bench_engine_only, args.repeats),
                (bench_end_to_end, args.megabatches),
            ):
                row = fn(engine, R, n)
                rows.append(row)
                print(f"{row['mode']:<11} {row['engine']:<12} {R:>3} "
                      f"{row['rounds']:>7} {row['wall_s']:>8.3f} "
                      f"{row['steps_per_s']:>9.1f}")

    speedups = {}
    for mode in ("engine", "end_to_end"):
        for R in REPLICA_SWEEP:
            by_eng = {
                r["engine"]: r for r in rows
                if r["n_replicas"] == R and r["mode"] == mode
            }
            speedups[f"{mode}_R{R}"] = (
                by_eng["scan"]["steps_per_s"]
                / by_eng["legacy_loop"]["steps_per_s"]
            )
    for k, v in speedups.items():
        print(f"scan/legacy speedup {k}: {v:.2f}x")

    out = {
        "benchmark": "megabatch_engine",
        "workload": MICRO.name,
        "b_max": B_MAX,
        "mega_batch": MEGA_BATCH,
        "rows": rows,
        "speedup_steps_per_s": speedups,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
