"""Algorithm comparison benchmark: time-to-accuracy for every registered
algorithm on the synthetic XML workload — the paper's headline experiment
(Fig. 6), extended to whatever the core/algorithms registry contains
(currently the paper's Adaptive SGD, the four baselines, and the
ABS-SGD-style ``delayed_sync`` plugin).

Every algorithm runs the same workload under the same heterogeneous
virtual cluster; "time" is the discrete-event virtual clock, so results
are deterministic and hardware-independent. Emits ``BENCH_algorithms.json``
at the repo root so future PRs (and new registered algorithms) have a
comparable trajectory.

``--elastic-schedule "0:4,10:6,15:3"`` (DESIGN.md §6) runs every algorithm
under replica churn — workers joining/leaving at those mega-batch
boundaries — instead of fixed membership, so the elasticity scenario is
benchmarkable head-to-head. Off by default: the committed
``BENCH_algorithms.json`` baseline (and its regression gate) is the
fixed-membership run; churn results default to
``BENCH_algorithms_elastic.json`` so they can never overwrite it, and
``scripts/bench_check.py`` rejects any baseline produced with a schedule.
Algorithms that clamp membership (``single``) follow their resize policy
and run unchanged.

  PYTHONPATH=src python -m benchmarks.algorithms
  PYTHONPATH=src python -m benchmarks.algorithms --megabatches 4   # CI smoke
  PYTHONPATH=src python -m benchmarks.algorithms \
      --elastic-schedule "0:4,10:6,15:3"   # -> BENCH_algorithms_elastic.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import algorithms
from repro.launch.train import parse_elastic_schedule

from .common import AMAZON, fmt, run_one, summarize

# reachable by the averaging algorithms within the default budget on the
# reduced-scale workload, so tta is a measured number, not a dash
TARGET_ACC = 0.3


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--megabatches", type=int, default=20)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--target", type=float, default=TARGET_ACC)
    ap.add_argument("--engine", default="scan")
    ap.add_argument("--elastic-schedule", default="",
                    help="'megabatch:R' list (e.g. '0:4,10:6,15:3'):"
                         " benchmark under replica churn (DESIGN.md §6)."
                         " Default: fixed membership, matching the"
                         " committed baseline")
    ap.add_argument("--out", default=None,
                    help="output json (default BENCH_algorithms.json, or"
                         " BENCH_algorithms_elastic.json under an elastic"
                         " schedule so churn runs never overwrite the"
                         " fixed-membership baseline the bench gate reads)")
    args = ap.parse_args(argv)

    schedule = (
        parse_elastic_schedule(args.elastic_schedule)
        if args.elastic_schedule else None
    )
    if args.out is None:
        args.out = ("BENCH_algorithms_elastic.json" if schedule
                    else "BENCH_algorithms.json")
    if schedule and 0 in schedule:
        args.replicas = schedule[0]

    rows = []
    print(f"{'algorithm':<14} {'best_acc':>9} {'tta(vt)':>9} "
          f"{'mb_to_tgt':>9} {'virtual_time':>12}")
    for algo in algorithms.available():
        mlog = run_one(
            AMAZON,
            n_megabatches=args.megabatches,
            algorithm=algo,
            n_replicas=args.replicas,
            engine=args.engine,
            resize_schedule=schedule,
        )
        s = summarize(mlog, args.target)
        row = {"algorithm": algo, **s}
        rows.append(row)
        print(f"{algo:<14} {fmt(s['best_acc']):>9} {fmt(s['tta']):>9} "
              f"{fmt(s['megabatches_to_target']):>9} "
              f"{fmt(s['virtual_time']):>12}")

    out = {
        "benchmark": "algorithms",
        "workload": AMAZON.name,
        "target_accuracy": args.target,
        "megabatches": args.megabatches,
        "n_replicas": args.replicas,
        "engine": args.engine,
        "elastic_schedule": (
            {str(mb): schedule[mb] for mb in sorted(schedule)}
            if schedule else None
        ),
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
