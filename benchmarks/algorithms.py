"""Algorithm comparison benchmark: time-to-accuracy for every registered
algorithm on the synthetic XML workload — the paper's headline experiment
(Fig. 6), extended to whatever the core/algorithms registry contains
(currently the paper's Adaptive SGD, the four baselines, and the
ABS-SGD-style ``delayed_sync`` plugin).

Every algorithm runs the same workload under the same heterogeneous
virtual cluster; "time" is the discrete-event virtual clock, so results
are deterministic and hardware-independent. Emits ``BENCH_algorithms.json``
at the repo root so future PRs (and new registered algorithms) have a
comparable trajectory.

``--elastic-schedule "0:4,10:6,15:3"`` (DESIGN.md §6) runs every algorithm
under replica churn — workers joining/leaving at those mega-batch
boundaries — instead of fixed membership, so the elasticity scenario is
benchmarkable head-to-head. Off by default: the committed
``BENCH_algorithms.json`` baseline (and its regression gate) is the
fixed-membership run; churn results default to
``BENCH_algorithms_elastic.json`` so they can never overwrite it, and
``scripts/bench_check.py`` rejects any baseline produced with a schedule.
Algorithms that clamp membership (``single``) follow their resize policy
and run unchanged.

The fixed-membership run also measures the *faults* scenario (DESIGN.md
§7): the paper algorithm re-run under a seeded fault script — a NaN-poisoned
replica healed by the trainer's non-finite guard, a crash evicted by the
fleet controller with backoff readmission — with async checkpointing
active. The headline is ``recovery_overhead`` = faulty TTA / clean TTA
(lower is better, 1.0 = faults cost nothing); ``scripts/bench_check.py``
gates it like any other headline metric.

  PYTHONPATH=src python -m benchmarks.algorithms
  PYTHONPATH=src python -m benchmarks.algorithms --megabatches 4   # CI smoke
  PYTHONPATH=src python -m benchmarks.algorithms \
      --elastic-schedule "0:4,10:6,15:3"   # -> BENCH_algorithms_elastic.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.core import algorithms
from repro.launch.train import parse_elastic_schedule

from .common import AMAZON, fmt, run_one, summarize

# reachable by the averaging algorithms within the default budget on the
# reduced-scale workload, so tta is a measured number, not a dash
TARGET_ACC = 0.3


def run_faults_scenario(args, clean: dict) -> dict:
    """Re-run the paper algorithm under the seeded fault script with async
    checkpointing on; headline = faulty TTA / clean TTA (lower is better).
    Deterministic: virtual-clock timing + position-keyed fault draws."""
    from repro.checkpoint.store import CheckpointManager
    from repro.core.fleet import FleetController, parse_fault_spec

    with tempfile.TemporaryDirectory() as ckpt_dir:
        fleet = FleetController(
            injector=parse_fault_spec(args.faults),
            min_replicas=max(2, args.replicas // 2),
            max_replicas=2 * args.replicas,
        )
        mlog = run_one(
            AMAZON,
            n_megabatches=args.megabatches,
            algorithm="adaptive",
            n_replicas=args.replicas,
            engine=args.engine,
            fleet=fleet,
            checkpoint=CheckpointManager(ckpt_dir, every=5),
        )
    s = summarize(mlog, args.target)
    overhead = (
        s["tta"] / clean["tta"]
        if s["tta"] is not None and clean and clean["tta"] else None
    )
    print(f"{'adaptive+faults':<14} {fmt(s['best_acc']):>9} "
          f"{fmt(s['tta']):>9} {fmt(s['megabatches_to_target']):>9} "
          f"{fmt(s['virtual_time']):>12}   "
          f"recovery_overhead={fmt(overhead)} "
          f"fleet_events={len(fleet.events)}")
    return {
        "spec": args.faults,
        "fleet_events": len(fleet.events),
        "clean_tta": clean["tta"] if clean else None,
        "faulty_tta": s["tta"],
        "faulty_best_acc": s["best_acc"],
        "recovery_overhead": overhead,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--megabatches", type=int, default=20)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--target", type=float, default=TARGET_ACC)
    ap.add_argument("--engine", default="scan")
    ap.add_argument("--elastic-schedule", default="",
                    help="'megabatch:R' list (e.g. '0:4,10:6,15:3'):"
                         " benchmark under replica churn (DESIGN.md §6)."
                         " Default: fixed membership, matching the"
                         " committed baseline")
    ap.add_argument("--faults", default="seed=11,3:nan:0,5:crash:1",
                    help="seeded fault script for the recovery-overhead"
                         " scenario (DESIGN.md §7); empty string skips it."
                         " Only runs under fixed membership — the faults"
                         " scenario IS a membership experiment, layering an"
                         " elastic schedule on top would conflate the two")
    ap.add_argument("--out", default=None,
                    help="output json (default BENCH_algorithms.json, or"
                         " BENCH_algorithms_elastic.json under an elastic"
                         " schedule so churn runs never overwrite the"
                         " fixed-membership baseline the bench gate reads)")
    args = ap.parse_args(argv)

    schedule = (
        parse_elastic_schedule(args.elastic_schedule)
        if args.elastic_schedule else None
    )
    if args.out is None:
        args.out = ("BENCH_algorithms_elastic.json" if schedule
                    else "BENCH_algorithms.json")
    if schedule and 0 in schedule:
        args.replicas = schedule[0]

    rows = []
    clean_adaptive = None
    print(f"{'algorithm':<14} {'best_acc':>9} {'tta(vt)':>9} "
          f"{'mb_to_tgt':>9} {'virtual_time':>12}")
    for algo in algorithms.available():
        mlog = run_one(
            AMAZON,
            n_megabatches=args.megabatches,
            algorithm=algo,
            n_replicas=args.replicas,
            engine=args.engine,
            resize_schedule=schedule,
        )
        s = summarize(mlog, args.target)
        row = {"algorithm": algo, **s}
        rows.append(row)
        if algo == "adaptive":
            clean_adaptive = s
        print(f"{algo:<14} {fmt(s['best_acc']):>9} {fmt(s['tta']):>9} "
              f"{fmt(s['megabatches_to_target']):>9} "
              f"{fmt(s['virtual_time']):>12}")

    faults = None
    if args.faults and schedule is None:
        faults = run_faults_scenario(args, clean_adaptive)

    out = {
        "benchmark": "algorithms",
        "workload": AMAZON.name,
        "target_accuracy": args.target,
        "megabatches": args.megabatches,
        "n_replicas": args.replicas,
        "engine": args.engine,
        "elastic_schedule": (
            {str(mb): schedule[mb] for mb in sorted(schedule)}
            if schedule else None
        ),
        "rows": rows,
        "faults": faults,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
