"""Algorithm comparison benchmark: time-to-accuracy for every registered
algorithm on the synthetic XML workload — the paper's headline experiment
(Fig. 6), extended to whatever the core/algorithms registry contains
(currently the paper's Adaptive SGD, the four baselines, and the
ABS-SGD-style ``delayed_sync`` plugin).

Every algorithm runs the same workload under the same heterogeneous
virtual cluster; "time" is the discrete-event virtual clock, so results
are deterministic and hardware-independent. Emits ``BENCH_algorithms.json``
at the repo root so future PRs (and new registered algorithms) have a
comparable trajectory.

  PYTHONPATH=src python -m benchmarks.algorithms
  PYTHONPATH=src python -m benchmarks.algorithms --megabatches 4   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import algorithms

from .common import AMAZON, fmt, run_one, summarize

# reachable by the averaging algorithms within the default budget on the
# reduced-scale workload, so tta is a measured number, not a dash
TARGET_ACC = 0.3


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--megabatches", type=int, default=20)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--target", type=float, default=TARGET_ACC)
    ap.add_argument("--engine", default="scan")
    ap.add_argument("--out", default="BENCH_algorithms.json")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'algorithm':<14} {'best_acc':>9} {'tta(vt)':>9} "
          f"{'mb_to_tgt':>9} {'virtual_time':>12}")
    for algo in algorithms.available():
        mlog = run_one(
            AMAZON,
            n_megabatches=args.megabatches,
            algorithm=algo,
            n_replicas=args.replicas,
            engine=args.engine,
        )
        s = summarize(mlog, args.target)
        row = {"algorithm": algo, **s}
        rows.append(row)
        print(f"{algo:<14} {fmt(s['best_acc']):>9} {fmt(s['tta']):>9} "
              f"{fmt(s['megabatches_to_target']):>9} "
              f"{fmt(s['virtual_time']):>12}")

    out = {
        "benchmark": "algorithms",
        "workload": AMAZON.name,
        "target_accuracy": args.target,
        "megabatches": args.megabatches,
        "n_replicas": args.replicas,
        "engine": args.engine,
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
