"""Sparse-gradient path benchmark: dense autodiff vs the row-sparse path
(DESIGN.md §3), swept over the feature-space size NF.

Three measurements per (path, NF):

* **fwd_bwd** — one gradient computation (value_and_grad of the dense loss
  vs ``loss_and_sparse_grad``). The dense backward materializes the (NF, H)
  d``w1``; the sparse one stops at O(B*K*H) values.
* **fwd_bwd_update** — gradient + ``sgd_update``: the dense update rewrites
  all NF*H parameters, the sparse one scatters ~B*K rows. This is the
  per-round hot path the paper's per-update cost argument is about.
* **end_to_end** — full ``run_megabatch`` on the scan engine (R=4,
  adaptive) with the trainer's ``sparse_grads`` flag on/off.

Both paths use the jnp input layer off-TPU (interpret-mode Pallas would
benchmark the interpreter, not the math); on TPU the same flags route
through the Pallas kernels. Emits ``BENCH_spmm_grad.json`` at the repo root.

  PYTHONPATH=src python -m benchmarks.spmm_grad
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import SparseDataset
from repro.models.xml_mlp import (
    XMLMLPConfig, init_params, loss_and_sparse_grad, loss_fn,
)
from repro.optim.sgd import SGDConfig, sgd_update

NF_SWEEP = (10_000, 50_000, 100_000, 200_000)
B, K, HIDDEN, N_CLASSES, N_LABELS = 64, 64, 64, 512, 4
E2E_NF = (10_000, 100_000)


def _synth_batch(nf: int, rng: np.random.Generator) -> dict:
    """Uniform synthetic padded-COO batch (stats don't matter for perf)."""
    return {
        "feat_idx": jnp.asarray(rng.integers(0, nf, (B, K)), jnp.int32),
        "feat_val": jnp.asarray(rng.gamma(2.0, 0.5, (B, K)), jnp.float32),
        "feat_mask": jnp.asarray(rng.random((B, K)) > 0.1),
        "label_idx": jnp.asarray(
            rng.integers(0, N_CLASSES, (B, N_LABELS)), jnp.int32
        ),
        "label_mask": jnp.asarray(rng.random((B, N_LABELS)) > 0.3),
        "sample_mask": jnp.ones((B,), bool),
    }


def _synth_dataset(nf: int, n_samples: int, rng: np.random.Generator) -> SparseDataset:
    """Uniform-index dataset, cheap to build at NF >= 100k (xml_synth's
    Zipf sampling is O(NF) per draw — too slow for a perf fixture)."""
    nnz = np.clip(rng.lognormal(np.log(K // 2), 0.4, n_samples), 4, K).astype(np.int64)
    indptr = np.concatenate([[0], np.cumsum(nnz)])
    n_lab = np.maximum(1, rng.poisson(N_LABELS, n_samples)).astype(np.int64)
    label_ptr = np.concatenate([[0], np.cumsum(n_lab)])
    return SparseDataset(
        n_features=nf,
        n_classes=N_CLASSES,
        indptr=indptr,
        indices=rng.integers(0, nf, indptr[-1]).astype(np.int32),
        values=rng.gamma(2.0, 0.5, indptr[-1]).astype(np.float32),
        label_ptr=label_ptr,
        labels=rng.integers(0, N_CLASSES, label_ptr[-1]).astype(np.int32),
    )


def _time(fn, repeats: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return time.perf_counter() - t0


ROUNDS = 8  # rounds scanned inside one jit, like the mega-batch engine


def bench_step(nf: int, repeats: int) -> list[dict]:
    """Per-round cost of grad (+ update), measured the way the scan engine
    runs it: ROUNDS rounds inside one ``jax.lax.scan`` so the parameter
    buffer is updated in place (an isolated jit call would have to
    copy-on-write the whole (NF, H) buffer for the scatter and hide the
    sparse win behind memcpy)."""
    cfg = XMLMLPConfig(n_features=nf, n_classes=N_CLASSES, hidden=HIDDEN)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _synth_batch(nf, rng)
    sgd = SGDConfig()

    dense_grad = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )
    def sparse_grad(p):
        return loss_and_sparse_grad(cfg, p, batch)

    def scanned(grad_fn, with_update):
        def body(p, _):
            (loss, _), g = grad_fn(p)
            if with_update:
                p, _ = sgd_update(p, g, 0.1, sgd)
                return p, loss
            # fwd+bwd only: keep grads live via a cheap reduction
            return p, loss + sum(
                jnp.sum(l.astype(jnp.float32))
                for l in jax.tree_util.tree_leaves(g)
            )

        @jax.jit
        def run(p):
            return jax.lax.scan(body, p, None, length=ROUNDS)

        return run

    rows = []
    for mode, with_update in (("fwd_bwd", False), ("fwd_bwd_update", True)):
        for path, grad_fn in (("dense", dense_grad), ("sparse", sparse_grad)):
            run = scanned(grad_fn, with_update)
            dt = _time(lambda: jax.block_until_ready(run(params)), repeats)
            steps = repeats * ROUNDS
            rows.append({
                "mode": mode, "path": path, "nf": nf, "steps": steps,
                "wall_s": dt, "steps_per_s": steps / dt,
            })
    return rows


def bench_end_to_end(nf: int, n_megabatches: int) -> list[dict]:
    rows = []
    for sparse in (False, True):
        ds = _synth_dataset(nf, 4096, np.random.default_rng(1))
        prov = SparseProvider.make(ds, seed=0)
        cfg = ElasticConfig.from_bmax(
            B, algorithm="adaptive", n_replicas=4, mega_batch=8
        )
        tr = ElasticTrainer(
            _make_trainable_model(nf), prov, cfg, base_lr=0.1, seed=0,
            engine="scan", sparse_grads=sparse,
        )
        state = tr.init_state()
        state, _ = tr.run_megabatch(state)  # warmup/compile
        n_rounds = 0
        t0 = time.perf_counter()
        for _ in range(n_megabatches):
            state, info = tr.run_megabatch(state)
            n_rounds += info["n_rounds"]
        dt = time.perf_counter() - t0
        rows.append({
            "mode": "end_to_end", "path": "sparse" if sparse else "dense",
            "nf": nf, "megabatches": n_megabatches, "rounds": n_rounds,
            "wall_s": dt, "megabatches_per_s": n_megabatches / dt,
            "steps_per_s": n_rounds / dt,
        })
    return rows


def _make_trainable_model(nf: int):
    from repro.models.xml_mlp import make_model

    return make_model(XMLMLPConfig(n_features=nf, n_classes=N_CLASSES,
                                   hidden=HIDDEN))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=30)
    ap.add_argument("--megabatches", type=int, default=4)
    ap.add_argument("--out", default="BENCH_spmm_grad.json")
    args = ap.parse_args(argv)

    rows = []
    print(f"{'mode':<16} {'path':<7} {'NF':>8} {'wall_s':>8} {'steps/s':>10}")
    for nf in NF_SWEEP:
        for row in bench_step(nf, args.repeats):
            rows.append(row)
            print(f"{row['mode']:<16} {row['path']:<7} {nf:>8} "
                  f"{row['wall_s']:>8.3f} {row['steps_per_s']:>10.1f}")
    for nf in E2E_NF:
        for row in bench_end_to_end(nf, args.megabatches):
            rows.append(row)
            print(f"{row['mode']:<16} {row['path']:<7} {nf:>8} "
                  f"{row['wall_s']:>8.3f} {row['steps_per_s']:>10.1f}")

    speedups = {}
    for row in rows:
        if row["path"] != "sparse":
            continue
        dense = next(
            r for r in rows
            if r["mode"] == row["mode"] and r["nf"] == row["nf"]
            and r["path"] == "dense"
        )
        speedups[f"{row['mode']}_nf{row['nf']}"] = (
            row["steps_per_s"] / dense["steps_per_s"]
        )
    for k, v in speedups.items():
        print(f"sparse/dense speedup {k}: {v:.2f}x")

    out = {
        "benchmark": "spmm_grad",
        "batch": {"b": B, "k": K, "hidden": HIDDEN, "n_classes": N_CLASSES},
        "backend": jax.default_backend(),
        "rows": rows,
        "speedup_sparse_over_dense": speedups,
    }
    path = os.path.join(os.path.dirname(os.path.dirname(__file__)), args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
