"""One benchmark per paper figure (§5.2). Each prints a CSV block and
returns rows for machine consumption.

Fig. 6  time-to-accuracy: adaptive vs elastic vs sync(TF) vs crossbow x GPUs
Fig. 7  statistical efficiency: accuracy vs mega-batch count
Fig. 8  scalability: adaptive on 1/2/4 workers + SLIDE-proxy CPU baseline
Fig. 9  mega-batch size (merge frequency) sweep
Fig. 10 initial batch size (a) and scaling factor beta (b)
Fig. 11 perturbation threshold (a) and factor delta (b)
Fig. 12 batch-size evolution + perturbation activation frequency
"""
from __future__ import annotations

import numpy as np

from .common import (
    AMAZON, B_MAX, MEGA_BATCH, N_MEGABATCHES, WORKLOADS,
    build_trainer, fmt, run_for_budget, run_one, summarize,
)

TARGETS = {"amazon": 0.35, "delicious": 0.55}
# virtual-second budget per worker count: all algorithms get the same time
# (paper §5.1); chosen so Adaptive completes ~25-30 mega-batches.
BUDGETS = {1: 10.0, 2: 5.0, 4: 2.6}


def _csv(title, header, rows):
    print(f"\n# {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(fmt(x) for x in r))
    return rows


# --------------------------------------------------------------------------


def fig6_time_to_accuracy(fast: bool = False):
    """Adaptive vs baselines, per worker count (paper Fig. 6). Every
    algorithm runs for the SAME virtual-time budget (paper methodology)."""
    rows = []
    gpus = [2, 4] if fast else [1, 2, 4]
    for wname, w in WORKLOADS.items():
        target = TARGETS[wname]
        for algo in ("adaptive", "elastic", "sync", "crossbow"):
            for g in gpus:
                if g == 1 and algo != "adaptive":
                    continue  # paper: all methods coincide at 1 GPU
                seeds = [0] if fast else [0, 1, 2]
                accs, ttas, mbs = [], [], []
                for seed in seeds:
                    mlog = run_for_budget(
                        w, BUDGETS[g],
                        algorithm=algo if g > 1 else "single",
                        n_replicas=g, seed=seed,
                    )
                    s = summarize(mlog, target)
                    accs.append(s["best_acc"])
                    ttas.append(s["tta"] if s["tta"] is not None
                                else float("inf"))
                    mbs.append(len(mlog.records))
                med_tta = float(np.median(ttas))
                rows.append((
                    wname, algo, g, float(np.median(accs)),
                    None if np.isinf(med_tta) else med_tta,
                    float(np.median(mbs)),
                ))
    return _csv(
        "Fig6 time-to-accuracy (equal virtual-time budget; median of seeds)",
        ["dataset", "algorithm", "workers", "best_acc",
         "tta@target", "megabatches_done"],
        rows,
    )


def fig7_statistical_efficiency(fast: bool = False):
    """Accuracy per mega-batch count (paper Fig. 7)."""
    rows = []
    for wname, w in WORKLOADS.items():
        for algo in ("adaptive", "elastic", "sync", "crossbow"):
            mlog = run_one(w, algorithm=algo, n_replicas=4)
            for r in mlog.records:
                if "accuracy" in r:
                    rows.append((wname, algo, r["megabatch"], r["accuracy"]))
    return _csv(
        "Fig7 statistical efficiency (accuracy per mega-batch)",
        ["dataset", "algorithm", "megabatch", "accuracy"],
        rows,
    )


def fig8_scalability(fast: bool = False):
    """Adaptive SGD on 1/2/4 workers + SLIDE-proxy (paper Fig. 8).

    SLIDE proxy: single CPU-speed worker with small batches (= many updates,
    high statistical efficiency, low hardware efficiency). Its virtual clock
    runs at the paper's observed GPU/CPU throughput ratio.
    """
    rows = []
    budget = 6.0  # SAME virtual-time budget for every config (paper Fig. 8)
    for wname, w in WORKLOADS.items():
        target = TARGETS[wname]
        for g in (1, 2, 4):
            mlog = run_for_budget(
                w, budget, max_megabatches=60,
                algorithm="adaptive" if g > 1 else "single", n_replicas=g,
            )
            s = summarize(mlog, target)
            rows.append((wname, f"adaptive-{g}gpu", s["best_acc"], s["tta"],
                         s["megabatches_to_target"]))
        # SLIDE proxy: b = b_max/8 (more updates), 6x slower virtual clock
        trainer, tb = build_trainer(
            w, algorithm="single", n_replicas=1, b_max=B_MAX // 8,
            base_lr=2.0 / 8,
        )
        trainer.cost.work_cost *= 6.0  # CPU/GPU throughput gap
        state = trainer.init_state()
        from repro.utils.logging import MetricsLog
        mlog = MetricsLog()
        for mb in range(60):
            state, info = trainer.run_megabatch(state)
            ev = trainer.evaluate(state.global_model, tb)
            info.update(accuracy=ev["accuracy"], megabatch=mb + 1)
            mlog.append(**info)
            if info["virtual_time"] >= budget:
                break
        s = summarize(mlog, target)
        rows.append((wname, "slide-proxy-cpu", s["best_acc"], s["tta"],
                     s["megabatches_to_target"]))
    return _csv(
        "Fig8 scalability (adaptive x workers vs SLIDE-proxy)",
        ["dataset", "config", "best_acc", "tta", "mb_to_target"],
        rows,
    )


def fig9_megabatch_size(fast: bool = False):
    """Merge-frequency sweep (paper Fig. 9). mega=4 on 4 workers ~= gradient
    aggregation; larger mega-batches amortize merging."""
    rows = []
    sizes = [4, 25, 100] if fast else [4, 10, 25, 50, 100]
    for wname, w in WORKLOADS.items():
        target = TARGETS[wname]
        for mb in sizes:
            # same total samples: adjust number of mega-batches
            n = max(2, int(round(N_MEGABATCHES * MEGA_BATCH / mb)))
            mlog = run_one(w, n_megabatches=n, mega_batch=mb)
            s = summarize(mlog, target)
            rows.append((wname, mb, s["best_acc"], s["tta"],
                         s["virtual_time"]))
    return _csv(
        "Fig9 mega-batch size (merge frequency)",
        ["dataset", "megabatch_batches", "best_acc", "tta", "total_vt"],
        rows,
    )


def fig10_batch_size_and_beta(fast: bool = False):
    """Initial batch size (a) + scaling factor beta (b) (paper Fig. 10)."""
    rows = []
    b_min = B_MAX // 8
    for wname, w in WORKLOADS.items():
        target = TARGETS[wname]
        for b0 in (b_min, B_MAX // 2, B_MAX):
            mlog = run_one(w, b_init=b0)
            s = summarize(mlog, target)
            rows.append((wname, f"b0={b0}", s["best_acc"], s["tta"]))
        for beta in (b_min / 4, b_min / 2, b_min):
            mlog = run_one(w, beta=beta)
            s = summarize(mlog, target)
            rows.append((wname, f"beta={beta}", s["best_acc"], s["tta"]))
    return _csv(
        "Fig10 initial batch size (a) / beta (b)",
        ["dataset", "param", "best_acc", "tta"],
        rows,
    )


def fig11_perturbation(fast: bool = False):
    """Perturbation threshold (a) + factor delta (b) (paper Fig. 11)."""
    rows = []
    for wname, w in WORKLOADS.items():
        target = TARGETS[wname]
        for thr in (0.05, 0.10, 0.20):
            mlog = run_one(w, pert_thr=thr)
            s = summarize(mlog, target)
            freq = np.mean([r["pert_active"] for r in mlog.records])
            rows.append((wname, f"pert_thr={thr}", s["best_acc"], s["tta"],
                         freq))
        # delta=0.0 disables perturbation: quantifies the denormalization
        # drift the paper accepts (sum(alpha) > 1 when u_r != u_s)
        for d in (0.0, 0.05, 0.10, 0.20):
            mlog = run_one(w, delta=d)
            s = summarize(mlog, target)
            freq = np.mean([r["pert_active"] for r in mlog.records])
            rows.append((wname, f"delta={d}", s["best_acc"], s["tta"], freq))
    return _csv(
        "Fig11 perturbation threshold (a) / factor (b)",
        ["dataset", "param", "best_acc", "tta", "pert_freq"],
        rows,
    )


def fig12_activation(fast: bool = False):
    """Batch-size evolution + perturbation activation (paper Fig. 12)."""
    rows = []
    w = AMAZON
    trainer, tb = build_trainer(w, algorithm="adaptive", n_replicas=4)
    state = trainer.init_state()
    for mb in range(N_MEGABATCHES):
        state, info = trainer.run_megabatch(state)
        for i, (b, u) in enumerate(zip(info["b"], info["u"])):
            rows.append((mb + 1, i, b, u, int(info["pert_active"])))
    scaled = sum(
        1 for i in range(0, len(rows), 4)
        if len({r[2] for r in rows[i:i + 4]}) > 1
    )
    pert = sum(rows[i][4] for i in range(0, len(rows), 4))
    n_mb = N_MEGABATCHES
    print(f"# scaling active on {scaled}/{n_mb} mega-batches; "
          f"perturbation on {pert}/{n_mb}")
    return _csv(
        "Fig12 batch-size evolution / perturbation activation",
        ["megabatch", "worker", "b", "u", "pert_active"],
        rows,
    )


ALL_FIGURES = {
    "fig6": fig6_time_to_accuracy,
    "fig7": fig7_statistical_efficiency,
    "fig8": fig8_scalability,
    "fig9": fig9_megabatch_size,
    "fig10": fig10_batch_size_and_beta,
    "fig11": fig11_perturbation,
    "fig12": fig12_activation,
}
