"""Shared benchmark fixtures: one synthetic XML workload + trainer builder.

All paper-figure benchmarks run the same reduced-scale stand-ins for
Amazon-670k / Delicious-200k (data/xml_synth.py keeps the nnz/label
statistics; the spaces are scaled so a figure completes in CPU minutes).
Virtual-cluster timing comes from the discrete-event clock, so
"time-to-accuracy" numbers are deterministic and hardware-independent.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace as dc_replace

import numpy as np

from repro.configs.base import ElasticConfig
from repro.core import algorithms
from repro.core.heterogeneity import SpeedModel
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model
from repro.utils.logging import MetricsLog

BASE_LR = 2.0          # gridded in powers of 10 (paper methodology)
B_MAX = 64
MEGA_BATCH = 25        # batches per mega-batch (paper: 100; scaled w/ data)
N_MEGABATCHES = 20
HET_GAP = 0.32         # paper Fig. 1


@dataclass(frozen=True)
class Workload:
    name: str
    n_features: int
    n_classes: int
    avg_nnz: int
    avg_labels: int
    n_samples: int = 8192
    hidden: int = 64
    seed: int = 0


# reduced-scale stand-ins with the papers' sparsity statistics (Table 1)
AMAZON = Workload("amazon-670k[x0.015]", 2048, 1024, 76, 5)
DELICIOUS = Workload("delicious-200k[x0.003]", 2048, 512, 128, 16)
WORKLOADS = {"amazon": AMAZON, "delicious": DELICIOUS}


@functools.lru_cache(maxsize=4)
def _dataset(w: Workload):
    ds = make_xml_dataset(
        n_samples=w.n_samples, n_features=w.n_features, n_classes=w.n_classes,
        avg_nnz=w.avg_nnz, avg_labels=w.avg_labels, seed=w.seed,
    )
    return train_test_split(ds, test_frac=0.2, seed=w.seed)


def build_trainer(
    w: Workload,
    algorithm: str = "adaptive",
    n_replicas: int = 4,
    mega_batch: int = MEGA_BATCH,
    b_max: int = B_MAX,
    base_lr: float = BASE_LR,
    pert_thr: float = 0.10,
    delta: float = 0.10,
    beta: float | None = None,
    b_init: int | None = None,
    het_gap: float = HET_GAP,
    engine: str = "scan",
    overlap: bool = True,
    seed: int = 0,
):
    train, test = _dataset(w)
    provider = SparseProvider.make(train, seed=seed)
    model = make_model(
        XMLMLPConfig(n_features=w.n_features, n_classes=w.n_classes,
                     hidden=w.hidden)
    )
    n_rep = algorithms.get(algorithm).resolve_n_replicas(n_replicas)
    cfg = ElasticConfig.from_bmax(b_max, algorithm=algorithm,
                                  n_replicas=n_rep, mega_batch=mega_batch)
    if beta is not None:
        cfg = dc_replace(cfg, beta=beta)
    cfg = dc_replace(cfg, pert_thr=pert_thr, delta=delta)
    trainer = ElasticTrainer(
        model=model, provider=provider, cfg=cfg, base_lr=base_lr,
        speed=SpeedModel(n_rep, max_gap=het_gap, seed=seed), seed=seed,
        engine=engine, overlap=overlap,
    )
    if b_init is not None:
        orig = trainer.init_state

        def patched():
            st = orig()
            st.b = np.full(n_rep, float(b_init))
            st.lr = np.full(n_rep, base_lr * b_init / cfg.b_max)
            return st

        trainer.init_state = patched
    test_batches = provider.test_batches(test, b_max, max_samples=768)
    return trainer, test_batches


def run_one(w: Workload, n_megabatches: int = N_MEGABATCHES,
            resize_schedule: dict[int, int] | None = None,
            fleet=None, checkpoint=None, **kw) -> MetricsLog:
    """``resize_schedule`` ({megabatch: R}, DESIGN.md §6) drives workers
    joining/leaving mid-benchmark; None = fixed membership (the committed
    BENCH baselines). ``fleet``/``checkpoint`` (DESIGN.md §7) run the
    benchmark under fault injection / async checkpointing."""
    trainer, test_batches = build_trainer(w, **kw)
    _, mlog = trainer.run(n_megabatches, test_batches=test_batches,
                          resize_schedule=resize_schedule,
                          fleet=fleet, checkpoint=checkpoint)
    return mlog


def run_for_budget(w: Workload, budget_vt: float, max_megabatches: int = 40,
                   **kw) -> MetricsLog:
    """Paper methodology (§5.1): 'we execute every algorithm for the same
    amount of time' — run mega-batches until the virtual clock passes
    ``budget_vt``. Slow algorithms (gradient aggregation) complete fewer
    mega-batches in the budget, exactly as in the paper."""
    trainer, test_batches = build_trainer(w, **kw)
    state = trainer.init_state()
    mlog = MetricsLog()
    for mb in range(max_megabatches):
        state, info = trainer.run_megabatch(state)
        ev = trainer.evaluate(state.global_model, test_batches)
        info.update(accuracy=ev["accuracy"], test_loss=ev["loss"],
                    megabatch=mb + 1)
        mlog.append(**info)
        if info["virtual_time"] >= budget_vt:
            break
    return mlog


def summarize(mlog: MetricsLog, target: float) -> dict:
    return {
        "best_acc": mlog.best("accuracy"),
        "tta": mlog.time_to_accuracy(target),
        "megabatches_to_target": next(
            (r["megabatch"] for r in mlog.records
             if r.get("accuracy", -1) >= target), None,
        ),
        "virtual_time": mlog.records[-1]["virtual_time"],
    }


def fmt(x, nd=4):
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)
