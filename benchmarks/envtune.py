"""Benchmark process-environment tuning (see benchmarks/README.md).

Python's default glibc malloc fragments badly under the host-side staging
pattern (large short-lived NumPy buffers interleaved with tiny scheduler
allocations), and TF/XLA's default logging both costs time and drowns the
benchmark tables. The HomebrewNLP run scripts tune both via the process
environment; we reproduce that here, but self-applied: ``ensure_tuned_env``
re-execs the benchmark process exactly once under the tuned environment so
the allocator and logging settings are in force *before* the runtime loads.

Tuned settings:

* ``LD_PRELOAD=libtcmalloc…`` — gperftools' thread-caching allocator, iff
  the library is installed (no hard dependency; glibc malloc otherwise).
* ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000`` — silence tcmalloc's
  stderr report for large (staging-buffer-sized) allocations.
* ``TF_CPP_MIN_LOG_LEVEL=4`` — suppress TF/XLA C++ logging below FATAL.

``REPRO_BENCH_TUNED=1`` marks an already-tuned process (set by the re-exec,
or by CI jobs that apply the variables at the job level) and prevents loops.
"""
from __future__ import annotations

import glob
import os
import sys

GUARD = "REPRO_BENCH_TUNED"

_TCMALLOC_GLOBS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc*.so*",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc*.so*",
    "/usr/lib64/libtcmalloc*.so*",
    "/usr/lib/libtcmalloc*.so*",
    "/usr/local/lib/libtcmalloc*.so*",
)


def find_tcmalloc() -> str | None:
    """Best installed tcmalloc variant, or None (minimal > full > debug)."""
    hits = [h for pat in _TCMALLOC_GLOBS for h in glob.glob(pat)]
    if not hits:
        return None
    hits.sort(key=lambda p: ("minimal" not in p, "debug" in p, len(p), p))
    return hits[0]


def tuned_env(base: dict | None = None) -> dict:
    """A copy of ``base`` (default: os.environ) with the tuning applied."""
    env = dict(os.environ if base is None else base)
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000")
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    lib = find_tcmalloc()
    if lib is not None and "tcmalloc" not in env.get("LD_PRELOAD", ""):
        prior = env.get("LD_PRELOAD")
        env["LD_PRELOAD"] = f"{lib}:{prior}" if prior else lib
    return env


def ensure_tuned_env() -> None:
    """Re-exec the current process once under the tuned environment.

    Call at the top of a benchmark ``main()`` (before timing anything).
    No-op when the guard variable is already set. The re-exec preserves a
    ``python -m pkg.module`` invocation via ``__main__.__spec__``.
    """
    if os.environ.get(GUARD) == "1":
        return
    env = tuned_env()
    env[GUARD] = "1"
    import __main__

    spec = getattr(__main__, "__spec__", None)
    if spec is not None and spec.name:
        argv = [sys.executable, "-m", spec.name, *sys.argv[1:]]
    else:
        argv = [sys.executable, *sys.argv]
    sys.stdout.flush()
    sys.stderr.flush()
    os.execve(sys.executable, argv, env)
