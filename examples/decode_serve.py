"""Serving example: batched greedy decoding with a KV/SSM cache for three
architecture families (dense GQA, attention-free Mamba2, hybrid Jamba) in
their reduced configurations — the same ``decode_step`` the decode_32k /
long_500k dry-run shapes lower on the production mesh.

Run:  PYTHONPATH=src python examples/decode_serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.models import model as MDL

ARCHES = ["llama3.2-1b", "mamba2-780m", "jamba-1.5-large-398b"]
BATCH, CONTEXT, GEN = 2, 16, 8


def serve(arch: str):
    cfg = ARCHS[arch].reduced()
    params = MDL.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    cache = MDL.init_cache(cfg, BATCH, CONTEXT + GEN)
    step = jax.jit(lambda p, c, t: MDL.decode_step(cfg, p, c, t))

    # prefill by stepping through the prompt
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (BATCH, CONTEXT)), jnp.int32
    )
    logits = None
    for i in range(CONTEXT):
        logits, cache = step(params, cache, prompt[:, i : i + 1])

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(GEN - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    assert toks.shape == (BATCH, GEN)
    assert not bool(jnp.any(jnp.isnan(logits)))
    kinds = {MDL.layer_pattern(cfg)[i][0] for i in range(cfg.n_layers)}
    return toks, (GEN - 1) / dt, kinds


def main():
    print(f"batch={BATCH} context={CONTEXT} generate={GEN}\n")
    for arch in ARCHES:
        toks, sps, kinds = serve(arch)
        print(f"{arch:<24} mixers={sorted(kinds)!s:<18} "
              f"decode {sps:6.1f} steps/s  sample={np.asarray(toks[0, :6])}")
    print("\nAll three families decode through the same serve path "
          "(KV cache for attn, O(1) recurrent state for SSM layers).")


if __name__ == "__main__":
    main()
