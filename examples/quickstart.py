"""Quickstart: train a small sparse-XML MLP with Adaptive SGD on 4 simulated
heterogeneous workers, compare against Elastic SGD, and print the
time-to-accuracy of both — the paper's headline comparison in miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import SpeedModel
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model

N_MEGABATCHES = 12
TARGET_ACC = 0.40
BASE_LR = 2.0  # paper methodology: grid powers of 10, pick best accuracy


def run(algorithm: str):
    ds = make_xml_dataset(
        n_samples=4096, n_features=2048, n_classes=512, avg_nnz=64, seed=0
    )
    train, test = train_test_split(ds, test_frac=0.2, seed=0)
    provider = SparseProvider.make(train, seed=0)
    model = make_model(
        XMLMLPConfig(n_features=ds.n_features, n_classes=ds.n_classes, hidden=128)
    )
    cfg = ElasticConfig.from_bmax(
        64, algorithm=algorithm, n_replicas=4, mega_batch=10
    )
    trainer = ElasticTrainer(
        model=model,
        provider=provider,
        cfg=cfg,
        base_lr=BASE_LR,
        speed=SpeedModel(4, max_gap=0.32, seed=0),  # paper Fig.1: 32% gap
        seed=0,
    )
    test_batches = provider.test_batches(test, 64, max_samples=512)
    _, mlog = trainer.run(N_MEGABATCHES, test_batches=test_batches, verbose=True)
    return mlog


def main():
    results = {}
    for algo in ("adaptive", "elastic"):
        print(f"\n=== {algo} SGD ===")
        mlog = run(algo)
        tta = mlog.time_to_accuracy(TARGET_ACC)
        best = mlog.best("accuracy")
        results[algo] = (tta, best)
        print(f"{algo}: best accuracy {best:.4f}, "
              f"time-to-{TARGET_ACC:.0%} = {tta if tta is not None else 'not reached'}")

    a, e = results["adaptive"], results["elastic"]
    print("\n=== summary (virtual heterogeneous-cluster seconds) ===")
    print(f"adaptive: tta={a[0]}, best={a[1]:.4f}")
    print(f"elastic : tta={e[0]}, best={e[1]:.4f}")
    if a[0] is not None and (e[0] is None or a[0] <= e[0]):
        print("Adaptive SGD reaches the target at least as fast — "
              "the paper's Figure 6 effect.")


if __name__ == "__main__":
    main()
