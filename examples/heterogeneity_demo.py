"""Heterogeneity demo: visualize (in text) the paper's core mechanism.

Simulates a 4-worker cluster with a 32% fastest/slowest speed gap (paper
Fig. 1) and shows, mega-batch by mega-batch:
  * per-worker update counts u_i converging as batch-size scaling (Alg. 1)
    re-balances work,
  * per-worker batch sizes b_i diverging to match speeds,
  * merge weights alpha_i and perturbation activations (Alg. 2),
reproducing the paper's Figure 12 behaviour.

Run:  PYTHONPATH=src python examples/heterogeneity_demo.py
"""
import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import SpeedModel
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model


def bar(x, lo, hi, width=24):
    n = int((x - lo) / max(hi - lo, 1e-9) * width)
    return "#" * max(0, min(n, width))


def main():
    R = 4
    ds = make_xml_dataset(
        n_samples=8192, n_features=1024, n_classes=256, avg_nnz=48, seed=1
    )
    train, test = train_test_split(ds, test_frac=0.2, seed=1)
    provider = SparseProvider.make(train, seed=1)
    model = make_model(
        XMLMLPConfig(n_features=ds.n_features, n_classes=ds.n_classes, hidden=64)
    )
    # mega-batch of 50 batches: enough dispatch resolution for the 32% speed
    # gap to show up as different update counts (paper uses 100)
    cfg = ElasticConfig.from_bmax(64, algorithm="adaptive", n_replicas=R,
                                  mega_batch=50)
    speed = SpeedModel(R, max_gap=0.32, jitter=0.05, seed=1)
    print("simulated worker speeds (relative):",
          np.round(1.0 / speed.factors, 3))

    trainer = ElasticTrainer(model=model, provider=provider, cfg=cfg,
                             base_lr=1.0, speed=speed, seed=1)
    state = trainer.init_state()
    print(f"\n{'mb':>3} {'worker':>6} {'u_i':>4} {'b_i':>6} {'alpha':>7}  "
          f"{'batch-size bar':<26} pert")
    for mb in range(10):
        state, info = trainer.run_megabatch(state)
        for i in range(R):
            print(f"{mb:>3} {i:>6} {info['u'][i]:>4} {info['b'][i]:>6.1f} "
                  f"{info['alphas'][i]:>7.4f}  "
                  f"|{bar(info['b'][i], cfg.b_min, cfg.b_max):<24}| "
                  f"{'*' if info['pert_active'] else ''}")
        spread = max(info["u"]) - min(info["u"])
        print(f"    update-count spread: {spread}   "
              f"(goal: 0 = same time horizon)")
    print("\nBatch sizes have adapted so faster workers take bigger batches;")
    print("update counts converge -> replicas merge on the same time horizon.")


if __name__ == "__main__":
    main()
