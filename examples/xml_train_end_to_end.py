"""End-to-end driver: train a ~100M-parameter sparse XML MLP for a few
hundred steps with Adaptive SGD on simulated heterogeneous workers.

The model mirrors the paper's SLIDE testbed at Amazon-670k-like scale:
  sparse input layer (n_features x hidden) -> ReLU -> softmax over classes.
With n_features=135,909-shaped-down, n_classes=670,091-scaled and
hidden=128, parameter count = (F + C) * H ~= 1e8 at scale 1.0. Default runs
at scale 0.12 (~12M params, CPU-friendly); pass --scale 1.0 on a real
machine for the full ~100M.

Run:  PYTHONPATH=src python examples/xml_train_end_to_end.py [--scale 0.12]
"""
import argparse
import time

import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import SpeedModel
from repro.core.trainer import ElasticTrainer
from repro.data.providers import SparseProvider
from repro.data.sparse import train_test_split
from repro.data.xml_synth import AMAZON_670K, make_xml_dataset
from repro.models.xml_mlp import XMLMLPConfig, make_model
from repro.optim.sgd import SGDConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12,
                    help="fraction of Amazon-670k feature/label spaces")
    ap.add_argument("--samples", type=int, default=16384)
    ap.add_argument("--megabatches", type=int, default=12)
    ap.add_argument("--mega-batch", type=int, default=25,
                    help="batches per mega-batch")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--b-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    nf = max(512, int(AMAZON_670K["n_features"] * args.scale))
    nc = max(128, int(AMAZON_670K["n_classes"] * args.scale))
    hidden = 128
    n_params = (nf + nc) * hidden + hidden + nc
    print(f"dataset: features={nf} classes={nc} (Amazon-670k x {args.scale})")
    print(f"model: 3-layer MLP hidden={hidden}, {n_params/1e6:.1f}M params")

    t0 = time.perf_counter()
    ds = make_xml_dataset(
        n_samples=args.samples, n_features=nf, n_classes=nc,
        avg_nnz=AMAZON_670K["avg_nnz"],
        avg_labels=AMAZON_670K["avg_labels"], seed=args.seed,
    )
    train, test = train_test_split(ds, test_frac=0.15, seed=args.seed)
    print(f"generated {ds.n_samples} samples "
          f"(avg nnz {ds.avg_nnz():.0f}) in {time.perf_counter()-t0:.1f}s")

    provider = SparseProvider.make(train, seed=args.seed)
    model = make_model(XMLMLPConfig(n_features=nf, n_classes=nc, hidden=hidden))
    cfg = ElasticConfig.from_bmax(
        args.b_max, algorithm="adaptive",
        n_replicas=args.replicas, mega_batch=args.mega_batch,
    )
    trainer = ElasticTrainer(
        model=model, provider=provider, cfg=cfg,
        sgd=SGDConfig(), base_lr=2.0,  # gridded per paper methodology
        speed=SpeedModel(args.replicas, max_gap=0.32, seed=args.seed),
        seed=args.seed,
    )
    test_batches = provider.test_batches(test, args.b_max, max_samples=1024)

    total_steps = 0
    state, mlog = trainer.run(
        args.megabatches, test_batches=test_batches, verbose=True
    )
    total_steps = sum(sum(r["u"]) for r in mlog.records)
    best = mlog.best("accuracy")
    print(f"\ntrained {total_steps} SGD steps across {args.replicas} workers "
          f"in {args.megabatches} mega-batches")
    print(f"best test top-1 accuracy: {best:.4f}")
    print(f"final batch sizes: {mlog.records[-1]['b']} "
          f"(adaptive, started at {float(args.b_max)})")
    print(f"perturbation active on "
          f"{sum(r['pert_active'] for r in mlog.records)}/{len(mlog.records)} merges")


if __name__ == "__main__":
    main()
