"""Pure-jnp oracle for the padded-COO sparse input layer (SpMM).

h[b, :] = sum_k  mask[b,k] * val[b,k] * W[idx[b,k], :]

This is the gather formulation of the paper's cuSPARSE SpMM over libSVM
batches (XML input layer). Accumulates in f32.
"""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ref(feat_idx, feat_val, feat_mask, w):
    rows = w[feat_idx].astype(jnp.float32)                     # (B, K, H)
    scale = (feat_val * feat_mask).astype(jnp.float32)[..., None]
    return jnp.sum(rows * scale, axis=1).astype(w.dtype)       # (B, H)


def spmm_grad_w_ref(feat_idx, feat_val, feat_mask, dh, n_rows):
    """Transpose of spmm_ref: dW[r] = sum_{idx[b,k]=r} scale[b,k]*dh[b]."""
    b, k = feat_idx.shape
    scale = (feat_val * feat_mask).astype(jnp.float32)         # (B, K)
    vals = scale[..., None] * dh.astype(jnp.float32)[:, None, :]
    h = dh.shape[1]
    return (
        jnp.zeros((n_rows, h), jnp.float32)
        .at[feat_idx.reshape(-1)]
        .add(vals.reshape(b * k, h))
    )


def spmm_grad_val_ref(feat_idx, feat_mask, w, dh):
    """d feat_val[b,k] = mask[b,k] * <dh[b], W[idx[b,k]]>."""
    rows = w[feat_idx].astype(jnp.float32)                     # (B, K, H)
    dv = jnp.einsum("bkh,bh->bk", rows, dh.astype(jnp.float32))
    return dv * feat_mask
