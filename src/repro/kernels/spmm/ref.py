"""Pure-jnp oracle for the padded-COO sparse input layer (SpMM).

h[b, :] = sum_k  mask[b,k] * val[b,k] * W[idx[b,k], :]

This is the gather formulation of the paper's cuSPARSE SpMM over libSVM
batches (XML input layer). Accumulates in f32.
"""
from __future__ import annotations

import jax.numpy as jnp


def spmm_ref(feat_idx, feat_val, feat_mask, w):
    rows = w[feat_idx].astype(jnp.float32)                     # (B, K, H)
    scale = (feat_val * feat_mask).astype(jnp.float32)[..., None]
    return jnp.sum(rows * scale, axis=1).astype(w.dtype)       # (B, H)
