"""Pallas TPU kernel: padded-COO batch SpMM (the paper's sparse input layer).

GPU algorithm (cuSPARSE CSR SpMM) does not transfer to TPU: there is no
sparse unit, and warp-level row decomposition has no analogue. The
TPU-native formulation (DESIGN.md §2) is **scalar-prefetch driven row
gather + dense accumulate**, K-blocked:

  * ``feat_idx`` is a *scalar-prefetch* operand (SMEM): the BlockSpec
    index_maps of the W operands read it to drive the HBM->VMEM DMA of
    exactly the embedding rows each grid step needs — the TPU analogue of
    cuSPARSE's indexed loads, with the DMA pipelined by the Pallas grid.
  * grid = (B, K/block_k, H_blocks): for sample b and nnz slots
    [kb*block_k, (kb+1)*block_k), gather ``block_k`` rows of W — the same
    array is passed ``block_k`` times, operand j's index_map selecting row
    ``idx[b, kb*block_k + j]`` — and accumulate ``sum_j val_j*mask_j*row_j``
    into out[b] in VMEM (f32). Blocking the K dimension cuts grid steps
    (and per-step DMA setup / grid bookkeeping) by ``block_k``x versus the
    one-row-per-step formulation; the ``block_k`` row DMAs of one step are
    issued together and overlap.
  * The accumulator tile is revisited across the K dimension (out index_map
    ignores kb), so it stays resident in VMEM for the whole inner loop —
    only the W rows move.

Zero-padding slots contribute 0 via the mask; idx of padded slots may be
anything in range (the gathered row is multiplied by 0). K is padded up to
a multiple of ``block_k`` with zero-scale slots.

The **backward** (DESIGN.md §3) is the transpose: ``spmm_grad_w`` is a
scatter-add of ``scale[b,k] * dh[b]`` into the gathered rows. Write
conflicts (the same embedding row touched by many (b, k) slots) are handled
by sorting the flattened nnz slots by row id first, so all updates to one
output row occupy *consecutive* grid steps and the f32 accumulator tile
stays resident in VMEM for exactly the run of that row — the out index_map
revisits a block only consecutively, which is the one revisit pattern the
Pallas pipeline guarantees. Rows never touched keep the zeros of the
aliased initializer (``input_output_aliases``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_H = 512
DEFAULT_BLOCK_K = 8


def _make_kblocked_kernel(block_k: int):
    def kernel(idx_ref, scale_ref, *refs):
        """Grid (B, K/block_k, nH). idx_ref is scalar-prefetched (SMEM, (B, K));
        refs = block_k gathered W rows (each (1, BH)) + the out tile."""
        w_refs, out_ref = refs[:-1], refs[-1]
        kb = pl.program_id(1)

        @pl.when(kb == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        acc = jnp.zeros(out_ref.shape, jnp.float32)
        for j in range(block_k):                      # unrolled VMEM accumulate
            s = scale_ref[0, j, 0]                    # val*mask for (b, kb*bk+j)
            acc += s * w_refs[j][...].astype(jnp.float32)
        out_ref[...] += acc.astype(out_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_h", "block_k", "interpret")
)
def spmm(
    feat_idx: jax.Array,    # (B, K) int32
    feat_val: jax.Array,    # (B, K) float
    feat_mask: jax.Array,   # (B, K) bool
    w: jax.Array,           # (NF, H)
    *,
    block_h: int = DEFAULT_BLOCK_H,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, k = feat_idx.shape
    nf, h = w.shape
    block_h = min(block_h, h)
    pad_h = (-h) % block_h
    if pad_h:
        w = jnp.pad(w, ((0, 0), (0, pad_h)))
    hp = h + pad_h
    block_k = max(1, min(block_k, k))
    pad_k = (-k) % block_k
    scale = (feat_val * feat_mask).astype(jnp.float32)[..., None]  # (B, K, 1)
    if pad_k:  # zero-scale slots: gathered row 0 is multiplied by 0
        feat_idx = jnp.pad(feat_idx, ((0, 0), (0, pad_k)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_k), (0, 0)))
    kp = k + pad_k

    grid = (b, kp // block_k, hp // block_h)

    def w_spec(j):
        return pl.BlockSpec(
            (1, block_h), lambda bi, ki, hi, idx, j=j: (idx[bi, ki * block_k + j], hi)
        )

    out = pl.pallas_call(
        _make_kblocked_kernel(block_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_k, 1), lambda bi, ki, hi, idx: (bi, ki, 0)),
                # W rows selected by the prefetched indices — this is the gather
                *[w_spec(j) for j in range(block_k)],
            ],
            out_specs=pl.BlockSpec((1, block_h), lambda bi, ki, hi, idx: (bi, hi)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, hp), jnp.float32),
        interpret=interpret,
    )(feat_idx.astype(jnp.int32), scale, *([w] * block_k))
    return out[:, :h].astype(w.dtype)


# --------------------------------------------------------------------------
# backward: dW scatter-add (sorted formulation, DESIGN.md §3)
# --------------------------------------------------------------------------


def _grad_w_kernel(rows_ref, samp_ref, scale_ref, dh_ref, init_ref, out_ref):
    """Grid (nH, S): for sorted nnz slot si, accumulate scale*dh[sample] into
    the out row ``rows[si]``. rows/samp are scalar-prefetched (SMEM); the out
    tile is revisited (and stays in VMEM) for the whole run of equal rows."""
    del init_ref  # aliased to out: only its zeros for untouched rows matter
    si = pl.program_id(1)
    prev = rows_ref[jnp.maximum(si - 1, 0)]

    @pl.when((si == 0) | (rows_ref[si] != prev))
    def _start_row_run():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += scale_ref[0, 0] * dh_ref[...].astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("n_rows", "block_h", "interpret")
)
def spmm_grad_w(
    feat_idx: jax.Array,    # (B, K) int32
    feat_val: jax.Array,    # (B, K) float
    feat_mask: jax.Array,   # (B, K) bool
    dh: jax.Array,          # (B, H) cotangent of the spmm output
    n_rows: int,            # NF
    *,
    block_h: int = DEFAULT_BLOCK_H,
    interpret: bool = False,
) -> jax.Array:
    """dW[r] = sum_{(b,k): idx[b,k]=r} val[b,k]*mask[b,k]*dh[b]. Returns
    (NF, H) f32. Sorting the S = B*K slots by row id makes duplicate-row
    updates consecutive (write-conflict handling); zero-scale (masked /
    padded) slots scatter 0 wherever their idx points, so no sentinel is
    needed and every index stays in range."""
    b, k = feat_idx.shape
    s = b * k
    h = dh.shape[1]
    flat = feat_idx.reshape(s).astype(jnp.int32)
    order = jnp.argsort(flat)
    rows_s = flat[order]
    samp_s = (order // k).astype(jnp.int32)
    scale = (feat_val * feat_mask).astype(jnp.float32).reshape(s)
    scale_s = scale[order].reshape(s, 1)

    block_h = min(block_h, h)
    pad_h = (-h) % block_h
    dh32 = dh.astype(jnp.float32)
    if pad_h:
        dh32 = jnp.pad(dh32, ((0, 0), (0, pad_h)))
    hp = h + pad_h
    init = jnp.zeros((n_rows, hp), jnp.float32)

    out = pl.pallas_call(
        _grad_w_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # rows_s, samp_s
            grid=(hp // block_h, s),
            in_specs=[
                pl.BlockSpec((1, 1), lambda hi, si, rows, samp: (si, 0)),
                # dh row of the sample owning slot si — prefetch-driven gather
                pl.BlockSpec(
                    (1, block_h), lambda hi, si, rows, samp: (samp[si], hi)
                ),
                # zero initializer, aliased to the output buffer; ANY = no
                # per-step DMA — only its (aliased) HBM zeros matter
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (1, block_h), lambda hi, si, rows, samp: (rows[si], hi)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((n_rows, hp), jnp.float32),
        input_output_aliases={4: 0},  # init (input 4, after the 2 prefetch + 2 ops)
        interpret=interpret,
    )(rows_s, samp_s, scale_s, dh32, init)
    return out[:, :h]
