"""Pallas TPU kernel: padded-COO batch SpMM (the paper's sparse input layer).

GPU algorithm (cuSPARSE CSR SpMM) does not transfer to TPU: there is no
sparse unit, and warp-level row decomposition has no analogue. The
TPU-native formulation (DESIGN.md §2) is **scalar-prefetch driven row
gather + dense accumulate**:

  * ``feat_idx`` is a *scalar-prefetch* operand (SMEM): the BlockSpec
    index_map of W reads it to drive the HBM->VMEM DMA of exactly the one
    embedding row each grid step needs — the TPU analogue of cuSPARSE's
    indexed loads, with the DMA pipelined by the Pallas grid.
  * grid = (B, K, H_blocks): for sample b and nnz slot k, fetch row
    W[idx[b,k]] one (1, block_h) tile at a time and accumulate
    ``val * mask * row`` into out[b] in VMEM (f32). The accumulator tile is
    revisited across the K dimension (out index_map ignores k), so it stays
    resident in VMEM for the whole inner loop — only the W row moves.

Zero-padding slots contribute 0 via the mask; idx of padded slots may be
anything in range (the gathered row is multiplied by 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_H = 512


def _spmm_kernel(idx_ref, scale_ref, w_ref, out_ref):
    """Grid (B, K, nH). idx_ref is scalar-prefetched (SMEM, (B, K))."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    b = pl.program_id(0)
    s = scale_ref[0, 0]                     # val*mask for (b, k), f32
    row = w_ref[...].astype(jnp.float32)    # (1, BH) — row idx[b,k]
    out_ref[...] += (s * row).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_h", "interpret")
)
def spmm(
    feat_idx: jax.Array,    # (B, K) int32
    feat_val: jax.Array,    # (B, K) float
    feat_mask: jax.Array,   # (B, K) bool
    w: jax.Array,           # (NF, H)
    *,
    block_h: int = DEFAULT_BLOCK_H,
    interpret: bool = False,
) -> jax.Array:
    b, k = feat_idx.shape
    nf, h = w.shape
    block_h = min(block_h, h)
    pad_h = (-h) % block_h
    if pad_h:
        w = jnp.pad(w, ((0, 0), (0, pad_h)))
    hp = h + pad_h
    scale = (feat_val * feat_mask).astype(jnp.float32)[..., None]  # (B, K, 1)

    grid = (b, k, hp // block_h)

    out = pl.pallas_call(
        _spmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1), lambda bi, ki, hi, idx: (bi, ki, 0)),
                # W row selected by the prefetched index — this is the gather
                pl.BlockSpec(
                    (1, block_h), lambda bi, ki, hi, idx: (idx[bi, ki], hi)
                ),
            ],
            out_specs=pl.BlockSpec((1, block_h), lambda bi, ki, hi, idx: (bi, hi)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, hp), jnp.float32),
        interpret=interpret,
    )(feat_idx.astype(jnp.int32), scale, w)
    return out[:, :h].astype(w.dtype)
