"""Public entrypoint for the SpMM kernel (sparse XML input layer)."""
from __future__ import annotations

import jax

from .spmm import spmm as _spmm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm(feat_idx, feat_val, feat_mask, w, block_h: int = 512, block_k: int = 8):
    """Padded-COO batch x dense W. Returns (B, H) in W's dtype.

    ``block_k`` = embedding rows gathered per grid step (DESIGN.md §2:
    K-blocked gather; 1 recovers the one-row-per-step formulation)."""
    return _spmm_kernel(
        feat_idx, feat_val, feat_mask, w,
        block_h=block_h, block_k=block_k, interpret=not _on_tpu(),
    )
