"""Public entrypoint for the SpMM kernel (sparse XML input layer)."""
from __future__ import annotations

import jax

from .spmm import spmm as _spmm_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spmm(feat_idx, feat_val, feat_mask, w, block_h: int = 512):
    """Padded-COO batch x dense W. Returns (B, H) in W's dtype."""
    return _spmm_kernel(
        feat_idx, feat_val, feat_mask, w,
        block_h=block_h, interpret=not _on_tpu(),
    )
