"""Public entrypoint for the SpMM kernel (sparse XML input layer).

``spmm`` carries a ``jax.custom_vjp``: the forward is the scalar-prefetch
row-gather kernel (spmm.py) and the backward is the sorted scatter-add
kernel ``spmm_grad_w`` plus the cheap d``feat_val`` gather-dot — both sides
of the paper's "SpMM + its transpose dominate per-update cost" observation
run TPU-native (DESIGN.md §2/§3). ``feat_idx``/``feat_mask`` are integral
and get symbolic-zero (float0) cotangents.

Interpret gating: these kernels are built on TPU-specific Mosaic
constructs (``pltpu.PrefetchScalarGridSpec``), which the GPU (Triton)
lowering does not implement — so native mode is TPU-only and every other
backend runs interpret mode (kernel bodies still run, so correctness is
validated on every platform / in CI).
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from .spmm import spmm as _spmm_kernel
from .spmm import spmm_grad_w as _spmm_grad_w_kernel
from .ref import spmm_grad_val_ref


def _interpret_mode() -> bool:
    return jax.default_backend() != "tpu"


def spmm(feat_idx, feat_val, feat_mask, w, block_h: int = 512, block_k: int = 8):
    """Padded-COO batch x dense W. Returns (B, H) in W's dtype. Differentiable
    w.r.t. ``feat_val`` and ``w`` (custom VJP, Pallas both ways).

    ``block_k`` = embedding rows gathered per grid step (DESIGN.md §2:
    K-blocked gather; 1 recovers the one-row-per-step formulation)."""
    return _spmm(feat_idx, feat_val, feat_mask, w, int(block_h), int(block_k))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _spmm(feat_idx, feat_val, feat_mask, w, block_h, block_k):
    return _spmm_kernel(
        feat_idx, feat_val, feat_mask, w,
        block_h=block_h, block_k=block_k, interpret=_interpret_mode(),
    )


def _spmm_fwd(feat_idx, feat_val, feat_mask, w, block_h, block_k):
    out = _spmm(feat_idx, feat_val, feat_mask, w, block_h, block_k)
    return out, (feat_idx, feat_val, feat_mask, w)


def _spmm_bwd(block_h, block_k, res, dh):
    feat_idx, feat_val, feat_mask, w = res
    dw = spmm_grad_w(
        feat_idx, feat_val, feat_mask, dh, w.shape[0], block_h=block_h
    ).astype(w.dtype)
    # d feat_val: gather-dot, same O(B*K*H) footprint as the forward
    dval = spmm_grad_val_ref(feat_idx, feat_mask, w, dh).astype(feat_val.dtype)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)  # integral primals
    return f0(feat_idx), dval, f0(feat_mask), dw


_spmm.defvjp(_spmm_fwd, _spmm_bwd)


def spmm_grad_w(feat_idx, feat_val, feat_mask, dh, n_rows: int,
                block_h: int = 512):
    """Standalone transpose-SpMM: scatter-add ``scale[b,k] * dh[b]`` into the
    gathered rows. Returns (n_rows, H) f32."""
    return _spmm_grad_w_kernel(
        feat_idx, feat_val, feat_mask, dh, int(n_rows),
        block_h=block_h, interpret=_interpret_mode(),
    )
