"""Public entrypoint for the weighted-merge kernel.

``merge(replicas, alphas, ...)`` runs the Pallas kernel natively on TPU/GPU
and in interpret mode on CPU (CI): the kernel *body* runs in Python either
way, so correctness is validated on every platform. ``merge_pytree`` applies
the kernel leaf-wise over a replica-stacked param pytree; it is what
``asgd.normalized_merge`` routes through on accelerator backends.
"""
from __future__ import annotations

import jax

from .weighted_merge import weighted_merge


def _interpret_mode() -> bool:
    # Pallas lowers natively on TPU and GPU; only CPU needs interpret mode
    return jax.default_backend() == "cpu"


def merge(replicas, alphas, g=None, gp=None, gamma: float = 0.0, block_n=2048):
    """replicas (R, N); alphas (R,). Returns merged (N,)."""
    return weighted_merge(
        replicas, alphas, g, gp, gamma,
        block_n=block_n, interpret=_interpret_mode(),
    )


def merge_pytree(replica_tree, alphas, global_tree=None, prev_tree=None,
                 gamma: float = 0.0):
    """Leaf-wise Algorithm-2 merge over a pytree whose leaves carry a leading
    replica dim R. Leaves are flattened to (R, N) for the kernel and reshaped
    back. Returns a pytree shaped like one replica."""
    def leaf(x, g=None, gp=None):
        r = x.shape[0]
        flat = x.reshape(r, -1)
        gf = g.reshape(-1) if g is not None else None
        gpf = gp.reshape(-1) if gp is not None else None
        out = merge(flat, alphas, gf, gpf, gamma)
        return out.reshape(x.shape[1:])

    if global_tree is not None and gamma != 0.0:
        return jax.tree_util.tree_map(leaf, replica_tree, global_tree, prev_tree)
    return jax.tree_util.tree_map(lambda x: leaf(x), replica_tree)
