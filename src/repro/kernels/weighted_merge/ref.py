"""Pure-jnp oracle for the weighted model merge (Algorithm 2, line 11).

out = sum_r alphas[r] * replicas[r]  (+ gamma * (g - gp) when provided)

Shapes: replicas (R, N) — the framework flattens each param leaf to 1-D and
concatenates; the kernel operates on flat chunks.
"""
from __future__ import annotations

import jax.numpy as jnp


def weighted_merge_ref(replicas, alphas, g=None, gp=None, gamma: float = 0.0):
    acc = jnp.einsum(
        "r,rn->n", alphas.astype(jnp.float32), replicas.astype(jnp.float32)
    )
    if g is not None and gamma != 0.0:
        acc = acc + gamma * (g.astype(jnp.float32) - gp.astype(jnp.float32))
    return acc.astype(replicas.dtype)
