"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD decomposition [arXiv:2405.21060] splits the linear recurrence into
(1) an intra-chunk quadratic part — three small matmuls that map onto the
MXU — and (2) an inter-chunk state recurrence that is *sequential over
chunks only*. The GPU implementation (Triton) parallelizes chunks across
SMs and does a separate state-passing pass; on TPU we instead exploit the
sequential grid: grid = (B, H, n_chunks) with the chunk dimension innermost,
carrying the running (P, N) state in VMEM scratch across grid steps — the
state never round-trips to HBM between chunks (the TPU-native equivalent of
the GPU's cross-SM state pass, DESIGN.md §2).

Per grid step, for one (batch, head, chunk):
    a_cs    = cumsum(dA)                      # (c, 1)
    L       = tril(exp(a_cs - a_cs^T))        # (c, c) decay kernel
    scores  = (C @ B^T) * L                   # MXU matmul 1
    y_diag  = scores @ x                      # MXU matmul 2
    y_off   = (C @ state^T) * exp(a_cs)       # MXU matmul 3 (carry-in)
    state   = state * exp(a_cs[-1]) + x^T @ (B * exp(a_cs[-1] - a_cs))
All math f32; x/B/C tiles may be bf16 in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_ref, *, chunk):
    ci = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (c, P)
    a = a_ref[0, 0].astype(jnp.float32)      # (c, 1)
    bm = b_ref[0, 0].astype(jnp.float32)     # (c, N)
    cm = c_ref[0, 0].astype(jnp.float32)     # (c, N)

    a_cs = jnp.cumsum(a, axis=0)             # (c, 1) inclusive
    # segment-sum decay kernel: L[i,j] = exp(sum_{j<k<=i} a_k), lower-tri
    seg = a_cs - a_cs.reshape(1, chunk)      # (c, c) = cs[i] - cs[j]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L                                     # (c, c)
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (c, P)

    # inter-chunk contribution from the carried state
    state = state_ref[...]                    # (P, N)
    y_off = jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(a_cs)                         # (c, P)
    y_ref[0, 0] = (y + y_off).astype(y_ref.dtype)

    # state update: decay whole chunk + inject B-weighted inputs
    total = a_cs[chunk - 1]                   # (1,)
    decay_in = jnp.exp(total.reshape(1, 1) - a_cs)  # (c, 1)
    xw = x * decay_in                         # (c, P)
    new_state = state * jnp.exp(total)[0] + jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                         # (P, N)
    state_ref[...] = new_state

    @pl.when(ci == n_c - 1)
    def _fin():
        fin_ref[0, 0] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # (B, L, H, P) — dt-discretized inputs (x * dt)
    dA: jax.Array,   # (B, L, H)    — dt * A
    Bm: jax.Array,   # (B, L, H, N)
    Cm: jax.Array,   # (B, L, H, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P) f32, final_state (B,H,P,N) f32)."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    # head-major layout so each (b, h, chunk) tile is contiguous
    xt = x.transpose(0, 2, 1, 3)                      # (B, H, L, P)
    at = dA.transpose(0, 2, 1)[..., None]             # (B, H, L, 1)
    bt = Bm.transpose(0, 2, 1, 3)                     # (B, H, L, N)
    ct = Cm.transpose(0, 2, 1, 3)

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, at, bt, ct)
    return y.transpose(0, 2, 1, 3), fin
