"""Public entrypoint for the SSD chunked-scan kernel."""
from __future__ import annotations

import jax

from .ssd_scan import ssd_scan as _kernel


def _interpret_mode() -> bool:
    # This kernel uses TPU-specific Mosaic constructs (pltpu.* grid specs /
    # scratch) with no GPU (Triton) lowering: native mode is TPU-only
    return jax.default_backend() != "tpu"


def ssd_scan(x, dA, Bm, Cm, chunk: int = 256):
    """Chunked SSD scan. Returns (y (B,L,H,P) f32, final (B,H,P,N) f32)."""
    return _kernel(x, dA, Bm, Cm, chunk=chunk, interpret=_interpret_mode())
