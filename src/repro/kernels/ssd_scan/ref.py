"""Pure-jnp oracle for the chunked SSD scan — delegates to the model's
reference implementation (models/mamba2.py::ssd_chunked), which is itself
validated against a naive per-token recurrence in tests/test_models.py.
"""
from __future__ import annotations

from repro.models.mamba2 import ssd_chunked


def ssd_scan_ref(x, dA, Bm, Cm, chunk, initial_state=None):
    """x (B,L,H,P); dA (B,L,H); Bm/Cm (B,L,H,N). Returns (y, final_state)."""
    return ssd_chunked(x, dA, Bm, Cm, chunk, initial_state=initial_state)
