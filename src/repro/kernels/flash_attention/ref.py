"""Pure-jnp oracle: exact softmax attention, GQA-native, causal/sliding.

q (B, Sq, Hq, hd); k, v (B, Skv, Hkv, hd); Hq % Hkv == 0.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, rep, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kf) * (hd ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    rel = qpos - kpos
    allow = jnp.ones((sq, skv), bool)
    if causal:
        allow &= rel >= 0
    if window > 0:
        allow &= rel < window
    s = jnp.where(allow[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(allow[None, None, None], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, vf)
    return o.reshape(b, sq, hq, hd).astype(q.dtype)
