"""Public entrypoint for the flash-attention kernel."""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _kernel


def _interpret_mode() -> bool:
    # This kernel uses TPU-specific Mosaic constructs (pltpu.* grid specs /
    # scratch) with no GPU (Triton) lowering: native mode is TPU-only
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    """GQA-native flash attention. q (B,Sq,Hq,hd); k/v (B,Skv,Hkv,hd)."""
    return _kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret_mode(),
    )
