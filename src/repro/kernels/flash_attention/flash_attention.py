"""Pallas TPU flash attention (GQA-native, causal / sliding-window).

Online-softmax tiling: grid = (B, Hq, nQ, nKV) with the KV dimension
innermost (TPU grids execute sequentially, so the f32 accumulator tiles in
VMEM scratch carry across the KV loop). Per step the MXU sees a
(block_q, hd) x (hd, block_k) score matmul and a (block_q, block_k) x
(block_k, hd) value matmul — both hardware-aligned when block_* are
multiples of 128 and hd is a lane multiple.

GQA is *native*: the index_map of K/V divides the query-head grid index by
the group size, so KV tiles are fetched once per KV head — never repeated in
HBM or VMEM (the same property the jnp fallback in models/layers.py has).

Causal/sliding masks are applied with 2-D iota position tiles; fully-masked
KV tiles short-circuit via ``pl.when`` (no MXU work, no accumulator touch),
which is what makes the causal lower-triangle ~2x cheaper and the sliding
window O(S·W) instead of O(S²).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float("-inf")


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, causal: bool, window: int, block_q: int, block_k: int, skv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # tile-level reachability: any (q, k) with q >= k (causal) and
    # q - k < window (sliding) inside this tile pair?
    conds = []
    if causal:
        conds.append(q_start + block_q - 1 >= k_start)
    if window > 0:
        conds.append(q_start - (k_start + block_k - 1) < window)
    live = functools.reduce(jnp.logical_and, conds) if conds else (ki >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)   # (BQ, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)   # (BK, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (hd ** -0.5)                             # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        rel = qpos - kpos
        allow = kpos < skv  # guard KV right-padding
        if causal:
            allow = jnp.logical_and(allow, rel >= 0)
        if window > 0:
            allow = jnp.logical_and(allow, rel < window)
        s = jnp.where(allow, s, NEG_INF)

        m_prev = m_ref[...]                          # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(allow, p, 0.0)
        corr = jnp.where(jnp.isinf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,     # (B, Sq, Hq, hd)
    k: jax.Array,     # (B, Skv, Hkv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sqp, skvp = sq + pad_q, skv + pad_k

    grid = (b, hq, sqp // block_q, skvp // block_k)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, skv=skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bi, h, qi, ki: (bi, qi, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda bi, h, qi, ki: (bi, ki, h // rep, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda bi, h, qi, ki: (bi, ki, h // rep, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, hd), lambda bi, h, qi, ki: (bi, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, sqp, hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
