"""Pure-jnp oracle for the grouped expert FFN (SwiGLU) matmul.

buf (E, C, D) x wi/wg (E, D, F) x wo (E, F, D) -> (E, C, D)
out[e] = (silu(buf[e] @ wg[e]) * (buf[e] @ wi[e])) @ wo[e]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn_gmm_ref(buf, wi, wg, wo):
    x = buf.astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg.astype(jnp.float32)))
    u = jnp.einsum("ecd,edf->ecf", x, wi.astype(jnp.float32))
    out = jnp.einsum("ecf,efd->ecd", g * u, wo.astype(jnp.float32))
    return out.astype(buf.dtype)
