"""Public entrypoint for the grouped MoE FFN kernel."""
from __future__ import annotations

import jax

from .moe_gmm import moe_ffn_gmm as _kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def moe_ffn_gmm(buf, wi, wg, wo, block_c: int = 128, block_f: int = 512):
    """Fused SwiGLU grouped matmul. buf (E,C,D) -> (E,C,D)."""
    return _kernel(
        buf, wi, wg, wo,
        block_c=block_c, block_f=block_f, interpret=not _on_tpu(),
    )
