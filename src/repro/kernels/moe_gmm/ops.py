"""Public entrypoint for the grouped MoE FFN kernel."""
from __future__ import annotations

import jax

from .moe_gmm import moe_ffn_gmm as _kernel


def _interpret_mode() -> bool:
    # This kernel uses TPU-specific Mosaic constructs (pltpu.* grid specs /
    # scratch) with no GPU (Triton) lowering: native mode is TPU-only
    return jax.default_backend() != "tpu"


def moe_ffn_gmm(buf, wi, wg, wo, block_c: int = 128, block_f: int = 512):
    """Fused SwiGLU grouped matmul. buf (E,C,D) -> (E,C,D)."""
    return _kernel(
        buf, wi, wg, wo,
        block_c=block_c, block_f=block_f, interpret=_interpret_mode(),
    )
