"""Pallas TPU kernel: grouped expert FFN (fused SwiGLU) for MoE layers.

After sort-based dispatch (models/moe.py) tokens sit in an (E, C, D) buffer;
each expert's FFN is an independent (C, D) x (D, F) x (F, D) SwiGLU chain.
The GPU approach (persistent-CTA grouped GEMM over ragged rows) doesn't map
to TPU; instead the *fixed-capacity* buffer makes every expert a statically
shaped matmul chain the MXU can pipeline — capacity padding buys static
shapes, the classic TPU trade (DESIGN.md §2).

Fusion: both projections and the SwiGLU product are computed per (expert,
token-block, ff-block) grid step, and the contraction over the ff dimension
accumulates straight into the (BC, D) f32 output tile in VMEM scratch — the
(C, F) intermediate activation is NEVER materialized in HBM. Grid =
(E, nC, nF), ff innermost so the accumulator tile stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 128
DEFAULT_BLOCK_F = 512


def _gmm_kernel(x_ref, wg_ref, wi_ref, wo_ref, out_ref, acc_ref):
    fi = pl.program_id(2)
    n_f = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)       # (BC, D)
    wg = wg_ref[0].astype(jnp.float32)     # (D, BF)
    wi = wi_ref[0].astype(jnp.float32)
    wo = wo_ref[0].astype(jnp.float32)     # (BF, D)
    g = jax.nn.silu(
        jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    )
    u = jax.lax.dot_general(x, wi, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        g * u, wo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(fi == n_f - 1)
    def _finalize():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "interpret")
)
def moe_ffn_gmm(
    buf: jax.Array,   # (E, C, D)
    wi: jax.Array,    # (E, D, F)
    wg: jax.Array,    # (E, D, F)
    wo: jax.Array,    # (E, F, D)
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_f: int = DEFAULT_BLOCK_F,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = buf.shape
    f = wi.shape[-1]
    block_c = min(block_c, c)
    block_f = min(block_f, f)
    pad_c = (-c) % block_c
    pad_f = (-f) % block_f
    if pad_c:
        buf = jnp.pad(buf, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        wi = jnp.pad(wi, ((0, 0), (0, 0), (0, pad_f)))
        wg = jnp.pad(wg, ((0, 0), (0, 0), (0, pad_f)))
        wo = jnp.pad(wo, ((0, 0), (0, pad_f), (0, 0)))
    cp, fp = c + pad_c, f + pad_f

    grid = (e, cp // block_c, fp // block_f)
    out = pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda ei, ci, fi: (ei, ci, 0)),
            pl.BlockSpec((1, d, block_f), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, d, block_f), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, block_f, d), lambda ei, ci, fi: (ei, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda ei, ci, fi: (ei, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cp, d), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, d), jnp.float32)],
        interpret=interpret,
    )(buf, wg, wi, wo)
    return out[:, :c]
