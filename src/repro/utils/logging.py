"""Minimal structured logger + metrics accumulator for training loops."""
from __future__ import annotations

import json
import sys
import time
from dataclasses import dataclass, field
from typing import Any


def log(msg: str, **kv: Any) -> None:
    parts = [msg] + [f"{k}={v}" for k, v in kv.items()]
    print("[repro] " + " ".join(parts), file=sys.stderr, flush=True)


@dataclass
class MetricsLog:
    """Append-only metrics log; one record per merge boundary / eval point."""

    records: list[dict] = field(default_factory=list)
    _t0: float = field(default_factory=time.perf_counter)

    def append(self, **kv: Any) -> None:
        rec = dict(kv)
        rec.setdefault("wall_s", time.perf_counter() - self._t0)
        self.records.append(rec)

    def column(self, key: str) -> list:
        return [r[key] for r in self.records if key in r]

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.records, f, indent=1, default=float)

    @staticmethod
    def load(path: str) -> "MetricsLog":
        m = MetricsLog()
        with open(path) as f:
            m.records = json.load(f)
        return m

    def best(self, key: str, mode: str = "max"):
        col = self.column(key)
        if not col:
            return None
        return max(col) if mode == "max" else min(col)

    def time_to_accuracy(self, target: float, time_key: str = "virtual_time"):
        """First time at which accuracy >= target (the paper's headline metric)."""
        for r in self.records:
            if r.get("accuracy", -1.0) >= target:
                return r[time_key]
        return None
