"""Pytree utilities used across the framework.

All functions are pure and jit-compatible unless noted. The elastic-averaging
core manipulates *replicated* pytrees whose leaves carry a leading replica
dimension ``R``; helpers here implement the per-replica reductions
(Algorithm 2 of the paper needs per-replica L2 norms and weighted sums).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, *trees)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(lambda x, y: x + y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return tree_map(lambda x, y: x - y, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return tree_map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, leafwise."""
    return tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return tree_map(jnp.zeros_like, a)


def tree_size(a: PyTree) -> int:
    """Total number of scalar parameters in the tree (static python int)."""
    return sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(a))


def tree_dot(a: PyTree, b: PyTree):
    """Sum over leaves of <a_i, b_i>."""
    parts = jax.tree_util.tree_leaves(
        tree_map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    )
    return jnp.sum(jnp.stack(parts))


def tree_l2_norm(a: PyTree):
    return jnp.sqrt(tree_dot(a, a))


def tree_l2_norm_per_replica(a: PyTree):
    """L2 norm per replica for a tree whose leaves have leading dim R.

    Returns a vector of shape (R,). Used by Algorithm 2's regularization
    check: ``||w_i||_2 / |w| < pert_thr``.
    """
    parts = [
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in jax.tree_util.tree_leaves(a)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(parts, axis=0), axis=0))


def tree_weighted_sum_replicas(a: PyTree, alphas) -> PyTree:
    """sum_i alphas[i] * a[i] over the leading replica dimension.

    ``alphas`` has shape (R,). This is the merge reduction of Algorithm 2,
    line 11 (without the momentum term).
    """

    def leaf(l):
        al = alphas.reshape((-1,) + (1,) * (l.ndim - 1)).astype(jnp.float32)
        return jnp.sum(al * l.astype(jnp.float32), axis=0).astype(l.dtype)

    return tree_map(leaf, a)


def replica_all_sum(x, axis_name: str | None = None):
    """Sum ``x`` over all shards of the replica mesh axis.

    ``axis_name=None`` (the vmap placement: every replica lives in this
    program) is the identity — local reductions over the leading R dim are
    already global. Under shard_map (``placement='sharded'``) the local R
    dim only covers this shard's replicas, and cross-replica math must
    psum the partials over the mesh axis.
    """
    return x if axis_name is None else jax.lax.psum(x, axis_name)


def tree_replica_mean_keepdims(a: PyTree, axis_name: str | None = None) -> PyTree:
    """float32 mean over the *global* replica dim, keepdims, leafwise.

    The cross-replica averaging primitive of the sync/crossbow family.
    With ``axis_name`` set, each shard's local mean is pmean-ed over the
    replica mesh axis — exact because every shard owns the same number of
    replicas (sharding.rules.replica_mesh guarantees divisibility).
    """

    def leaf(l):
        m = jnp.mean(l.astype(jnp.float32), axis=0, keepdims=True)
        if axis_name is not None:
            m = jax.lax.pmean(m, axis_name)
        return m

    return tree_map(leaf, a)


def tree_broadcast_replicas(a: PyTree, n: int) -> PyTree:
    """Broadcast a tree (no replica dim) to a leading replica dim of size n."""
    return tree_map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), a)


def tree_replica_slice(a: PyTree, i: int) -> PyTree:
    return tree_map(lambda l: l[i], a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return tree_map(lambda l: l.astype(dtype), a)


def tree_has_nan(a: PyTree):
    parts = [jnp.any(jnp.isnan(l)) for l in jax.tree_util.tree_leaves(a)]
    return jnp.any(jnp.stack(parts))
