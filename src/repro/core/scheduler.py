"""Dynamic scheduler: the paper's availability-driven batch dispatch,
reformulated for an SPMD machine as *masked lockstep rounds*.

Paper (§3.1): batches are dispatched one-by-one to whichever GPU finishes
first, until a mega-batch worth of samples has been consumed; the number of
model updates u_i then differs across GPUs. On SPMD hardware all replicas
step together, so we plan a mega-batch as a discrete-event simulation over
the virtual clock:

  while samples remain in the mega-batch:
      i <- replica with the earliest virtual completion time
      dispatch the next b_i samples to i; advance its clock

The plan is then executed as ``max_i u_i`` lockstep rounds; replicas with
fewer dispatches get masked (no-op) rounds. The resulting update counts,
batch contents and merge math are *identical* to the paper's asynchronous
execution — only the wall-clock interleaving differs, and the virtual clock
preserves the paper's timing semantics for measurement.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ElasticConfig
from repro.core.heterogeneity import CostModel, VirtualClock


@dataclass
class Dispatch:
    """One batch assignment: replica i processes `n_samples` at round r."""

    replica: int
    round: int
    n_samples: int
    start_t: float
    end_t: float
    payload: object = None  # the actual batch (set when a fetch_fn is given)
    work: int = 0           # work units (nnz/tokens) that priced this step


@dataclass
class MegaBatchPlan:
    dispatches: list[Dispatch]
    u: np.ndarray            # (R,) update counts
    n_rounds: int
    barrier_time: float      # virtual time when the merge can start
    samples: int

    def per_round_sizes(self, n_replicas: int) -> np.ndarray:
        """(n_rounds, R) valid-sample counts; 0 = masked round."""
        out = np.zeros((self.n_rounds, n_replicas), np.int64)
        for d in self.dispatches:
            out[d.round, d.replica] = d.n_samples
        return out

    def per_replica_work(self, n_replicas: int) -> np.ndarray:
        """(R,) total work units dispatched to each replica — the
        denominator when a MeasuredSpeedModel attributes wall time."""
        out = np.zeros(n_replicas, np.float64)
        for d in self.dispatches:
            out[d.replica] += d.work
        return out

    def payload_grid(self, n_replicas: int, min_rounds: int = 0) -> list[list]:
        """Dense (n_rounds, R) grid of payloads; ``None`` = masked slot.

        This is the handoff to the mega-batch engine: the sparse dispatch
        list becomes the rectangular layout a lockstep executor consumes.
        ``min_rounds`` pads with fully-masked rounds (no-ops under the
        update mask) so the scan engine can bucket round counts and avoid
        one XLA compilation per distinct ``n_rounds``.
        """
        n_rounds = max(self.n_rounds, min_rounds)
        grid: list[list] = [[None] * n_replicas for _ in range(n_rounds)]
        for d in self.dispatches:
            grid[d.round][d.replica] = d.payload
        return grid


@dataclass
class DynamicScheduler:
    """Plans mega-batches on the virtual clock; tracks update counts."""

    cfg: ElasticConfig
    cost: CostModel
    clock: VirtualClock = field(init=False)

    def __post_init__(self):
        self.clock = VirtualClock(self.cfg.n_replicas)

    def resize(self, cfg: ElasticConfig) -> None:
        """Adopt a new replica count between mega-batches (DESIGN.md §6).

        Re-planning needs nothing beyond the new config and a clock of the
        right width: survivor timelines carry over, joiners enter at the
        barrier (see ``VirtualClock.resize``). The speed model behind
        ``cost`` is resized by the trainer before this is called, so the
        next ``plan_megabatch`` prices every replica of the new population.
        """
        self.cfg = cfg
        self.clock.resize(cfg.n_replicas)

    def plan_megabatch(
        self, b: np.ndarray, mega_samples: int, fetch_fn=None
    ) -> MegaBatchPlan:
        """Simulate dispatch of ``mega_samples`` samples.

        ``b`` — per-replica batch sizes (Algorithm 1 output).
        ``fetch_fn(replica, take) -> (payload, work_units)`` pulls the actual
        batch (so the *real* nnz/token cardinality feeds the clock — the
        paper's second heterogeneity source). Without it work == n_samples.
        """
        R = self.cfg.n_replicas
        b = np.maximum(np.asarray(b, np.int64), 1)
        remaining = int(mega_samples)
        u = np.zeros(R, np.int64)
        dispatches: list[Dispatch] = []
        while remaining > 0:
            i = self.clock.earliest()
            take = int(min(b[i], remaining))
            payload, work = fetch_fn(i, take) if fetch_fn else (None, take)
            dt = self.cost.step_time(i, work)
            start = float(self.clock.t[i])
            self.clock.advance(i, dt)
            dispatches.append(
                Dispatch(i, int(u[i]), take, start, start + dt, payload, int(work))
            )
            u[i] += 1
            remaining -= take
        barrier = self.clock.barrier()
        self.cost.speed.advance()
        return MegaBatchPlan(
            dispatches=dispatches,
            u=u,
            n_rounds=int(u.max()) if len(dispatches) else 0,
            barrier_time=barrier,
            samples=int(mega_samples),
        )

    def plan_static(self, b: int, n_batches_per_replica: int, fetch_fn=None) -> MegaBatchPlan:
        """Elastic/sync baseline: every replica gets the same fixed share.

        Models the paper's Figure 3: static partitioning means the slowest
        replica dictates the barrier.
        """
        R = self.cfg.n_replicas
        u = np.full(R, n_batches_per_replica, np.int64)
        dispatches = []
        for r in range(n_batches_per_replica):
            for i in range(R):
                payload, work = fetch_fn(i, int(b)) if fetch_fn else (None, int(b))
                dt = self.cost.step_time(i, work)
                start = float(self.clock.t[i])
                self.clock.advance(i, dt)
                dispatches.append(
                    Dispatch(i, r, int(b), start, start + dt, payload, int(work))
                )
        barrier = self.clock.barrier()
        self.cost.speed.advance()
        return MegaBatchPlan(
            dispatches=dispatches,
            u=u,
            n_rounds=n_batches_per_replica,
            barrier_time=barrier,
            samples=int(b) * n_batches_per_replica * R,
        )
