"""Device heterogeneity model + virtual clock.

The paper identifies two sources of heterogeneity (§1):
  1. intrinsic device variance — identical GPUs differ by up to 32% on the
     same batch (paper Fig. 1);
  2. sparse-data variance — per-batch non-zero counts differ, and sparse
     kernels are cardinality-sensitive.

On this CPU container (and on real TPU slices, which are more homogeneous
than multi-GPU boxes) we *simulate* (1) with a per-replica speed factor and
take (2) directly from the data (total nnz / token count of each batch).
``CostModel.step_time`` returns the virtual seconds a replica needs for a
batch; the scheduler's discrete-event simulation runs on this clock. On real
heterogeneous hardware the same interface is fed measured step times — the
algorithm only ever sees *relative speeds*, exactly as in the paper.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class SpeedModel:
    """Per-replica multiplicative slowdown factors.

    ``max_gap`` = 0.32 reproduces the paper's observed fastest/slowest gap.
    ``jitter`` adds per-step lognormal noise (clock/memory-latency
    oscillation); ``drift`` lets factors wander over time so the adaptive
    algorithm has something to track.
    """

    n_replicas: int
    max_gap: float = 0.32
    jitter: float = 0.03
    drift: float = 0.0
    seed: int = 0
    factors: np.ndarray = field(init=False)
    _rng: np.random.Generator = field(init=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        if self.n_replicas == 1:
            self.factors = np.ones(1)
        else:
            # evenly spread in [1, 1+max_gap], randomly permuted
            base = 1.0 + np.linspace(0.0, self.max_gap, self.n_replicas)
            self.factors = self._rng.permutation(base)

    def step_factor(self, i: int) -> float:
        f = self.factors[i]
        if self.jitter > 0:
            f *= float(self._rng.lognormal(0.0, self.jitter))
        return float(f)

    def advance(self) -> None:
        """Random-walk drift of the underlying factors (optional).

        Factors are *relative* speeds (module docstring): the fastest
        replica defines 1.0. The random walk is therefore renormalized so
        the minimum stays pinned at 1.0 — without it, a walk that happened
        to slow every replica would inflate the whole fleet's virtual time
        with no relative-speed content, and the clip below could only ever
        push factors up, never back down. After renormalization the clip
        bounds the *gap*: the slowest replica stays within 2x the paper's
        observed spread of the fastest.
        """
        if self.drift > 0:
            self.factors *= np.exp(self._rng.normal(0.0, self.drift, self.n_replicas))
            self.factors /= self.factors.min()  # fastest pinned to 1.0
            self.factors = np.clip(self.factors, 1.0, 1.0 + 2 * self.max_gap)

    def resize(self, new_R: int) -> None:
        """Membership change (DESIGN.md §6): survivors keep their current
        factors, joiners start at the homogeneous prior (1.0). After a
        shrink the surviving factors are renormalized so the fastest is
        again 1.0 (relative speeds are the contract)."""
        keep = min(self.n_replicas, new_R)
        factors = np.ones(new_R)
        factors[:keep] = self.factors[:keep]
        self.factors = factors / factors.min()
        self.n_replicas = new_R

    def permute(self, perm) -> None:
        """Reorder replica slots (DESIGN.md §7: targeted eviction moves the
        evicted slot to the tail before a shrink). Pure relabeling — no
        renormalization, the factor set is unchanged."""
        self.factors = self.factors[np.asarray(perm, np.int64)]

    # ---- checkpointing (DESIGN.md §7) ----
    def state_dict(self) -> dict:
        """Full restorable state: factor arrays plus the jitter/drift RNG
        (``arrays`` -> tensor store, ``meta`` -> json metadata), so a
        restored run replays the same simulated heterogeneity trajectory."""
        return {
            "arrays": {"factors": self.factors.copy()},
            "meta": {"kind": "simulated",
                     "rng": self._rng.bit_generator.state},
        }

    def load_state_dict(self, sd: dict) -> None:
        self.factors = np.asarray(sd["arrays"]["factors"], np.float64).copy()
        self.n_replicas = len(self.factors)
        self._rng.bit_generator.state = sd["meta"]["rng"]


@dataclass
class MeasuredSpeedModel:
    """Relative replica speeds estimated from *measured* round times.

    The simulated ``SpeedModel`` invents heterogeneity; this model closes
    the paper's feedback loop (§3.1) instead: the trainer reports how long
    each replica's share of a mega-batch actually took
    (``observe(replica, work_units, seconds)``), the model keeps an
    exponential moving average of seconds-per-work-unit per replica, and
    ``step_factor`` exposes the *relative* speeds (slowest/fastest ratios,
    fastest normalized to 1.0) — the only thing the scheduler's virtual
    clock ever consumes, exactly as in the paper.

    Measurement sources (DESIGN.md §5):
      * sharded placement — post-round timing of the mega-batch program,
        attributed per replica by its scheduled share of the window
        (``observe_plan``); on a heterogeneous fleet, per-shard host
        callbacks can feed ``observe`` directly instead;
      * tests — ``timer`` is injectable, so a fake clock drives the model
        deterministically (no sleeping in unit tests).

    Until a replica has ``min_obs`` observations its factor stays at the
    prior (1.0 = homogeneous), so cold-start planning is unbiased, and the
    first ``warmup_windows`` mega-batch windows are discarded entirely —
    they are dominated by jit compilation, which would otherwise charge
    compile time only to the replicas that happened to be live. The
    interface is duck-compatible with ``SpeedModel`` (``step_factor`` /
    ``advance`` / ``factors``): ``CostModel`` cannot tell them apart.
    """

    n_replicas: int
    ema: float = 0.5             # weight of the newest observation
    min_obs: int = 1             # observations before the prior is replaced
    warmup_windows: int = 1      # leading observe_plan windows to discard
    timer: Callable[[], float] = time.perf_counter  # injectable for tests
    t_per_work: np.ndarray = field(init=False)      # EMA seconds/work-unit
    n_obs: np.ndarray = field(init=False)
    n_windows: int = field(init=False, default=0)
    skip_windows: int = field(init=False, default=0)  # see discard_next_window
    _factors: np.ndarray = field(init=False, default=None)  # cache; see factors

    def __post_init__(self):
        self.t_per_work = np.full(self.n_replicas, np.nan)
        self.n_obs = np.zeros(self.n_replicas, np.int64)

    # ---- measurement ingestion ----
    def begin(self) -> float:
        """Start a measurement window (returns a timer handle)."""
        return self.timer()

    def elapsed(self, handle: float) -> float:
        return self.timer() - handle

    def observe(self, replica: int, work_units: float, seconds: float) -> None:
        """One measured (replica, work, wall-seconds) sample."""
        if work_units <= 0 or seconds <= 0:
            return
        tpw = seconds / float(work_units)
        if self.n_obs[replica] == 0:
            self.t_per_work[replica] = tpw
        else:
            self.t_per_work[replica] = (
                self.ema * tpw + (1.0 - self.ema) * self.t_per_work[replica]
            )
        self.n_obs[replica] += 1
        self._factors = None  # invalidate the cached relative factors

    def observe_plan(self, per_replica_work: np.ndarray, seconds: float,
                     u: np.ndarray | None = None, n_rounds: int = 0) -> None:
        """Attribute one mega-batch's wall time across its replicas.

        With the plan's update counts ``u`` (and its round count), each
        replica is charged only its *scheduled share* of the window,
        ``seconds * u_i / n_rounds`` — a replica live in every round owns
        the whole window, one masked out of half the rounds owns half. This
        matters: charging everyone the full window would measure planner
        asymmetry (who got the leftover dispatch) as a speed difference and
        feed it back into the next plan, a self-amplifying loop with no
        hardware cause. With the share normalization, equal per-round
        throughput measures equal speed regardless of how many rounds the
        planner handed out. Without ``u`` the whole window is charged
        (e.g. single-dispatch callers).

        The residual limit is physical, not statistical: lockstep rounds
        end at a global barrier, so a genuinely slow device stretches every
        live round for everyone and the coarse fallback converges toward
        homogeneous factors. True per-replica contrast needs per-shard
        timing callbacks feeding ``observe`` directly (ROADMAP).

        Degenerate plans (``n_rounds == 0`` or an all-zero ``u`` — e.g. a
        fully-masked mega-batch, or a resize boundary where nothing was
        dispatched) carry no attributable signal: the window is still
        counted (so the compile-warmup discard stays aligned with the
        trainer's mega-batch sequence) but no EMA is charged — previously
        such a window either divided by a zero round count or silently fell
        back to charging everyone the whole window.
        """
        if not self._admit_window():
            return
        share = self._scheduled_share(u, n_rounds)
        if share is None:
            return  # window counted above; nothing attributable
        work = np.asarray(per_replica_work, np.float64)
        for i, w in enumerate(work):
            if w > 0 and share[i] > 0:
                self.observe(i, w, seconds * share[i])

    def observe_shards(self, windows: np.ndarray,
                       per_replica_work: np.ndarray,
                       u: np.ndarray | None = None,
                       n_rounds: int = 0) -> None:
        """Attribute *per-shard* measured windows across their replicas.

        ``windows`` is one wall-clock window per mesh shard, bracketed by
        ``jax.debug.callback`` markers inside the shard's own mega-batch
        program (DESIGN.md §8). Unlike :meth:`observe_plan`'s single host
        window — which a global barrier stretches identically for everyone —
        each shard's window reflects that shard's actual device time, so a
        genuinely slow shard shows up as a real cross-shard contrast instead
        of converging toward homogeneous factors. Within a shard the window
        is split by scheduled share exactly like ``observe_plan`` (the
        shard's replicas execute in one program; the share is the only
        attribution signal available there).

        Shares the warmup / skip-window gating with ``observe_plan``: a
        mega-batch consumes exactly one window regardless of which
        attribution path it takes. Windows whose shard count does not divide
        the population (stale callbacks across a resize) charge nothing.
        """
        if not self._admit_window():
            return
        windows = np.asarray(windows, np.float64)
        n_shards = len(windows)
        if n_shards == 0 or self.n_replicas % n_shards != 0:
            return
        share = self._scheduled_share(u, n_rounds)
        if share is None:
            return
        rps = self.n_replicas // n_shards
        work = np.asarray(per_replica_work, np.float64)
        for i, w in enumerate(work):
            seconds = float(windows[i // rps]) * share[i]
            if w > 0 and seconds > 0:
                self.observe(i, w, seconds)

    def _admit_window(self) -> bool:
        """Count one measurement window; False while warmup/skip gating
        discards it (compile time must never reach the EMAs)."""
        self.n_windows += 1
        if self.n_windows <= self.warmup_windows:
            return False
        if self.skip_windows > 0:       # e.g. first window after a resize
            self.skip_windows -= 1
            return False
        return True

    def _scheduled_share(self, u, n_rounds: int) -> np.ndarray | None:
        """Per-replica scheduled share of a window; None if unattributable."""
        if u is None:
            return np.ones(self.n_replicas)
        u_arr = np.asarray(u, np.float64)
        if n_rounds <= 0 or not np.any(u_arr > 0):
            return None
        return u_arr / float(n_rounds)

    # ---- the SpeedModel interface the scheduler consumes ----
    @property
    def factors(self) -> np.ndarray:
        """Relative slowdown factors, fastest replica == 1.0.

        Cached between observations: the planner calls ``step_factor`` once
        per dispatch (hundreds of times per mega-batch plan), while the
        underlying EMAs only change at ``observe`` time.
        """
        if self._factors is not None:
            return self._factors
        measured = self.n_obs >= self.min_obs
        if not measured.any():
            out = np.ones(self.n_replicas)
        else:
            fastest = np.nanmin(np.where(measured, self.t_per_work, np.nan))
            out = np.ones(self.n_replicas)
            out[measured] = self.t_per_work[measured] / fastest
        self._factors = out
        return out

    def step_factor(self, i: int) -> float:
        # no synthetic jitter: the EMA already carries the real noise
        return float(self.factors[i])

    def advance(self) -> None:
        """Drift is tracked by the EMA itself; nothing to simulate."""

    def discard_next_window(self) -> None:
        """Mark the next ``observe_plan`` window unattributable (still
        counted in ``n_windows``, charged to no EMA). Used after events
        that put non-round work inside the timed window — e.g. a resize to
        a first-visit population shape jit-compiles the executors there,
        and compile seconds at EMA weight would corrupt every live
        replica's factor exactly like the cold-start warmup would."""
        self.skip_windows += 1

    def resize(self, new_R: int) -> None:
        """Membership change (DESIGN.md §6): surviving replicas keep their
        measured EMAs and observation counts; joiners start unmeasured
        (NaN seconds-per-work, zero observations), so their factor is the
        homogeneous prior until ``min_obs`` real windows land. The warmup
        counter is *not* reset (cold-start warmup happened once), but the
        first post-resize window is discarded: a resize to a *first-visit*
        population shape compiles the executors inside the next timed
        window (revisited shapes are cache hits, DESIGN.md §6, but one
        discarded mega-batch per rare resize event is cheap insurance
        either way)."""
        keep = min(self.n_replicas, new_R)
        t_per_work = np.full(new_R, np.nan)
        n_obs = np.zeros(new_R, np.int64)
        t_per_work[:keep] = self.t_per_work[:keep]
        n_obs[:keep] = self.n_obs[:keep]
        self.t_per_work, self.n_obs = t_per_work, n_obs
        self.n_replicas = new_R
        self._factors = None
        self.discard_next_window()

    def permute(self, perm) -> None:
        """Reorder replica slots (targeted eviction, DESIGN.md §7): the
        EMAs and observation counts follow their replica."""
        perm = np.asarray(perm, np.int64)
        self.t_per_work = self.t_per_work[perm]
        self.n_obs = self.n_obs[perm]
        self._factors = None

    # ---- checkpointing (DESIGN.md §7) ----
    def state_dict(self) -> dict:
        """EMAs, observation counts and the warmup/skip counters — enough
        that a restored run keeps attributing windows exactly where the
        killed run left off (the trainer additionally discards the first
        post-restore window: a fresh process recompiles inside it)."""
        return {
            "arrays": {"t_per_work": self.t_per_work.copy(),
                       "n_obs": self.n_obs.copy()},
            "meta": {"kind": "measured", "n_windows": int(self.n_windows),
                     "skip_windows": int(self.skip_windows)},
        }

    def load_state_dict(self, sd: dict) -> None:
        self.t_per_work = np.asarray(sd["arrays"]["t_per_work"],
                                     np.float64).copy()
        self.n_obs = np.asarray(sd["arrays"]["n_obs"], np.int64).copy()
        self.n_replicas = len(self.n_obs)
        self.n_windows = int(sd["meta"]["n_windows"])
        self.skip_windows = int(sd["meta"]["skip_windows"])
        self._factors = None


class ShardWindowTimer:
    """Host-side collector for per-shard device execution windows.

    The sharded mega-batch executor brackets each shard's program with two
    ``jax.debug.callback`` markers (trainer, DESIGN.md §8): the *start*
    marker depends only on an input leaf, so XLA schedules it at program
    entry; the *end* marker depends on the reduced metrics, so it fires
    after the scan. The difference is that shard's own wall window —
    the signal :meth:`MeasuredSpeedModel.observe_shards` consumes.

    Callbacks are unordered and asynchronous: the trainer calls
    ``jax.effects_barrier()`` before :meth:`take`, and ``take`` returns
    ``None`` whenever the marker set is incomplete or non-positive (e.g.
    the legacy engine, whose executor carries no markers) — callers then
    fall back to whole-window attribution. ``timer`` is injectable so unit
    tests drive the windows deterministically.
    """

    def __init__(self, timer: Callable[[], float] = time.perf_counter):
        self.timer = timer
        # jax.debug.callback may fire from runtime callback threads, so the
        # marker dicts and take()'s swap are lock-guarded (JL106/JL101); the
        # first-wins check in mark_start must be atomic with its set
        self._lock = threading.Lock()
        self._n = 0
        self._t0: dict[int, float] = {}
        self._t1: dict[int, float] = {}

    def reset(self, n_shards: int) -> None:
        """Open a measurement window expecting markers from n_shards."""
        with self._lock:
            self._n = int(n_shards)
            self._t0 = {}
            self._t1 = {}

    def mark_start(self, shard) -> None:
        s = int(shard)
        with self._lock:
            if s not in self._t0:   # first callback opens the shard's window
                self._t0[s] = self.timer()

    def mark_end(self, shard) -> None:
        s = int(shard)
        with self._lock:
            self._t1[s] = self.timer()  # last callback closes it

    def take(self) -> np.ndarray | None:
        """(n_shards,) window seconds, or None if any marker is missing."""
        with self._lock:
            n, t0, t1 = self._n, self._t0, self._t1
            self._n, self._t0, self._t1 = 0, {}, {}
        if n == 0 or set(t0) != set(range(n)) or set(t1) != set(range(n)):
            return None
        w = np.array([t1[s] - t0[s] for s in range(n)], np.float64)
        return w if np.all(w > 0) else None


@dataclass
class CostModel:
    """Virtual step time of one batch on one replica.

    time = speed_i * (overhead + work_cost * work_units)

    ``work_units`` is total nnz for sparse batches (cuSPARSE-like
    cardinality sensitivity) or total tokens for LM batches. ``speed`` is
    either the simulated ``SpeedModel`` or a ``MeasuredSpeedModel`` — the
    cost model only consumes the shared ``step_factor`` interface.
    """

    speed: "SpeedModel | MeasuredSpeedModel"
    overhead: float = 1.0e-3
    work_cost: float = 2.0e-6

    def step_time(self, replica: int, work_units: int) -> float:
        return self.speed.step_factor(replica) * (
            self.overhead + self.work_cost * float(work_units)
        )


@dataclass
class VirtualClock:
    """Per-replica virtual timelines; merge barrier = max over replicas."""

    n_replicas: int
    t: np.ndarray = field(init=False)

    def __post_init__(self):
        self.t = np.zeros(self.n_replicas)

    def earliest(self) -> int:
        return int(np.argmin(self.t))

    def resize(self, new_R: int) -> None:
        """Membership change (DESIGN.md §6): survivors keep their virtual
        timelines; joiners enter at the latest survivor time (they cannot
        have been available in the past — between mega-batches all clocks
        sit at the barrier anyway, so this is the barrier time)."""
        keep = min(self.n_replicas, new_R)
        t = np.full(new_R, float(self.t[:keep].max()) if keep else 0.0)
        t[:keep] = self.t[:keep]
        self.t = t
        self.n_replicas = new_R

    def permute(self, perm) -> None:
        """Reorder replica timelines (targeted eviction, DESIGN.md §7)."""
        self.t = self.t[np.asarray(perm, np.int64)]

    def advance(self, i: int, dt: float) -> None:
        self.t[i] += dt

    def barrier(self) -> float:
        """All replicas wait for the slowest (synchronization point)."""
        m = float(self.t.max())
        self.t[:] = m
        return m
