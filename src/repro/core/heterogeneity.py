"""Device heterogeneity model + virtual clock.

The paper identifies two sources of heterogeneity (§1):
  1. intrinsic device variance — identical GPUs differ by up to 32% on the
     same batch (paper Fig. 1);
  2. sparse-data variance — per-batch non-zero counts differ, and sparse
     kernels are cardinality-sensitive.

On this CPU container (and on real TPU slices, which are more homogeneous
than multi-GPU boxes) we *simulate* (1) with a per-replica speed factor and
take (2) directly from the data (total nnz / token count of each batch).
``CostModel.step_time`` returns the virtual seconds a replica needs for a
batch; the scheduler's discrete-event simulation runs on this clock. On real
heterogeneous hardware the same interface is fed measured step times — the
algorithm only ever sees *relative speeds*, exactly as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class SpeedModel:
    """Per-replica multiplicative slowdown factors.

    ``max_gap`` = 0.32 reproduces the paper's observed fastest/slowest gap.
    ``jitter`` adds per-step lognormal noise (clock/memory-latency
    oscillation); ``drift`` lets factors wander over time so the adaptive
    algorithm has something to track.
    """

    n_replicas: int
    max_gap: float = 0.32
    jitter: float = 0.03
    drift: float = 0.0
    seed: int = 0
    factors: np.ndarray = field(init=False)
    _rng: np.random.Generator = field(init=False)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        if self.n_replicas == 1:
            self.factors = np.ones(1)
        else:
            # evenly spread in [1, 1+max_gap], randomly permuted
            base = 1.0 + np.linspace(0.0, self.max_gap, self.n_replicas)
            self.factors = self._rng.permutation(base)

    def step_factor(self, i: int) -> float:
        f = self.factors[i]
        if self.jitter > 0:
            f *= float(self._rng.lognormal(0.0, self.jitter))
        return float(f)

    def advance(self) -> None:
        """Random-walk drift of the underlying factors (optional)."""
        if self.drift > 0:
            self.factors *= np.exp(self._rng.normal(0.0, self.drift, self.n_replicas))
            self.factors = np.clip(self.factors, 1.0, 1.0 + 2 * self.max_gap)


@dataclass
class CostModel:
    """Virtual step time of one batch on one replica.

    time = speed_i * (overhead + work_cost * work_units)

    ``work_units`` is total nnz for sparse batches (cuSPARSE-like
    cardinality sensitivity) or total tokens for LM batches.
    """

    speed: SpeedModel
    overhead: float = 1.0e-3
    work_cost: float = 2.0e-6

    def step_time(self, replica: int, work_units: int) -> float:
        return self.speed.step_factor(replica) * (
            self.overhead + self.work_cost * float(work_units)
        )


@dataclass
class VirtualClock:
    """Per-replica virtual timelines; merge barrier = max over replicas."""

    n_replicas: int
    t: np.ndarray = field(init=False)

    def __post_init__(self):
        self.t = np.zeros(self.n_replicas)

    def earliest(self) -> int:
        return int(np.argmin(self.t))

    def advance(self, i: int, dt: float) -> None:
        self.t[i] += dt

    def barrier(self) -> float:
        """All replicas wait for the slowest (synchronization point)."""
        m = float(self.t.max())
        self.t[:] = m
        return m
