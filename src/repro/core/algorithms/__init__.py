"""Pluggable training-algorithm strategies (DESIGN.md §4).

Public API:

* ``Algorithm`` — the strategy base class (hook contract in base.py)
* ``register(name)`` / ``get(name)`` / ``available()`` — the registry
* hook result types: ``StateExtras``, ``RoundTransforms``, ``MergeOutcome``

Importing this package registers the built-in algorithm family; external
code adds members with ``@register("name")`` and they become reachable via
``ElasticConfig(algorithm="name")`` / ``--algorithm name`` with no trainer
edits.
"""
from .base import (  # noqa: F401
    Algorithm,
    MergeOutcome,
    RoundTransforms,
    StateExtras,
    available,
    get,
    register,
    replica_axis_name,
)

# built-ins self-register on import
from . import adaptive, crossbow, delayed_sync, elastic, single, sync  # noqa: F401, E402
