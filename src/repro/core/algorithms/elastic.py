"""Elastic model averaging (K-step averaging, paper §5.1 baseline).

Static equal batches, uniform-weight normalized merge with the same
global-model momentum rule as Adaptive, no batch-size adaptation.
"""
from __future__ import annotations

import numpy as np

from .base import Algorithm, MergeOutcome, StateExtras, register


@register("elastic")
class ElasticAveraging(Algorithm):
    def init_state_extras(self, cfg, params, keep_global_copies):
        b = np.full(cfg.n_replicas, float(cfg.b_max))
        if keep_global_copies:
            return StateExtras(b=b, global_model=params, prev_global=params)
        return StateExtras(b=b)

    def merge(self, trainer, state, plan, replicas):
        cfg = trainer.cfg
        alphas = np.full(cfg.n_replicas, 1.0 / cfg.n_replicas)
        new_global, new_replicas = trainer.merge_models(
            replicas,
            alphas,
            state.global_model,
            state.prev_global,
            cfg.gamma if state.global_model is not None else 0.0,
        )
        return MergeOutcome(
            replicas=new_replicas,
            global_model=new_global,
            prev_global=state.global_model,
            alphas=alphas,
        )
