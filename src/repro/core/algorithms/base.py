"""The pluggable Algorithm API (DESIGN.md §4).

An *algorithm* is everything that distinguishes one member of the
elastic-SGD family from another: how per-replica state is initialized, how
a mega-batch is partitioned, what happens to gradients/replicas inside a
lockstep round, how replicas are merged at the barrier, and how batch
sizes/learning rates adapt between mega-batches. ``ElasticTrainer`` is a
generic engine that drives whichever ``Algorithm`` the registry resolves
from ``cfg.algorithm`` — it contains no per-algorithm branching.

Hook contract (all hooks are host-side *except* the members of
``RoundTransforms``, which are traced inside the engine's jitted round —
see the jit rules on ``RoundTransforms``):

  * ``init_state_extras(cfg, params, keep_global_copies)`` → ``StateExtras``
    — initial per-replica batch sizes and the global/prev-global model
    copies (or None for algorithms that merge directly on the replicas).
  * ``plan(scheduler, state, mega_samples, fetch_fn)`` → ``MegaBatchPlan``
    — dynamic (availability-driven) vs static (equal-share) partitioning.
  * ``round_transforms(cfg)`` → ``RoundTransforms`` — the traced per-round
    behavior: an optional gradient transform (e.g. cross-replica
    averaging) and an optional post-update replica correction (e.g.
    CROSSBOW's pull toward the replica average).
  * ``merge(trainer, state, plan, replicas)`` → ``MergeOutcome`` — the
    barrier: produce the new global model and (possibly reset) replicas.
    ``trainer`` exposes the jitted tensor math (``trainer.merge_models``,
    ``trainer.replica_norms``) so implementations stay declarative.
  * ``adapt(state, plan, cfg)`` → ``(new_b, new_lr)`` — between-mega-batch
    batch-size/learning-rate adaptation (Algorithm 1 for ``adaptive``).
  * ``merges_per_megabatch(plan)`` — how many merge costs the virtual
    clock charges (per-round for eager synchronous schemes, 1 for
    barrier-only or latency-hiding schemes).
  * ``resolve_n_replicas(requested)`` — clamp the replica count
    (``single`` forces 1). Also consulted by ``ElasticTrainer.resize``,
    so a clamped algorithm turns membership changes into no-ops.
  * ``resize_policy`` / ``resize_b(...)`` — how the algorithm handles a
    replica-membership change between mega-batches (DESIGN.md §6): whether
    survivors restart from the merged global or keep their diverged
    parameters, and what batch sizes the new population plans with.

Registering a new algorithm requires **no trainer edits**::

    from repro.core.algorithms import Algorithm, register

    @register("my_algo")
    class MyAlgo(Algorithm):
        ...

and it is immediately reachable via ``ElasticConfig(algorithm="my_algo")``
and ``launch/train.py --algorithm my_algo``
(``tests/test_algorithms.py::test_toy_algorithm_via_public_api`` holds the
API to exactly this bar).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

PyTree = Any

#: mesh-axis name bound inside the trainer's sharded executors — re-exported
#: here so strategy code never imports the sharding layer directly.
from repro.sharding.rules import REPLICA_AXIS  # noqa: E402


def replica_axis_name(cfg) -> Optional[str]:
    """The collective axis a traced hook must reduce over, or None.

    Under ``cfg.placement == 'sharded'`` the engine traces RoundTransforms
    inside a shard_map over the 1-D replica mesh, so the leading R dim of
    the leaves a transform sees only covers *this shard's* replicas:
    cross-replica math (gradient averaging, CROSSBOW's center) must fold in
    the other shards via collectives over this axis name
    (``tu.replica_all_sum`` / ``tu.tree_replica_mean_keepdims`` take it as
    an argument). Under the default vmap placement every replica is local
    and this returns None — the helpers then reduce exactly as before, so
    the golden-checked numerics are untouched.
    """
    return REPLICA_AXIS if getattr(cfg, "placement", "vmap") == "sharded" else None


# --------------------------------------------------------------------------
# hook result types
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StateExtras:
    """Algorithm-specific slice of the initial ``ElasticState``.

    ``b`` is the (R,) initial per-replica batch size; the trainer derives
    the initial lr from it via the linear-scaling rule
    (``base_lr * b / b_max``). ``global_model``/``prev_global`` are the
    Algorithm-2 bookkeeping copies — None means the algorithm merges
    directly on the replicas (memory-lean, paper §4).
    """

    b: np.ndarray
    global_model: Optional[PyTree] = None
    prev_global: Optional[PyTree] = None


@dataclass(frozen=True)
class RoundTransforms:
    """Traced per-round behavior. **Jit rules** (DESIGN.md §4):

    Both engines pass this object to their jitted round functions as a
    *static* argument (it is hashed by identity of its callables), so the
    members trace inside the device program — the scan engine's
    one-sync-per-mega-batch and donation contracts are untouched. That
    imposes the usual tracing constraints on the callables:

    * pure jnp/tree math only — no host syncs, no Python branching on
      traced values;
    * static shapes: transforms see the same (R, ...) leaves every round —
      where R is the number of replicas *local to the executing program*:
      all of them under the vmap placement, this shard's slice under
      ``placement='sharded'``. Cross-replica reductions must therefore go
      through the placement-aware helpers (``replica_axis_name(cfg)`` +
      ``tu.replica_all_sum``/``tu.tree_replica_mean_keepdims``), never a
      bare ``jnp.mean(axis=0)``;
    * masked rounds must stay exact no-ops. ``grad_transform`` receives
      the (R,) update mask and must not leak masked replicas' (zero)
      gradients into live ones; ``post_round`` corrections are gated by
      the engine itself (skipped when ``mask.max() == 0``, i.e. on
      bucket-padding rounds) but must keep *masked replicas within a live
      round* consistent with the algorithm's semantics.
    * build the object once per trainer (``round_transforms`` is called a
      single time, from ``_build_jits``) — returning fresh closures per
      call would defeat the jit cache.
    * stay R-agnostic: the object survives ``ElasticTrainer.resize``
      (DESIGN.md §6 reuses it so jit caches persist across membership
      changes), so the callables must read the replica count from the
      leaves they are given, never bake ``cfg.n_replicas`` into a closure.

    ``grad_transform(grads, update_mask) -> grads`` runs after the vmapped
    per-replica gradient computation and before the SGD update; grads may
    contain RowSparseGrad leaves (densify first if cross-replica math is
    needed — replicas see different batches, so row-sparse leaves have no
    common row set). ``post_round(replicas) -> replicas`` runs after the
    SGD update.
    """

    grad_transform: Optional[Callable[[PyTree, Any], PyTree]] = None
    post_round: Optional[Callable[[PyTree], PyTree]] = None


@dataclass(frozen=True)
class MergeOutcome:
    """What the barrier produced.

    ``replicas`` — the (R, ...) tree training continues from (merged
    algorithms broadcast the new global; others return the input).
    ``global_model`` — the model evaluation/checkpointing uses.
    ``alphas``/``pert_active`` — Algorithm-2 diagnostics for the metrics
    log (uniform / False where not applicable).
    """

    replicas: PyTree
    global_model: PyTree
    prev_global: Optional[PyTree] = None
    alphas: Optional[np.ndarray] = None
    pert_active: bool = False


# --------------------------------------------------------------------------
# the strategy protocol
# --------------------------------------------------------------------------


class Algorithm:
    """Base strategy: K-step model averaging over a static equal plan.

    Subclasses override only the hooks whose behavior differs; the
    defaults implement the common elastic-averaging scaffolding (static
    plan, no round transforms, plain-average merge on the replicas, no
    adaptation, one merge per mega-batch).
    """

    #: registry key, set by @register
    name: str = "?"

    #: membership-change contract (DESIGN.md §6), consumed by
    #: ``ElasticTrainer.resize``:
    #:   'merge'    — default. Every current replica (including the ones
    #:                about to leave) contributes a final normalized merge;
    #:                the whole new population restarts from the merged
    #:                global. Right for the averaging family, whose barrier
    #:                already resets replicas to the global each mega-batch.
    #:   'preserve' — the final merge still folds the leavers' updates into
    #:                the global, but *surviving* replicas keep their own
    #:                (diverged) parameters; only joiners clone the merged
    #:                global. Right for independent-learner schemes
    #:                (CROSSBOW) where replica divergence is the algorithm.
    #:   'fixed'    — membership cannot change; ``resize`` raises. Use for
    #:                algorithms whose math is pinned to a replica count
    #:                (``single`` instead clamps via resolve_n_replicas, so
    #:                a resize request degenerates to a no-op).
    resize_policy: str = "merge"

    #: True when the algorithm reduces across replicas *inside* the jitted
    #: round body (``axis_name`` collectives in round_transforms — sync's
    #: gradient mean, CROSSBOW's center). A host-mode multi-host span
    #: (DESIGN.md §10) only exchanges at the mega-batch barrier, so an
    #: in-round collective would silently reduce over the local slot block
    #: alone; the trainer rejects spanning such algorithms at launch.
    #: Device spans are unaffected — there the mesh itself is global.
    round_collectives: bool = False

    # ---- state ----
    def init_state_extras(self, cfg, params, keep_global_copies: bool) -> StateExtras:
        # paper: initialize at b_max (Fig. 10a)
        return StateExtras(b=np.full(cfg.n_replicas, float(cfg.b_max)))

    # ---- planning ----
    def plan(self, scheduler, state, mega_samples: int, fetch_fn):
        """Default: static equal partitioning (the slowest replica
        dictates the barrier, paper Fig. 3)."""
        R = scheduler.cfg.n_replicas
        per_rep = max(1, int(round(mega_samples / (R * state.b[0]))))
        return scheduler.plan_static(int(state.b[0]), per_rep, fetch_fn=fetch_fn)

    def _plan_dynamic(self, scheduler, state, mega_samples: int, fetch_fn):
        """Availability-driven dispatch over the virtual clock (paper §3.1)."""
        return scheduler.plan_megabatch(
            np.round(state.b).astype(np.int64), mega_samples, fetch_fn=fetch_fn
        )

    # ---- traced round behavior ----
    def round_transforms(self, cfg) -> RoundTransforms:
        return RoundTransforms()

    # ---- barrier ----
    def merge(self, trainer, state, plan, replicas) -> MergeOutcome:
        """Plain average of the replicas (no global-model momentum)."""
        R = trainer.cfg.n_replicas
        alphas = np.full(R, 1.0 / R)
        new_global, new_replicas = trainer.merge_models(
            replicas, alphas, None, None, 0.0
        )
        return MergeOutcome(
            replicas=new_replicas, global_model=new_global, alphas=alphas
        )

    # ---- between-mega-batch adaptation ----
    def adapt(self, state, plan, cfg):
        return state.b, state.lr

    # ---- accounting ----
    def merges_per_megabatch(self, plan) -> int:
        return 1

    def resolve_n_replicas(self, requested: int) -> int:
        return requested

    # ---- membership change (DESIGN.md §6) ----
    def resize_b(self, cfg, b: np.ndarray, lr: np.ndarray, base_lr: float):
        """Per-replica batch sizes / learning rates for the resized
        population. ``cfg`` is the *new* config (``cfg.n_replicas`` is the
        new R); ``b``/``lr`` are the old per-replica arrays.

        Default: survivors keep their adapted values — Algorithm 1 resumes
        from them at the new R on the next ``adapt`` — and joiners start at
        the algorithm's initial batch size (``init_state_extras`` is
        re-consulted with ``params=None, keep_global_copies=False``; an
        algorithm whose initial ``b`` needs the params must override this
        hook) with the linear-scaling learning rate. Algorithms whose
        per-replica share depends on R itself (``sync``: b_max/R equal
        shares) re-derive everyone's values instead.
        """
        new_R = cfg.n_replicas
        keep = min(len(b), new_R)
        new_b = np.empty(new_R, np.float64)
        new_b[:keep] = np.asarray(b, np.float64)[:keep]
        new_lr = np.empty(new_R, np.float64)
        new_lr[:keep] = np.asarray(lr, np.float64)[:keep]
        if new_R > keep:  # a shrink needs no joiner values (and must not
            #               require init_state_extras to accept params=None)
            init_b = np.asarray(
                self.init_state_extras(cfg, None, False).b, np.float64
            )
            new_b[keep:] = init_b[keep:new_R]
            new_lr[keep:] = base_lr * new_b[keep:] / cfg.b_max
        return new_b, new_lr


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, type[Algorithm]] = {}


def register(name: str):
    """Class decorator: ``@register("my_algo")`` on an Algorithm subclass."""

    def deco(cls):
        if not (isinstance(cls, type) and issubclass(cls, Algorithm)):
            raise TypeError(f"{cls!r} must subclass Algorithm")
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"algorithm {name!r} already registered")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str) -> Algorithm:
    """Resolve a registered algorithm to a fresh strategy instance."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
