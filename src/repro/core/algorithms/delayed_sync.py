"""Delayed-synchronous SGD with adaptive batch sizes (ABS-SGD-style).

A sixth algorithm, registered purely through the public Algorithm API —
no trainer edits — in the spirit of ABS-SGD (Zhou et al., 2023, PAPERS.md):
heterogeneous workers process batches sized to their speed, gradients are
globally aggregated each step, and the aggregation latency is hidden
behind the next step's computation (one-step delayed synchronization).

Mapping onto the reproduction's masked-lockstep engine:

* **Adaptive batch sizes** — the mega-batch is planned with the paper's
  availability-driven dynamic dispatch (fast replicas get more rounds),
  and between mega-batches per-replica batch sizes follow the same
  deviation-from-mean-update-count scaling as Adaptive SGD (Algorithm 1)
  with the linear lr-scaling rule — the reproduction-scale analogue of
  ABS-SGD's proportional batch allocation.
* **Synchronous aggregation** — each lockstep round averages gradients
  across the *live* replicas of that round (mask-weighted mean: dynamic
  plans mask replicas whose clock passed the horizon, and their zero
  gradients must not dilute the mean — contrast `sync`, whose static plans
  keep every replica live).
* **Delay** — ABS-SGD's one-step-delayed aggregation exists to hide
  communication latency, not to change the update math beyond staleness.
  On the virtual clock we model exactly that effect: the per-round
  all-reduce overlaps compute, so the mega-batch is charged a single
  barrier merge cost instead of `sync`'s one per round.
* **Barrier** — live replicas apply identical mean gradients but at
  per-replica learning rates and update counts, so they drift; the
  barrier takes the update-count-weighted average (Algorithm 2's
  normalization without the global-momentum term).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import adaptive_sgd as asgd
from repro.optim.row_sparse import densify_tree
from repro.utils import tree as tu

from .base import Algorithm, MergeOutcome, RoundTransforms, register, replica_axis_name


def masked_mean_grads(grads, update_mask, axis_name=None):
    """Mean over live replicas, broadcast to all (masked rows get it too,
    but their SGD update is masked off, so they stay frozen). Live replicas
    are counted across the whole mesh: with ``axis_name`` set, the weighted
    sum and the live count are psum-ed over the replica axis before the
    divide (base.py jit rules)."""
    grads = densify_tree(grads)
    w = update_mask.astype(jnp.float32)
    denom = jnp.maximum(tu.replica_all_sum(jnp.sum(w), axis_name), 1.0)

    def one(g):
        wg = w.reshape((-1,) + (1,) * (g.ndim - 1)) * g.astype(jnp.float32)
        mean = tu.replica_all_sum(jnp.sum(wg, axis=0, keepdims=True), axis_name) / denom
        return jnp.broadcast_to(mean, g.shape).astype(g.dtype)

    return tu.tree_map(one, grads)


@register("delayed_sync")
class DelayedSyncAdaptiveBatch(Algorithm):
    # state init: the base default (b = b_max everywhere, no global copies)

    #: masked gradient mean psums across replicas every round
    round_collectives = True

    def plan(self, scheduler, state, mega_samples, fetch_fn):
        return self._plan_dynamic(scheduler, state, mega_samples, fetch_fn)

    def round_transforms(self, cfg):
        axis = replica_axis_name(cfg)  # None under vmap: helpers reduce as-is
        return RoundTransforms(
            grad_transform=lambda g, mask: masked_mean_grads(g, mask, axis)
        )

    def merge(self, trainer, state, plan, replicas):
        alphas = asgd.merge_weights(plan.u, state.b)
        new_global, new_replicas = trainer.merge_models(
            replicas, alphas, None, None, 0.0
        )
        return MergeOutcome(
            replicas=new_replicas, global_model=new_global, alphas=alphas
        )

    def adapt(self, state, plan, cfg):
        return asgd.batch_size_scaling(state.b, state.lr, plan.u, cfg)

    def merges_per_megabatch(self, plan):
        return 1  # aggregation latency is hidden behind compute (the delay)
