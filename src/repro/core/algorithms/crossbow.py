"""CROSSBOW synchronous model averaging (paper §5.1 baseline).

Independent learners corrected toward the replica average after every
round. The correction is a single function — ``crossbow_correct`` — used
both as the traced post-round hook (both engines run it inside the jitted
round body) and, jitted standalone, to read the center as the global model
at the mega-batch barrier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import tree as tu

from .base import Algorithm, MergeOutcome, RoundTransforms, register, replica_axis_name


def crossbow_correct(replicas, c: float, axis_name=None):
    """w_i ← w_i − c (w_i − w̄). Returns (corrected replicas, center w̄).

    The center w̄ averages the *global* replica population; ``axis_name``
    extends the mean across shards when tracing inside the sharded
    executor (base.py jit rules)."""
    center = tu.tree_replica_mean_keepdims(replicas, axis_name)
    corrected = tu.tree_map(
        lambda l, m: (
            l.astype(jnp.float32) - c * (l.astype(jnp.float32) - m)
        ).astype(l.dtype),
        replicas,
        center,
    )
    return corrected, tu.tree_map(lambda m: m[0].astype(jnp.float32), center)


_correct_jit = jax.jit(crossbow_correct, static_argnames=("c",))


@register("crossbow")
class Crossbow(Algorithm):
    #: independent learners: replica divergence *is* the algorithm, so a
    #: membership change must not collapse survivors onto the center —
    #: leavers fold into the center via the final merge, joiners clone it,
    #: survivors keep their own parameters (DESIGN.md §6).
    resize_policy = "preserve"

    #: the center w̄ averages the whole population every round — a host
    #: span cannot bridge that at mega-batch grain
    round_collectives = True

    def round_transforms(self, cfg):
        c = cfg.crossbow_correction
        axis = replica_axis_name(cfg)
        return RoundTransforms(
            post_round=lambda reps: crossbow_correct(reps, c, axis)[0]
        )

    def merge(self, trainer, state, plan, replicas):
        cfg = trainer.cfg
        replicas, center = _correct_jit(replicas, cfg.crossbow_correction)
        return MergeOutcome(
            replicas=replicas,
            global_model=center,
            alphas=np.full(cfg.n_replicas, 1.0 / cfg.n_replicas),
        )

    def merges_per_megabatch(self, plan):
        # synchronous averaging after every batch, like `sync`
        return plan.n_rounds
