"""Gradient aggregation (TensorFlow-mirrored synchronous SGD baseline).

Per-round cross-replica gradient averaging over a static equal plan with
per-GPU batch b_max / R; replicas stay bitwise-identical, so the "merge"
is just a replica slice. The paper models its per-batch all-reduce as one
merge cost per round.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.optim.row_sparse import densify_tree
from repro.utils import tree as tu

from .base import (
    Algorithm,
    MergeOutcome,
    RoundTransforms,
    StateExtras,
    register,
    replica_axis_name,
)


def mean_grads(grads, update_mask, axis_name=None):
    """All replicas share the plain cross-replica mean gradient.

    Replicas see different batches, so row-sparse grads have no common row
    set to average over — densify before the mean. (Static plans: every
    replica is live each round, so the mask does not enter.) The mean spans
    the *global* replica population: under the sharded placement
    ``axis_name`` folds the other shards in (base.py jit rules).
    """
    grads = densify_tree(grads)
    means = tu.tree_replica_mean_keepdims(grads, axis_name)
    return tu.tree_map(
        lambda g, m: jnp.broadcast_to(m, g.shape).astype(g.dtype), grads, means
    )


@register("sync")
class GradientAggregation(Algorithm):
    #: the gradient mean psums across replicas every round — a host span
    #: cannot bridge that at mega-batch grain (base.Algorithm docstring)
    round_collectives = True

    def init_state_extras(self, cfg, params, keep_global_copies):
        b0 = max(cfg.b_min, cfg.b_max // cfg.n_replicas)
        return StateExtras(b=np.full(cfg.n_replicas, float(b0)))

    def resize_b(self, cfg, b, lr, base_lr):
        """The per-replica share b_max/R depends on R itself: a membership
        change re-derives *everyone's* batch size (and linear-scaled lr) so
        the aggregated global batch stays b_max at the new population."""
        extras = self.init_state_extras(cfg, None, False)
        new_b = np.asarray(extras.b, np.float64)
        return new_b, base_lr * new_b / cfg.b_max

    def round_transforms(self, cfg):
        axis = replica_axis_name(cfg)  # None under vmap: helpers reduce as-is
        return RoundTransforms(
            grad_transform=lambda g, mask: mean_grads(g, mask, axis)
        )

    def merge(self, trainer, state, plan, replicas):
        R = trainer.cfg.n_replicas
        return MergeOutcome(
            replicas=replicas,  # identical already
            global_model=tu.tree_replica_slice(replicas, 0),
            alphas=np.full(R, 1.0 / R),
        )

    def merges_per_megabatch(self, plan):
        # "updates the global model after every batch"
        return plan.n_rounds
