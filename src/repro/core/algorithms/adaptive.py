"""The paper's contribution: Adaptive SGD.

Dynamic availability-driven scheduling (§3.1) + batch size scaling
(Algorithm 1) + normalized model merging with perturbation and global-model
momentum (Algorithm 2).
"""
from __future__ import annotations

import numpy as np

from repro.core import adaptive_sgd as asgd
from repro.utils import tree as tu

from .base import Algorithm, MergeOutcome, StateExtras, register


@register("adaptive")
class AdaptiveSGD(Algorithm):
    def init_state_extras(self, cfg, params, keep_global_copies):
        b = np.full(cfg.n_replicas, float(cfg.b_max))
        if keep_global_copies:
            return StateExtras(b=b, global_model=params, prev_global=params)
        return StateExtras(b=b)  # §4 memory-lean merging

    def plan(self, scheduler, state, mega_samples, fetch_fn):
        return self._plan_dynamic(scheduler, state, mega_samples, fetch_fn)

    def merge(self, trainer, state, plan, replicas):
        cfg = trainer.cfg
        R = cfg.n_replicas
        alphas = asgd.merge_weights(plan.u, state.b)
        norms = np.asarray(trainer.replica_norms(replicas))
        n_param = tu.tree_size(replicas) / R
        alphas, pert_active = asgd.apply_perturbation(
            alphas, plan.u, norms / n_param, cfg
        )
        new_global, new_replicas = trainer.merge_models(
            replicas,
            alphas,
            state.global_model,
            state.prev_global,
            cfg.gamma if state.global_model is not None else 0.0,
        )
        return MergeOutcome(
            replicas=new_replicas,
            global_model=new_global,
            prev_global=state.global_model,
            alphas=alphas,
            pert_active=pert_active,
        )

    def adapt(self, state, plan, cfg):
        return asgd.batch_size_scaling(state.b, state.lr, plan.u, cfg)
