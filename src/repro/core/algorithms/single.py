"""Single-worker mini-batch SGD (R = 1).

On one GPU, Adaptive == Elastic == plain SGD (paper §5.2): dynamic
planning degenerates to sequential dispatch, and the merge is the identity
(a slice of the one replica).
"""
from __future__ import annotations

import numpy as np

from repro.utils import tree as tu

from .base import Algorithm, MergeOutcome, register


@register("single")
class SingleWorker(Algorithm):
    def plan(self, scheduler, state, mega_samples, fetch_fn):
        return self._plan_dynamic(scheduler, state, mega_samples, fetch_fn)

    def merge(self, trainer, state, plan, replicas):
        return MergeOutcome(
            replicas=replicas,
            global_model=tu.tree_replica_slice(replicas, 0),
            alphas=np.full(trainer.cfg.n_replicas, 1.0 / trainer.cfg.n_replicas),
        )

    def resolve_n_replicas(self, requested):
        # also neutralizes membership changes: ElasticTrainer.resize
        # resolves through this first, so any elastic schedule degenerates
        # to the single worker (a 1 -> 1 resize is a no-op, never an error)
        return 1
