"""Fleet controller + deterministic fault injection (DESIGN.md §7).

PR 5 made replica membership *elastic* (``ElasticTrainer.resize``); this
module makes it *reactive*. Between mega-batches the trainer hands control
to a :class:`FleetController`, which consumes an event queue of
:class:`FaultEvent`s — replica crashes, preemption notices, join requests,
transient stalls, NaN poisoning — and turns them into targeted membership
changes (``trainer.remove_replicas`` / ``trainer.resize``), quarantine
bookkeeping with exponential-backoff readmission, and health-based
eviction of replicas whose relative speed blows past a timeout factor.

Fault model (DESIGN.md §7):

* ``crash`` — the replica is gone *without* notice: its in-flight updates
  are excluded from the final merge (``remove_replicas(...,
  merge_leavers=False)`` zeroes its rows and redistributes its Alg.-2
  merge weight over the survivors), and the worker enters quarantine with
  exponential-backoff readmission.
* ``preempt`` — the replica got notice (spot/preemptible semantics): its
  updates fold into the final normalized merge like any graceful leaver,
  and it auto-rejoins after its announced absence.
* ``join`` — capacity appears: ``resize(R + 1)`` (the joiner clones the
  merged global with zero momentum, DESIGN.md §6).
* ``stall`` — a transient slowdown: the simulated speed factor is
  multiplied by ``severity`` for ``duration`` mega-batches. No membership
  change by itself — but the health detector may evict the straggler if
  the slowdown exceeds the timeout factor, which is exactly the
  quarantine layer's job (Ma & Rusu: a silently degraded worker poisons
  update quality if it keeps contributing at full weight).
* ``nan`` — a replica's parameters are poisoned with NaN. Detection and
  repair are the *trainer's* job (``guard_nonfinite``): the poisoned rows
  are excluded from the merge and re-cloned from the finite donor; the
  controller only injects the fault.

Every failure path is reproducible: the :class:`FaultInjector` draws its
probabilistic events from ``np.random.default_rng((seed, mega_batch))`` —
keyed by position, not draw history — and scripted schedules fire at exact
mega-batch indices, so tests, the chaos CI job, and the faults benchmark
replay identical event sequences.

The injector is the *test harness*; production liveness is the
:class:`HeartbeatMonitor` (DESIGN.md §10): each process renews a lease
file under the shared fleet directory, and the monitor turns a lease that
stops changing into the same ``FaultEvent`` stream — missed deadline →
``crash``, announced departure → ``preempt``, lease resumed after backoff
→ ``join`` — so ``FleetController`` consumes real signals through the
exact code path the injector exercises deterministically in tests.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.heterogeneity import SpeedModel
from repro.utils import tree as tu
from repro.utils.logging import log

FAULT_KINDS = ("crash", "preempt", "join", "stall", "nan")


@dataclass(frozen=True)
class FaultEvent:
    """One fault at a mega-batch boundary.

    ``replica`` — target slot; None lets the consumer pick (scripted
    events default to the tail slot, probabilistic draws pick uniformly).
    ``duration`` — mega-batches of absence (preempt) / slowdown (stall).
    ``severity`` — stall slowdown multiplier on the simulated speed factor.
    ``process`` — set by the HeartbeatMonitor: the event targets a whole
    *process* (all of its replica slots at once) rather than one slot.
    """

    kind: str
    replica: Optional[int] = None
    duration: int = 2
    severity: float = 4.0
    process: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}"
            )
        if self.duration < 1:
            raise ValueError(f"fault duration must be >= 1, got {self.duration}")


@dataclass
class FaultInjector:
    """Deterministic fault source: scripted schedule + seeded coin flips.

    ``schedule`` maps a mega-batch index to the events that fire before it;
    the ``p_*`` rates add at most one probabilistic event of each kind per
    boundary. Draws are keyed by ``(seed, mega_batch)`` alone, so the event
    at mega-batch 17 is the same whether or not earlier faults fired (and
    identical after a checkpoint restore).
    """

    seed: int = 0
    p_crash: float = 0.0
    p_preempt: float = 0.0
    p_join: float = 0.0
    p_stall: float = 0.0
    p_nan: float = 0.0
    schedule: dict[int, tuple[FaultEvent, ...]] = field(default_factory=dict)

    def events_for(self, mb: int, n_replicas: int) -> list[FaultEvent]:
        events = list(self.schedule.get(int(mb), ()))
        rates = (
            ("crash", self.p_crash), ("preempt", self.p_preempt),
            ("join", self.p_join), ("stall", self.p_stall),
            ("nan", self.p_nan),
        )
        if any(p > 0 for _, p in rates):
            rng = np.random.default_rng((self.seed, int(mb)))
            for kind, p in rates:
                # one draw per kind per boundary, unconditionally: the
                # event stream must not depend on which faults fired
                hit = rng.random() < p
                target = int(rng.integers(max(n_replicas, 1)))
                if p > 0 and hit:
                    events.append(
                        FaultEvent(
                            kind, None if kind == "join" else target
                        )
                    )
        return events


def parse_fault_spec(spec: str) -> FaultInjector:
    """Parse the launcher's ``--faults`` string.

    Comma-separated tokens, two shapes::

        seed=7,p_crash=0.02,p_join=0.05     injector parameters
        3:crash:1,5:join,7:nan:0,9:stall:2:4  MB:kind[:replica[:duration]]

    A scripted event's replica may be omitted (consumer picks the tail
    slot). Unknown parameters, kinds, or negative indices fail fast.
    """
    kwargs: dict = {}
    schedule: dict[int, list[FaultEvent]] = {}
    rate_keys = ("p_crash", "p_preempt", "p_join", "p_stall", "p_nan")
    for token in spec.split(","):
        token = token.strip()
        if not token:
            continue
        if "=" in token:
            key, _, value = token.partition("=")
            key = key.strip()
            if key == "seed":
                kwargs["seed"] = int(value)
            elif key in rate_keys:
                kwargs[key] = float(value)
            else:
                raise ValueError(
                    f"unknown fault parameter {key!r} in --faults {spec!r}"
                )
            continue
        parts = token.split(":")
        if len(parts) < 2:
            raise ValueError(
                f"bad fault token {token!r} (want MB:kind[:replica[:dur]])"
            )
        mb = int(parts[0])
        if mb < 0:
            raise ValueError(f"fault token {token!r} has negative mega-batch")
        replica = (
            int(parts[2]) if len(parts) > 2 and parts[2] != "" else None
        )
        duration = int(parts[3]) if len(parts) > 3 else 2
        schedule.setdefault(mb, []).append(
            FaultEvent(parts[1], replica, duration)
        )
    return FaultInjector(
        schedule={k: tuple(v) for k, v in schedule.items()}, **kwargs
    )


# ---------------------------------------------------------------------------
# heartbeat leases (DESIGN.md §10)

LEASE_PREFIX = "proc-"
LEASE_STATUSES = ("live", "leaving", "done")


def write_lease(leases_dir: str, process_id: int, counter: int,
                status: str = "live", megabatch: Optional[int] = None) -> str:
    """Atomically publish one process's lease (tmp + rename, so readers
    never see a partial write). Returns the lease path."""
    if status not in LEASE_STATUSES:
        raise ValueError(f"unknown lease status {status!r}")
    payload = {"process": int(process_id), "counter": int(counter),
               "status": status}
    if megabatch is not None:
        payload["megabatch"] = int(megabatch)
    path = os.path.join(leases_dir, f"{LEASE_PREFIX}{int(process_id)}.json")
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)
    return path


def read_leases(leases_dir: str) -> dict[int, dict]:
    """All parseable leases under ``leases_dir``: {process_id: payload}."""
    out: dict[int, dict] = {}
    try:
        names = os.listdir(leases_dir)
    except FileNotFoundError:
        return out
    for name in names:
        if not (name.startswith(LEASE_PREFIX) and name.endswith(".json")):
            continue
        try:
            with open(os.path.join(leases_dir, name)) as f:
                payload = json.load(f)
            out[int(payload["process"])] = payload
        except (OSError, ValueError, KeyError):
            continue  # racing writer or stray file; next observe sees it
    return out


class HeartbeatMonitor:
    """Lease-file liveness: the production signal source for
    :class:`FleetController` (DESIGN.md §10).

    Every process renews ``<fleet_dir>/leases/proc-<id>.json`` (an
    incrementing counter plus a status and the last completed mega-batch);
    the monitor watches *content changes*, not embedded timestamps, so
    liveness needs no clock sync between machines sharing the directory —
    a peer is stale when its lease hasn't changed for ``grace`` seconds of
    the local ``clock``. The clock is injectable, so every timing behavior
    is unit-testable without real sleeps.

    ``poll(mb)`` translates observations into the injector-shaped
    ``FaultEvent`` stream: stale or tombstoned → ``crash``; status
    ``'leaving'`` → ``preempt`` (spot semantics); a dead peer whose lease
    resumes changing → ``join``, but only ``rejoin_backoff`` mega-batches
    after its eviction (flap damping); status ``'done'`` is a clean exit,
    never an event. Tombstones (``<fleet_dir>/condemned/p<id>``, written
    by the host-span exchange or by :meth:`note_condemned`) are
    authoritative: a condemned peer is a crash even if its lease looks
    fresh, and a condemned *self* raises — a paused-then-resumed process
    whose peers already evicted it must not keep contributing.

    ``slot_map`` optionally maps process ids to replica slots for
    consumers whose trainer has no spanning context of its own.
    """

    def __init__(self, fleet_dir: str, process_id: Optional[int] = None,
                 interval: float = 0.5, grace: float = 3.0,
                 rejoin_backoff: int = 2, clock=time.monotonic,
                 slot_map: Optional[dict[int, list[int]]] = None):
        self.fleet_dir = fleet_dir
        self.leases_dir = os.path.join(fleet_dir, "leases")
        self.tombs_dir = os.path.join(fleet_dir, "condemned")
        os.makedirs(self.leases_dir, exist_ok=True)
        os.makedirs(self.tombs_dir, exist_ok=True)
        self.process_id = process_id
        self.interval = float(interval)
        self.grace = float(grace)
        self.rejoin_backoff = int(rejoin_backoff)
        self.clock = clock
        self.slot_map = slot_map
        self._counter = 0
        self._megabatch = 0
        self._status = "live"
        self._lock = threading.Lock()
        # pid -> [counter, status, changed_at (local clock), payload]
        self._seen: dict[int, list] = {}
        self._dead: dict[int, int] = {}      # pid -> eviction mega-batch
        self._finished: set[int] = set()
        self._condemned_cache: set[int] = set()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- own lease -----------------------------------------------------
    def renew(self, megabatch: Optional[int] = None,
              status: Optional[str] = None) -> None:
        """Publish a fresh lease. ``status`` is *sticky*: once a caller
        announces ``'leaving'``/``'done'``, the daemon renewals (which pass
        no status) keep republishing it — a per-call default of ``'live'``
        would let a concurrent renewal resurrect an announced departure."""
        if self.process_id is None:
            return
        with self._lock:
            self._counter += 1
            if megabatch is not None:
                self._megabatch = int(megabatch)
            if status is not None:
                self._status = str(status)
            # the lease write must stay ordered with the counter it stamps:
            # publishing outside the lock could emit counters out of order
            # and make a fresh lease look stale to peers
            write_lease(self.leases_dir, self.process_id, self._counter,  # jaxlint: disable=JL104 — lease publish must stay ordered with the counter it stamps
                        status=self._status, megabatch=self._megabatch)

    def start(self) -> None:
        """Renew in a daemon thread every ``interval`` seconds, so long
        device steps (first-compile mega-batches) can't starve liveness."""
        if self._thread is not None:
            return

        def _loop():
            while not self._stop.wait(self.interval):
                self.renew()

        self._thread = threading.Thread(
            target=_loop, name="heartbeat-renew", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # -- observation ---------------------------------------------------
    def observe(self) -> None:
        """Refresh the lease table; a content change resets the peer's
        staleness clock (at *this* process's clock — no skew assumptions)."""
        now = self.clock()
        for pid, payload in read_leases(self.leases_dir).items():
            rec = self._seen.get(pid)
            counter = payload.get("counter")
            status = payload.get("status", "live")
            if rec is None or rec[0] != counter or rec[1] != status:
                self._seen[pid] = [counter, status, now, payload]

    def condemned_ids(self) -> set[int]:
        try:
            names = os.listdir(self.tombs_dir)
        except FileNotFoundError:
            names = []
        self._condemned_cache = {
            int(n[1:]) for n in names if n.startswith("p")
        } | self._condemned_cache
        return set(self._condemned_cache)

    def note_condemned(self, pid: int) -> None:
        self._condemned_cache.add(int(pid))

    def peer_fresh(self, pid: int) -> bool:
        """Is this peer's lease still changing? (Exchange wait predicate:
        False means stop waiting for its contributions.)"""
        self.observe()
        rec = self._seen.get(pid)
        if rec is None:
            return False
        if rec[1] == "done":
            return False
        return (self.clock() - rec[2]) <= self.grace

    def live_processes(self) -> set[int]:
        self.observe()
        now = self.clock()
        condemned = self.condemned_ids()
        return {
            pid
            for pid, rec in self._seen.items()
            if rec[1] != "done"
            and pid not in condemned
            and (now - rec[2]) <= self.grace
        }

    def mark_dead(self, pid: int, mb: int) -> None:
        """Record an eviction decided elsewhere (e.g. by exchange-agreed
        peer observation) so poll() doesn't re-report it."""
        self._dead.setdefault(int(pid), int(mb))

    def last_megabatch(self, pid: int) -> Optional[int]:
        rec = self._seen.get(pid)
        return None if rec is None else rec[3].get("megabatch")

    # -- the event source ----------------------------------------------
    def poll(self, mb: int) -> list[FaultEvent]:
        """Observations → injector-shaped events for this boundary."""
        self.observe()
        now = self.clock()
        condemned = self.condemned_ids()
        if self.process_id is not None and self.process_id in condemned:
            raise RuntimeError(
                f"process {self.process_id} was condemned by a fleet peer "
                "(heartbeat lease went stale); restart to rejoin"
            )
        events: list[FaultEvent] = []
        for pid in sorted(self._seen):
            if pid == self.process_id or pid in self._finished:
                continue
            rec = self._seen[pid]
            status = rec[1]
            if pid in self._dead:
                # rejoin-after-backoff: the lease must be changing again
                if (
                    pid not in condemned
                    and status == "live"
                    and (now - rec[2]) <= self.grace
                    and mb - self._dead[pid] >= self.rejoin_backoff
                ):
                    del self._dead[pid]
                    events.append(FaultEvent("join", process=pid))
                continue
            if status == "done":
                self._finished.add(pid)
                continue
            stale = (now - rec[2]) > self.grace
            if pid in condemned or stale:
                events.append(FaultEvent("crash", process=pid))
                self._dead[pid] = int(mb)
            elif status == "leaving":
                events.append(
                    FaultEvent(
                        "preempt", process=pid,
                        duration=rec[3].get("duration", 2),
                    )
                )
                self._dead[pid] = int(mb)
        return events


@dataclass
class _Quarantined:
    """One absent worker awaiting readmission."""

    rejoin_at: int      # mega-batch index when readmission is due
    level: int = 0      # backoff escalation level (crashes only)
    graceful: bool = False


@dataclass
class FleetController:
    """Reactive membership driver, called by ``ElasticTrainer.run`` as
    ``state = fleet.step(trainer, state, mb)`` at each mega-batch boundary.

    Order of business per tick: expire stalls → readmit quarantined
    workers whose backoff elapsed → apply injected fault events → evict
    unhealthy stragglers. Membership always stays within
    ``[min_replicas, max_replicas]``; algorithms with
    ``resize_policy='fixed'`` keep their population (membership events are
    logged as skipped; NaN injection still fires — the trainer's guard
    handles it without a resize).

    Health detection: a replica whose relative speed factor exceeds
    ``timeout_factor``× the population median is treated as preempted
    (graceful eviction — its updates are sound, just late) and re-admitted
    after backoff. Feed it a ``MeasuredSpeedModel`` and this is real
    straggler detection; with the simulated model it reacts to injected
    stalls. ``timeout_factor=0`` disables the detector.

    Quarantine: readmission delay is ``backoff * 2**level`` mega-batches
    (capped at ``backoff_cap``); a crash within ``probation`` mega-batches
    of the last readmission escalates the level, so a flapping worker is
    kept out for exponentially longer.

    Every action lands in ``self.events`` (list of dicts with mega-batch,
    action, replica slot) — the chaos tests and the faults benchmark
    assert against this log.
    """

    injector: Optional[FaultInjector] = None
    monitor: Optional[Any] = None
    min_replicas: int = 1
    max_replicas: Optional[int] = None
    timeout_factor: float = 0.0
    backoff: int = 2
    backoff_cap: int = 16
    probation: int = 4
    verbose: bool = False
    events: list = field(default_factory=list)
    _quarantine: list = field(default_factory=list)
    _stalls: dict = field(default_factory=dict)  # slot -> [expire_mb, mult]
    _last_rejoin_mb: Optional[int] = None
    _last_level: int = 0

    # ------------------------------------------------------------------
    def step(self, trainer, state, mb: int):
        elastic = getattr(trainer.algo, "resize_policy", "merge") != "fixed"
        span = getattr(trainer, "_span", None)

        # 0. heartbeat liveness (DESIGN.md §10): renew our lease with the
        # progress the runner/peers key off, then turn peer observations
        # into the same event stream the injector produces. Under a
        # spanning trainer the proposals are exchange-agreed first, so
        # every survivor applies identical evictions at this boundary even
        # if their local grace periods elapse a boundary apart.
        if self.monitor is not None:
            self.monitor.renew(megabatch=mb)
            observed = self.monitor.poll(mb)
            if span is not None:
                observed = span.agree_events(observed)
                for ev in observed:
                    if ev.kind in ("crash", "preempt"):
                        self.monitor.mark_dead(ev.process, mb)
            for ev in observed:
                state = self._apply_event(trainer, state, mb, ev)

        # 1. transient stalls that ran their course
        for slot, (expire, mult) in sorted(self._stalls.items()):
            if mb >= expire:
                if slot < trainer.cfg.n_replicas and isinstance(
                    trainer.speed, SpeedModel
                ):
                    # a prefetched plan was costed with the stalled factor
                    trainer.invalidate_prefetch()
                    trainer.speed.factors[slot] /= mult
                del self._stalls[slot]
                self._log(mb, "stall_recovered", slot)

        # 2. quarantined workers whose backoff elapsed (injector-driven
        # evictions only: monitor evictions rejoin via the lease signal)
        for q in [q for q in self._quarantine if q.rejoin_at <= mb]:
            cap = self.max_replicas or np.inf
            if not elastic or trainer.cfg.n_replicas >= cap:
                continue  # stays queued until there is room
            state = trainer.resize(state, trainer.cfg.n_replicas + 1)
            self._quarantine.remove(q)
            self._last_rejoin_mb, self._last_level = mb, q.level
            self._log(
                mb, "rejoin", trainer.cfg.n_replicas - 1, level=q.level
            )

        # 3. injected fault events (slot-grain; a spanning trainer changes
        # membership at process grain through the monitor path instead)
        if self.injector is not None and span is None:
            for ev in self.injector.events_for(mb, trainer.cfg.n_replicas):
                state = self._apply_event(trainer, state, mb, ev)

        # 4. health: evict the straggler if it blew the timeout factor
        if (
            self.timeout_factor > 0
            and elastic
            and span is None
            and trainer.cfg.n_replicas > self.min_replicas
        ):
            factors = np.asarray(trainer.speed.factors, np.float64)
            worst = int(np.argmax(factors))
            median = float(np.median(factors))
            if factors[worst] > self.timeout_factor * max(median, 1e-12):
                state = self._evict(
                    trainer, state, mb, worst, graceful=True,
                    reason="timeout",
                )
        return state

    # ------------------------------------------------------------------
    def _apply_event(self, trainer, state, mb: int, ev: FaultEvent):
        if ev.process is not None:
            return self._apply_process_event(trainer, state, mb, ev)
        R = trainer.cfg.n_replicas
        elastic = getattr(trainer.algo, "resize_policy", "merge") != "fixed"
        slot = ev.replica if ev.replica is not None else R - 1
        if ev.kind != "join" and not 0 <= slot < R:
            self._log(mb, f"{ev.kind}_skipped", slot, reason="no such slot")
            return state

        if ev.kind == "join":
            cap = self.max_replicas or np.inf
            if not elastic:
                self._log(mb, "join_skipped", None, reason="fixed membership")
            elif R >= cap:
                self._log(mb, "join_skipped", None, reason="at max_replicas")
            else:
                state = trainer.resize(state, R + 1)
                self._log(mb, "join", R)
            return state

        if ev.kind in ("crash", "preempt"):
            if not elastic:
                self._log(
                    mb, f"{ev.kind}_skipped", slot, reason="fixed membership"
                )
            elif R <= self.min_replicas:
                self._log(
                    mb, f"{ev.kind}_skipped", slot, reason="at min_replicas"
                )
            else:
                state = self._evict(
                    trainer, state, mb, slot,
                    graceful=(ev.kind == "preempt"),
                    reason=ev.kind,
                    rejoin_in=ev.duration if ev.kind == "preempt" else None,
                )
            return state

        if ev.kind == "stall":
            if isinstance(trainer.speed, SpeedModel) and slot not in self._stalls:
                # the prefetched plan (if any) was costed pre-stall: revoke
                # it so the next plan sees the stalled factor (DESIGN.md §8)
                trainer.invalidate_prefetch()
                trainer.speed.factors[slot] *= ev.severity
                self._stalls[slot] = [mb + ev.duration, ev.severity]
                self._log(
                    mb, "stall", slot, duration=ev.duration,
                    severity=ev.severity,
                )
            else:
                # measured speeds: a real stall shows up in the EMAs and is
                # the health detector's business, nothing to simulate
                self._log(mb, "stall_skipped", slot, reason="not simulated")
            return state

        # 'nan': poison the slot's parameters; the trainer's non-finite
        # guard must exclude it from the merge and heal it
        poisoned = tu.tree_map(
            lambda l: l.at[slot].set(jnp.asarray(jnp.nan, l.dtype)),
            state.replicas,
        )
        self._log(mb, "nan", slot)
        return dataclasses.replace(state, replicas=poisoned)

    def _apply_process_event(self, trainer, state, mb: int, ev: FaultEvent):
        """A monitor-sourced event targeting a whole process: resolve its
        replica slots (trainer's spanning context, else the monitor's
        slot_map) and apply one multi-slot membership change. No
        quarantine entry is queued — a monitor-evicted process rejoins
        only when its lease resumes (the monitor's ``join`` path)."""
        pid = ev.process
        R = trainer.cfg.n_replicas
        elastic = getattr(trainer.algo, "resize_policy", "merge") != "fixed"
        spanning = getattr(trainer, "_span", None) is not None
        slots = None
        if hasattr(trainer, "process_slots"):
            slots = trainer.process_slots(pid)
        if slots is None and self.monitor is not None and self.monitor.slot_map:
            slots = self.monitor.slot_map.get(pid)

        if ev.kind == "join":
            n = len(slots) if slots else 1
            cap = self.max_replicas or np.inf
            if spanning:
                # v1: a host-span fleet cannot re-split live device state
                # onto a returning process; it rejoins on restart instead
                self._log(mb, "join_skipped", None, process=pid,
                          reason="spanning rejoin needs restart")
            elif not elastic:
                self._log(mb, "join_skipped", None, process=pid,
                          reason="fixed membership")
            elif R + n > cap:
                self._log(mb, "join_skipped", None, process=pid,
                          reason="at max_replicas")
            else:
                state = trainer.resize(state, R + n)
                self._log(mb, "join", list(range(R, R + n)), process=pid)
            return state

        if ev.kind not in ("crash", "preempt"):
            self._log(mb, f"{ev.kind}_skipped", None, process=pid,
                      reason="process events are crash/preempt/join")
            return state
        if slots is None:
            self._log(mb, f"{ev.kind}_skipped", None, process=pid,
                      reason="no slot mapping for process")
            return state
        if not elastic:
            self._log(mb, f"{ev.kind}_skipped", list(slots), process=pid,
                      reason="fixed membership")
            return state
        if R - len(slots) < self.min_replicas:
            self._log(mb, f"{ev.kind}_skipped", list(slots), process=pid,
                      reason="at min_replicas")
            return state

        graceful = ev.kind == "preempt"
        slots = sorted(int(s) for s in slots)
        state = trainer.remove_replicas(state, slots, merge_leavers=graceful)
        dropset = set(slots)
        self._stalls = {
            s - sum(1 for d in slots if d < s): v
            for s, v in self._stalls.items()
            if s not in dropset
        }
        self._log(mb, "evict", slots, reason=ev.kind, graceful=graceful,
                  process=pid)
        return state

    def _evict(self, trainer, state, mb, slot, graceful, reason,
               rejoin_in=None):
        level = 0
        if not graceful and self._last_rejoin_mb is not None and (
            mb - self._last_rejoin_mb <= self.probation
        ):
            level = self._last_level + 1
        if rejoin_in is None:
            rejoin_in = min(self.backoff * (2 ** level), self.backoff_cap)
        state = trainer.remove_replicas(
            state, [slot], merge_leavers=graceful
        )
        # survivor slots above the evicted one shift down by one
        self._stalls = {
            (s - 1 if s > slot else s): v
            for s, v in self._stalls.items()
            if s != slot
        }
        self._quarantine.append(
            _Quarantined(
                rejoin_at=mb + max(1, int(rejoin_in)),
                level=level,
                graceful=graceful,
            )
        )
        self._log(
            mb, "evict", slot, reason=reason, graceful=graceful,
            level=level, rejoin_in=int(rejoin_in),
        )
        return state

    def _log(self, mb: int, action: str, slot, **extra) -> None:
        entry = {"mb": int(mb), "action": action, "replica": slot, **extra}
        self.events.append(entry)
        if self.verbose:
            log(f"[fleet] mb={mb}", **{k: v for k, v in entry.items()
                                       if k != "mb"})
