"""ElasticTrainer: mega-batch training loop for Adaptive SGD + all baselines.

Algorithms (paper §5.1):
  * ``adaptive``  — the paper's contribution: dynamic scheduling + batch size
                    scaling (Alg. 1) + normalized model merging (Alg. 2).
  * ``elastic``   — elastic model averaging (K-step averaging): static equal
                    batches, plain average merge, same momentum update rule.
  * ``sync``      — gradient aggregation (TensorFlow-mirrored): per-round
                    gradient averaging, per-GPU batch = b_max / R.
  * ``crossbow``  — CROSSBOW synchronous model averaging: independent
                    learners corrected toward the replica average each round.
  * ``single``    — one worker (R=1); Adaptive == Elastic == mini-batch SGD.

The trainer is model-agnostic: a *model* is ``{'init': rng->params,
'loss_fn': (params, batch)->(loss, aux)}`` and a *provider* supplies padded
fixed-slot batches (data/providers.py). A model may additionally expose
``'sparse_grad_fn': (params, batch) -> ((loss, aux), grads)`` with
embedding-style grad leaves as RowSparseGrad (DESIGN.md §3) — the trainer
then runs the row-sparse update path (``sparse_grads=False`` forces dense
autodiff, the differential oracle). Distribution: the same jitted round
function runs single-device (tests) or sharded — leaves carry a leading
replica dim R which the launcher shards over the replica mesh axis.

Execution engines (DESIGN.md §1):
  * ``scan`` (default) — device-resident mega-batch engine. The whole plan
    is pre-stacked into (n_rounds, R, ...) arrays and all rounds run inside
    one jitted ``jax.lax.scan`` with replica/momentum buffers donated;
    loss/accuracy/n_valid accumulate on device, so the host syncs once per
    mega-batch instead of once per round.
  * ``legacy_loop`` — the original per-round host loop (one jitted dispatch
    + host stack + metric sync per round). Kept as an escape hatch and as
    the oracle for differential testing (tests/test_megabatch_engine.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig
from repro.core import adaptive_sgd as asgd
from repro.core.heterogeneity import CostModel, SpeedModel
from repro.core.scheduler import DynamicScheduler, MegaBatchPlan
from repro.optim.row_sparse import densify_tree
from repro.optim.sgd import SGDConfig, init_momentum, sgd_update
from repro.utils import tree as tu
from repro.utils.logging import MetricsLog, log

PyTree = Any

ENGINES = ("scan", "legacy_loop")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ElasticState:
    replicas: PyTree                 # leaves (R, ...)
    global_model: Optional[PyTree]
    prev_global: Optional[PyTree]
    momentum: Optional[PyTree]
    b: np.ndarray                    # per-replica batch size (may be fractional)
    lr: np.ndarray                   # per-replica learning rate
    megabatch_idx: int = 0


@dataclass
class ElasticTrainer:
    model: dict
    provider: Any
    cfg: ElasticConfig
    sgd: SGDConfig = field(default_factory=SGDConfig)
    base_lr: float = 0.05
    speed: Optional[SpeedModel] = None
    merge_cost: float = 5e-3         # virtual seconds per merge (all-reduce)
    keep_global_copies: bool = True  # False = paper §4 memory-lean merging
    engine: str = "scan"             # 'scan' | 'legacy_loop' (see module doc)
    round_bucket: bool = True        # pad n_rounds to pow2: bounds recompiles
    sparse_grads: bool = True        # use the model's row-sparse grad path if
                                     # it provides one; False = dense autodiff
                                     # (the differential oracle, DESIGN.md §3)
    seed: int = 0

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {self.engine!r}")
        if self.speed is None:
            self.speed = SpeedModel(self.cfg.n_replicas, seed=self.seed)
        self.cost = CostModel(self.speed)
        self.scheduler = DynamicScheduler(self.cfg, self.cost)
        self._build_jits()

    # ------------------------------------------------------------------
    # jitted device functions
    # ------------------------------------------------------------------
    def _build_jits(self):
        loss_fn = self.model["loss_fn"]
        # Sparse-gradient path (DESIGN.md §3): the model may expose
        # ((loss, aux), grads) directly, with embedding-style grads as
        # RowSparseGrad leaves — same calling convention as value_and_grad.
        sparse_fn = self.model.get("sparse_grad_fn") if self.sparse_grads else None
        grad_fn = sparse_fn or jax.value_and_grad(loss_fn, has_aux=True)

        def _crossbow_correct(replicas, c):
            center = tu.tree_map(
                lambda l: jnp.mean(l.astype(jnp.float32), axis=0, keepdims=True),
                replicas,
            )
            corrected = tu.tree_map(
                lambda l, m: (
                    l.astype(jnp.float32) - c * (l.astype(jnp.float32) - m)
                ).astype(l.dtype),
                replicas,
                center,
            )
            return corrected, tu.tree_map(lambda m: m[0].astype(jnp.float32), center)

        self._crossbow = jax.jit(_crossbow_correct, static_argnames=("c",))

        def round_body(replicas, momentum, batch, lr_vec, update_mask,
                       avg_grads, crossbow_c):
            """One lockstep round; shared by both engines (traced inside the
            scan for the device-resident engine, jitted alone for legacy)."""
            (loss, aux), grads = jax.vmap(grad_fn)(replicas, batch)
            if avg_grads:  # gradient aggregation: all replicas share the mean
                # replicas see different batches, so row-sparse grads have no
                # common row set to average over — densify before the mean
                grads = densify_tree(grads)
                grads = tu.tree_map(
                    lambda g: jnp.broadcast_to(
                        jnp.mean(g, axis=0, keepdims=True), g.shape
                    ),
                    grads,
                )
            new_replicas, new_momentum = sgd_update(
                replicas,
                grads,
                lr_vec,
                self.sgd,
                momentum_state=momentum,
                update_mask=update_mask,
                replica_dim=True,
            )
            if crossbow_c > 0.0:
                corrected, _ = _crossbow_correct(new_replicas, crossbow_c)
                # fully-masked (bucket-padding) rounds must be exact no-ops
                live = update_mask.max() > 0
                new_replicas = tu.tree_map(
                    lambda c, r: jnp.where(live, c, r), corrected, new_replicas
                )
            metrics = {
                "loss": loss,
                "accuracy": aux["accuracy"],
                "n_valid": aux["n_valid"],
            }
            return new_replicas, new_momentum, metrics

        def round_fn(replicas, momentum, batch, lr_vec, update_mask, avg_grads):
            return round_body(
                replicas, momentum, batch, lr_vec, update_mask, avg_grads, 0.0
            )

        self._round = jax.jit(round_fn, static_argnames=("avg_grads",))

        def megabatch_fn(replicas, momentum, batches, lr_vec, update_mask,
                         avg_grads, crossbow_c):
            """Scan-fused mega-batch: all rounds in one device program.

            ``batches`` leaves and ``update_mask`` carry a leading
            (n_rounds,) scan dim. Per-round metrics reduce on device into
            4 scalars — the only values the host ever pulls.
            """

            def body(carry, xs):
                reps, mom = carry
                batch, mask = xs
                new_reps, new_mom, m = round_body(
                    reps, mom, batch, lr_vec, mask, avg_grads, crossbow_c
                )
                wsum = jnp.sum(mask)
                denom = jnp.maximum(wsum, 1.0)
                stats = jnp.stack(
                    [
                        jnp.sum(m["loss"] * mask) / denom,
                        jnp.sum(m["accuracy"] * mask) / denom,
                        jnp.sum(m["n_valid"] * mask),
                        (wsum > 0).astype(jnp.float32),
                    ]
                )
                return (new_reps, new_mom), stats

            (replicas, momentum), stats = jax.lax.scan(
                body, (replicas, momentum), (batches, update_mask)
            )
            live = stats[:, 3]
            n_live = jnp.maximum(jnp.sum(live), 1.0)
            metrics = {
                "loss": jnp.sum(stats[:, 0]) / n_live,
                "accuracy": jnp.sum(stats[:, 1]) / n_live,
                "n_valid": jnp.sum(stats[:, 2]),
                "rounds_live": jnp.sum(live),
            }
            return replicas, momentum, metrics

        # Donate the replica/momentum buffers: the engine updates them in
        # place on device (no copy per mega-batch). CPU XLA cannot donate —
        # skip there to avoid a warning per compile.
        donate = (0, 1) if jax.default_backend() in ("tpu", "gpu") else ()
        self._megabatch = jax.jit(
            megabatch_fn,
            static_argnames=("avg_grads", "crossbow_c"),
            donate_argnums=donate,
        )

        def merge_fn(replicas, alphas, global_model, prev_global, gamma):
            new_global = asgd.normalized_merge(
                replicas, alphas, global_model, prev_global, gamma
            )
            R = jax.tree_util.tree_leaves(replicas)[0].shape[0]
            new_replicas = tu.tree_broadcast_replicas(new_global, R)
            return new_global, new_replicas

        self._merge = jax.jit(merge_fn, static_argnames=("gamma",))
        self._norms = jax.jit(lambda r: tu.tree_l2_norm_per_replica(r))
        self._eval = jax.jit(loss_fn)

    # ------------------------------------------------------------------
    # state init
    # ------------------------------------------------------------------
    def init_state(self) -> ElasticState:
        R = self.cfg.n_replicas
        rng = jax.random.PRNGKey(self.seed)
        params = self.model["init"](rng)
        replicas = tu.tree_broadcast_replicas(params, R)
        momentum = init_momentum(replicas, self.sgd)
        if self.cfg.algorithm == "sync":
            b0 = max(self.cfg.b_min, self.cfg.b_max // R)
        else:
            b0 = self.cfg.b_max  # paper: initialize at b_max (Fig. 10a)
        b = np.full(R, float(b0))
        lr = np.full(R, self.base_lr * b0 / self.cfg.b_max)
        keep = self.keep_global_copies and self.cfg.algorithm in ("adaptive", "elastic")
        return ElasticState(
            replicas=replicas,
            global_model=params if keep else None,
            prev_global=params if keep else None,
            momentum=momentum,
            b=b,
            lr=lr,
        )

    # ------------------------------------------------------------------
    # round execution engines
    # ------------------------------------------------------------------
    def _run_rounds_scan(self, state, plan, b_slots, avg_grads, crossbow_c):
        """Device-resident engine: pre-stack the plan, scan all rounds."""
        R = self.cfg.n_replicas
        min_rounds = _next_pow2(plan.n_rounds) if self.round_bucket else plan.n_rounds
        grid = plan.payload_grid(R, min_rounds=max(min_rounds, 1))
        batches_np, mask = self.provider.stack_plan(grid, b_slots)
        batches = {k: jnp.asarray(v) for k, v in batches_np.items()}
        replicas, momentum, m = self._megabatch(
            state.replicas,
            state.momentum,
            batches,
            jnp.asarray(state.lr, jnp.float32),
            jnp.asarray(mask),
            avg_grads=avg_grads,
            crossbow_c=crossbow_c,
        )
        # single host sync per mega-batch
        loss, acc = float(m["loss"]), float(m["accuracy"])
        return replicas, momentum, loss, acc

    def _run_rounds_legacy(self, state, plan, b_slots, avg_grads, crossbow_c):
        """Original per-round host loop (escape hatch / differential oracle)."""
        R = self.cfg.n_replicas
        grid = plan.payload_grid(R)
        replicas, momentum = state.replicas, state.momentum
        losses, accs = [], []
        for row in grid:
            payloads = [p if p is not None else self.provider.empty(b_slots) for p in row]
            update_mask = jnp.asarray(
                [1.0 if p is not None else 0.0 for p in row], jnp.float32
            )
            batch = {k: jnp.asarray(v) for k, v in self.provider.stack(payloads).items()}
            lr_vec = jnp.asarray(state.lr, jnp.float32)
            replicas, momentum, m = self._round(
                replicas, momentum, batch, lr_vec, update_mask, avg_grads
            )
            w = np.asarray(update_mask)
            if w.sum() > 0:
                losses.append(float((np.asarray(m["loss"]) * w).sum() / w.sum()))
                accs.append(float((np.asarray(m["accuracy"]) * w).sum() / w.sum()))
            if crossbow_c > 0.0:
                replicas, _ = self._crossbow(replicas, crossbow_c)
        loss = float(np.mean(losses)) if losses else float("nan")
        acc = float(np.mean(accs)) if accs else float("nan")
        return replicas, momentum, loss, acc

    # ------------------------------------------------------------------
    # one mega-batch
    # ------------------------------------------------------------------
    def run_megabatch(self, state: ElasticState) -> tuple[ElasticState, dict]:
        """Plan, execute, and merge one mega-batch; returns (new_state, info).

        Donation contract: with the scan engine on TPU/GPU, ``state.replicas``
        and ``state.momentum`` are DONATED to the device program — treat
        ``state`` as consumed and continue from the returned state only.
        (On CPU donation is disabled and old states stay readable.)
        """
        cfg = self.cfg
        R = cfg.n_replicas
        algo = cfg.algorithm
        mega_samples = cfg.mega_batch * cfg.b_max
        b_slots = cfg.b_max

        def fetch(i, take):
            payload = self.provider.fetch(take, b_slots)
            return payload, self.provider.work_units(payload)

        if algo in ("adaptive", "single"):
            plan = self.scheduler.plan_megabatch(
                np.round(state.b).astype(np.int64), mega_samples, fetch_fn=fetch
            )
        else:  # elastic / sync / crossbow: static equal partitioning
            per_rep = max(1, int(round(mega_samples / (R * state.b[0]))))
            plan = self.scheduler.plan_static(int(state.b[0]), per_rep, fetch_fn=fetch)

        # ---- execute lockstep rounds ----
        avg_grads = algo == "sync"
        crossbow_c = cfg.crossbow_correction if algo == "crossbow" else 0.0
        run_rounds = (
            self._run_rounds_legacy if self.engine == "legacy_loop"
            else self._run_rounds_scan
        )
        replicas, momentum, train_loss, train_acc = run_rounds(
            state, plan, b_slots, avg_grads, crossbow_c
        )

        # ---- merge ----
        pert_active = False
        alphas = np.full(R, 1.0 / R)
        if algo == "adaptive":
            alphas = asgd.merge_weights(plan.u, state.b)
            norms = np.asarray(self._norms(replicas))
            n_param = tu.tree_size(replicas) / R
            alphas, pert_active = asgd.apply_perturbation(
                alphas, plan.u, norms / n_param, cfg
            )
            new_global, replicas = self._merge(
                replicas,
                jnp.asarray(alphas, jnp.float32),
                state.global_model,
                state.prev_global,
                cfg.gamma if state.global_model is not None else 0.0,
            )
            prev_global = state.global_model
            new_b, new_lr = asgd.batch_size_scaling(state.b, state.lr, plan.u, cfg)
        elif algo == "elastic":
            new_global, replicas = self._merge(
                replicas,
                jnp.asarray(alphas, jnp.float32),
                state.global_model,
                state.prev_global,
                cfg.gamma if state.global_model is not None else 0.0,
            )
            prev_global = state.global_model
            new_b, new_lr = state.b, state.lr
        elif algo == "crossbow":
            replicas, new_global = self._crossbow(replicas, cfg.crossbow_correction)
            prev_global, new_b, new_lr = None, state.b, state.lr
        else:  # sync / single: replicas are identical already
            new_global = tu.tree_replica_slice(replicas, 0)
            prev_global, new_b, new_lr = None, state.b, state.lr

        # merge happens at the barrier and costs virtual time on every replica.
        # sync/crossbow merge after EVERY batch (paper: TensorFlow "updates the
        # global model after every batch"), elastic/adaptive once per mega-batch.
        n_merges = plan.n_rounds if algo in ("sync", "crossbow") else 1
        self.scheduler.clock.t[:] += self.merge_cost * n_merges
        virtual_time = float(self.scheduler.clock.t.max())

        new_state = ElasticState(
            replicas=replicas,
            global_model=new_global,
            prev_global=prev_global,
            momentum=momentum,
            b=np.asarray(new_b, np.float64),
            lr=np.asarray(new_lr, np.float64),
            megabatch_idx=state.megabatch_idx + 1,
        )
        info = {
            "u": plan.u.tolist(),
            "b": np.round(np.asarray(new_b), 2).tolist(),
            "lr": np.round(np.asarray(new_lr), 6).tolist(),
            "alphas": np.round(alphas, 4).tolist(),
            "pert_active": bool(pert_active),
            "train_loss": train_loss,
            "train_accuracy": train_acc,
            "virtual_time": virtual_time,
            "n_rounds": plan.n_rounds,
        }
        return new_state, info

    # ------------------------------------------------------------------
    # evaluation + full run
    # ------------------------------------------------------------------
    def evaluate(self, params: PyTree, test_batches: list) -> dict:
        tot_acc, tot_loss, tot_n = 0.0, 0.0, 0.0
        for payload in test_batches:
            batch = {
                k: jnp.asarray(v)
                for k, v in self.provider.stack([payload]).items()
            }
            batch = {k: v[0] for k, v in batch.items()}
            loss, aux = self._eval(params, batch)
            n = float(aux["n_valid"])
            tot_acc += float(aux["accuracy"]) * n
            tot_loss += float(loss) * n
            tot_n += n
        return {
            "accuracy": tot_acc / max(tot_n, 1.0),
            "loss": tot_loss / max(tot_n, 1.0),
        }

    def run(
        self,
        n_megabatches: int,
        test_batches: Optional[list] = None,
        eval_every: int = 1,
        verbose: bool = False,
    ) -> tuple[ElasticState, MetricsLog]:
        state = self.init_state()
        mlog = MetricsLog()
        t0 = time.perf_counter()
        for mb in range(n_megabatches):
            state, info = self.run_megabatch(state)
            if test_batches is not None and (mb + 1) % eval_every == 0:
                ev = self.evaluate(state.global_model, test_batches)
                info.update(accuracy=ev["accuracy"], test_loss=ev["loss"])
            info["megabatch"] = mb + 1
            info["wall_clock"] = time.perf_counter() - t0
            mlog.append(**info)
            if verbose:
                log(
                    f"[{self.cfg.algorithm}] mb={mb+1}",
                    loss=round(info["train_loss"], 4),
                    acc=round(info.get("accuracy", float("nan")), 4),
                    u=info["u"],
                    b=info["b"],
                    vt=round(info["virtual_time"], 3),
                )
        return state, mlog
