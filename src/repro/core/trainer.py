"""ElasticTrainer: the generic mega-batch training engine.

The trainer contains **no algorithm-specific branching**: everything that
distinguishes Adaptive SGD from its baselines (K-step averaging, gradient
aggregation, CROSSBOW model averaging, single-worker SGD, delayed-sync
adaptive batching, ...) lives in a pluggable strategy resolved from
``cfg.algorithm`` by the ``core/algorithms`` registry. The engine drives
the strategy through five hooks (DESIGN.md §4):

  init_state_extras → plan → round_transforms (traced) → merge → adapt

A *model* is a ``TrainableModel`` (models/protocol.py): ``init``,
``loss_fn``, optional ``sparse_grad_fn`` whose embedding-style grad leaves
are RowSparseGrad (DESIGN.md §3) — the trainer then runs the row-sparse
update path (``sparse_grads=False`` forces dense autodiff, the
differential oracle). The legacy ``{'init': ..., 'loss_fn': ...}`` dict is
still accepted and coerced. A *provider* supplies padded fixed-slot
batches (data/providers.py).

Placement (DESIGN.md §5, selected by ``cfg.placement``):
  * ``vmap`` (default) — every replica lives in one device program,
    vectorized over the leading R dim. Single-device; the differential
    oracle for the sharded mode.
  * ``sharded`` — the leading replica dim of params/momentum/batches is
    laid out over a 1-D ``replica`` device mesh with ``shard_map``: each
    shard runs its own replicas' rounds (same traced round_body, same
    jit/donation semantics per shard), and the barrier merge /
    replica-norm reductions become collectives (psum / axis-gather) over
    the mesh axis. Algorithm hooks are placement-agnostic: cross-replica
    math inside RoundTransforms goes through the placement-aware helpers
    (core/algorithms/base.py ``replica_axis_name``).

Execution engines (DESIGN.md §1):
  * ``scan`` (default) — device-resident mega-batch engine. The whole plan
    is pre-stacked into (n_rounds, R, ...) arrays and all rounds run inside
    one jitted ``jax.lax.scan`` with replica/momentum buffers donated;
    loss/accuracy/n_valid accumulate on device, so the host syncs once per
    mega-batch instead of once per round.
  * ``legacy_loop`` — the original per-round host loop (one jitted dispatch
    + host stack + metric sync per round). Kept as an escape hatch and as
    the oracle for differential testing (tests/test_megabatch_engine.py).

Both engines trace the *same* ``round_body`` — including the algorithm's
``RoundTransforms`` (gradient transform + post-round correction) — so the
strategy hooks behave identically under either executor.

Elastic membership (DESIGN.md §6): the replica count R may change between
mega-batches — ``resize`` re-plans (scheduler + speed model at the new R),
re-shards (replica mesh + cached shard_map executors), and carries state
(final normalized merge folds leaving replicas in; joiners clone the merged
global with zero momentum). ``run(resize_schedule=...)`` drives it from a
mega-batch→R schedule; jit caches are reused so revisiting a population
shape recompiles nothing.
"""
from __future__ import annotations

import copy
import dataclasses
import functools
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ElasticConfig
from repro.core import adaptive_sgd as asgd
from repro.core import algorithms
from repro.core.heterogeneity import (
    CostModel, MeasuredSpeedModel, ShardWindowTimer, SpeedModel,
)
from repro.core.scheduler import DynamicScheduler
from repro.data.batcher import StagingBuffers
from repro.models.protocol import TrainableModel, as_trainable_model
from repro.optim.sgd import SGDConfig, init_momentum, sgd_update
from repro.sharding.rules import REPLICA_AXIS, ReplicaMeshPool, replica_spec
from repro.utils import tree as tu
from repro.utils.logging import MetricsLog, log

PyTree = Any

ENGINES = ("scan", "legacy_loop")
PLACEMENTS = ("vmap", "sharded")


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class ElasticState:
    replicas: PyTree                 # leaves (R, ...)
    global_model: Optional[PyTree]
    prev_global: Optional[PyTree]
    momentum: Optional[PyTree]
    b: np.ndarray                    # per-replica batch size (may be fractional)
    lr: np.ndarray                   # per-replica learning rate
    megabatch_idx: int = 0


@dataclass
class _PlanView:
    """The slice of ElasticState the planning hook reads (``algo.plan``
    implementations consume only b / lr / the index) — lets the overlap
    pipeline plan mega-batch N+1 from ``adapt``'s outputs before N's merged
    state object exists."""

    b: np.ndarray
    lr: np.ndarray
    megabatch_idx: int


@dataclass
class _StagedMegaBatch:
    """A prefetched mega-batch: plan + device-resident arrays + the cursor
    snapshot that makes it revocable (DESIGN.md §8).

    ``snapshot`` holds the provider stream state, virtual-clock vector, and
    (simulated) speed-model state captured *before* the staging plan ran:
    ``invalidate_prefetch`` rolls the trainer back to it so a resize / fleet
    event replans from unconsumed cursors, and ``checkpoint_payload``
    substitutes it so a checkpoint taken mid-prefetch restores to *replay*
    the staged batch instead of skipping it.
    """

    plan: Any                 # MegaBatchPlan
    batches: dict             # device arrays, leaves (n_rounds, R, ...)
    mask: Any                 # device (n_rounds, R) float32 update mask
    lr_dev: Any               # device (R,) float32 learning rates
    b: np.ndarray             # host copies the plan was made for (validation)
    lr: np.ndarray
    megabatch_idx: int
    n_replicas: int
    slot_id: Optional[int]    # StagingBuffers slot, None = unbuffered
    snapshot: dict            # pre-staging cursor state (see above)


@dataclass
class ElasticTrainer:
    model: TrainableModel | dict
    provider: Any
    cfg: ElasticConfig
    sgd: SGDConfig = field(default_factory=SGDConfig)
    base_lr: float = 0.05
    speed: Optional[SpeedModel] = None
    merge_cost: float = 5e-3         # virtual seconds per merge (all-reduce)
    keep_global_copies: bool = True  # False = paper §4 memory-lean merging
    engine: str = "scan"             # 'scan' | 'legacy_loop' (see module doc)
    round_bucket: bool = True        # pad n_rounds to pow2: bounds recompiles
    sparse_grads: bool = True        # use the model's row-sparse grad path if
                                     # it provides one; False = dense autodiff
                                     # (the differential oracle, DESIGN.md §3)
    guard_nonfinite: bool = True     # quarantine NaN/Inf replicas before the
                                     # merge (DESIGN.md §7); numerically inert
                                     # while every replica stays finite
    overlap: bool = True             # overlapped mega-batch pipeline
                                     # (DESIGN.md §8): stage N+1 + dispatch
                                     # eval while N executes. scan engine
                                     # only; False = the sequential oracle
    mesh: Optional[Mesh] = None      # replica mesh for cfg.placement='sharded'
                                     # (None = build one over the local devices)
    multihost: Optional[Any] = None  # launch.multihost.MultihostContext: span
                                     # this trainer across processes
                                     # (DESIGN.md §10). None = single process.
    seed: int = 0

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {self.engine!r}")
        if self.cfg.placement not in PLACEMENTS:
            raise ValueError(
                f"cfg.placement must be one of {PLACEMENTS}, got {self.cfg.placement!r}"
            )
        self.model = as_trainable_model(self.model)
        self.algo = algorithms.get(self.cfg.algorithm)
        # process spanning (DESIGN.md §10). Host span: every process runs
        # the identical deterministic host loop at the *global* R but holds
        # only its contiguous block of replica slots on a process-local
        # mesh; cross-process reductions go through the context's file
        # exchange. Device span: the mesh just covers the global device
        # list — the SPMD executors are unchanged.
        self._span = None
        self._global_put = False
        if self.multihost is not None:
            if self.multihost.spanning == "host":
                self._setup_host_span()
            else:
                self._global_put = True
                if self.cfg.placement != "sharded":
                    raise ValueError(
                        "device-span multihost needs cfg.placement='sharded'"
                    )
        self._mesh_pool = None
        self._exec_cache = {}            # shard count -> sharded executors
        self._span_exec_cache = {}       # shard count -> span partial-merge
        if self.cfg.placement == "sharded":
            if self.mesh is None:
                devices = (
                    self.multihost.global_devices()
                    if self._global_put else None
                )
                self._mesh_pool = ReplicaMeshPool(devices)
                self.mesh = self._mesh_pool.mesh_for(self._mesh_width())
            else:
                if REPLICA_AXIS not in self.mesh.shape:
                    raise ValueError(
                        f"sharded placement needs a {REPLICA_AXIS!r} mesh axis, "
                        f"got {tuple(self.mesh.axis_names)}"
                    )
                if self.cfg.n_replicas % self.mesh.shape[REPLICA_AXIS] != 0:
                    raise ValueError(
                        f"n_replicas={self.cfg.n_replicas} not divisible by the "
                        f"replica mesh ({self.mesh.shape[REPLICA_AXIS]} devices)"
                    )
                # a resize may need meshes of other shard counts; they are
                # drawn from the same devices the caller chose
                self._mesh_pool = ReplicaMeshPool(list(self.mesh.devices.flat))
                self._mesh_pool.adopt(self.mesh)
        if self.speed is None:
            self.speed = SpeedModel(self.cfg.n_replicas, seed=self.seed)
        self.cost = CostModel(self.speed)
        self.scheduler = DynamicScheduler(self.cfg, self.cost)
        self._eval_batches = None        # pre-staged device test batches
        self._eval_batches_src = None    # pins the staged list + its batches
        self._eval_batches_key = None    # content fingerprint of that list
        self._staged = None              # prefetched _StagedMegaBatch
        self._staging = StagingBuffers() # double-buffered host staging slots
        # per-shard measured timing (DESIGN.md §8): only the sharded
        # executors carry the debug-callback markers, and only a measured
        # speed model consumes the windows. Built before the executors,
        # which close over it.
        self._shard_timer = (
            ShardWindowTimer()
            if self.cfg.placement == "sharded"
            and isinstance(self.speed, MeasuredSpeedModel)
            else None
        )
        self._build_jits()

    # ------------------------------------------------------------------
    # process spanning (DESIGN.md §10)
    # ------------------------------------------------------------------
    def _setup_host_span(self) -> None:
        """Validate + adopt a host-span multihost context: this process
        will run the global deterministic loop but execute only its own
        contiguous replica block. The constraints are structural, not
        incidental: vmap/legacy have no per-shard executors to localize;
        a measured speed model would feed each process different observed
        factors and fork the deterministic plan; algorithms whose round
        transforms reduce *across* replicas every round would need a
        cross-process collective inside the jitted scan, which the host
        exchange cannot provide."""
        ctx = self.multihost
        if self.cfg.placement != "sharded":
            raise ValueError("host-span multihost needs cfg.placement='sharded'")
        if self.engine != "scan":
            raise ValueError("host-span multihost needs engine='scan'")
        if self.mesh is not None:
            raise ValueError(
                "host-span multihost builds its own process-local mesh; "
                "do not pass one"
            )
        if isinstance(self.speed, MeasuredSpeedModel):
            raise ValueError(
                "host-span multihost needs the simulated SpeedModel: every "
                "process must plan from identical speed factors"
            )
        if getattr(self.algo, "round_collectives", False):
            raise ValueError(
                f"algorithm {self.cfg.algorithm!r} reduces across replicas "
                "inside every round (round_collectives=True); its collectives "
                "cannot span processes on the host-exchange path"
            )
        ctx.assign_slots(self.cfg.n_replicas)
        self._span = ctx

    def _mesh_width(self) -> int:
        """Replica count the local mesh must cover: the process-local
        block under host span, the global R otherwise."""
        return (
            self._span.local_count() if self._span is not None
            else self.cfg.n_replicas
        )

    def _span_slice(self) -> slice:
        """This process's rows of any global (R, ...) array."""
        if self._span is None:
            return slice(None)
        lo, hi = self._span.local_bounds()
        return slice(lo, hi)

    def process_slots(self, pid: int) -> Optional[list[int]]:
        """Global replica slots owned by fleet process ``pid`` (None when
        not spanning or unknown) — the FleetController's resolution hook
        for process-grain fault events."""
        if self._span is None:
            return None
        return self._span.slots_of(pid)

    # ------------------------------------------------------------------
    # jitted device functions
    # ------------------------------------------------------------------
    def _build_jits(self):
        loss_fn = self.model.loss_fn
        # Sparse-gradient path (DESIGN.md §3): the model may expose
        # ((loss, aux), grads) directly, with embedding-style grads as
        # RowSparseGrad leaves — same calling convention as value_and_grad.
        sparse_fn = self.model.sparse_grad_fn if self.sparse_grads else None
        grad_fn = sparse_fn or jax.value_and_grad(loss_fn, has_aux=True)

        # Built once per trainer: RoundTransforms is a static jit argument
        # (hashed by callable identity), so a stable object keeps the jit
        # cache stable across mega-batches.
        self._transforms = self.algo.round_transforms(self.cfg)

        # Collective axis of the sharded placement: inside shard_map the
        # leading R dim of every leaf covers only this shard's replicas, so
        # cross-replica reductions (metrics, live-gating, merges) must fold
        # the other shards in over this axis. None under vmap — every
        # reduction below then lowers exactly as the single-program
        # original. Same helper the algorithm hooks use, so engine and
        # strategies can never disagree on the axis.
        axis = algorithms.replica_axis_name(self.cfg)

        def round_body(replicas, momentum, batch, lr_vec, update_mask, transforms):
            """One lockstep round; shared by both engines (traced inside the
            scan for the device-resident engine, jitted alone for legacy)
            and by both placements (vectorized whole under 'vmap', mapped
            over the replica mesh under 'sharded'). The algorithm's
            RoundTransforms trace here, so strategy behavior is
            engine-independent by construction."""
            (loss, aux), grads = jax.vmap(grad_fn)(replicas, batch)
            if transforms.grad_transform is not None:
                grads = transforms.grad_transform(grads, update_mask)
            new_replicas, new_momentum = sgd_update(
                replicas,
                grads,
                lr_vec,
                self.sgd,
                momentum_state=momentum,
                update_mask=update_mask,
                replica_dim=True,
            )
            if transforms.post_round is not None:
                adjusted = transforms.post_round(new_replicas)
                # fully-masked (bucket-padding) rounds must be exact no-ops;
                # liveness spans the whole mesh — a shard whose local
                # replicas are all masked must still apply the correction
                # when a replica elsewhere is live (its collectives traced
                # unconditionally above, so every shard participates)
                live_local = update_mask.max()
                live = (
                    jax.lax.pmax(live_local, axis) if axis else live_local
                ) > 0
                new_replicas = tu.tree_map(
                    lambda a, r: jnp.where(live, a, r), adjusted, new_replicas
                )
            metrics = {
                "loss": loss,
                "accuracy": aux["accuracy"],
                "n_valid": aux["n_valid"],
            }
            return new_replicas, new_momentum, metrics

        def make_megabatch_fn(raw_stats):
            """Scan-fused mega-batch: all rounds in one device program.

            ``batches`` leaves and ``update_mask`` carry a leading
            (n_rounds,) scan dim. Per-round metrics reduce on device into
            4 scalars — the only values the host ever pulls. Under the
            sharded placement the raw per-round sums are psum-ed over the
            replica axis first, so every shard (and the host) sees
            whole-population metrics.

            ``raw_stats`` (host span, DESIGN.md §10): the psum above only
            covers the *local* mesh, so normalizing in-program would bake
            in per-process denominators. The variant returns the per-round
            raw sums instead — ``{"round_sums": (n_rounds, 4)}`` — and the
            host completes the reduction across processes with the exact
            same arithmetic (``_finish_metrics``). The default variant is
            byte-identical to the pre-span engine.
            """

            def megabatch_fn(replicas, momentum, batches, lr_vec,
                             update_mask, transforms):
                def body(carry, xs):
                    reps, mom = carry
                    batch, mask = xs
                    new_reps, new_mom, m = round_body(
                        reps, mom, batch, lr_vec, mask, transforms
                    )
                    sums = jnp.stack(
                        [
                            jnp.sum(m["loss"] * mask),
                            jnp.sum(m["accuracy"] * mask),
                            jnp.sum(m["n_valid"] * mask),
                            jnp.sum(mask),
                        ]
                    )
                    if axis:
                        sums = jax.lax.psum(sums, axis)
                    if raw_stats:
                        return (new_reps, new_mom), sums
                    denom = jnp.maximum(sums[3], 1.0)
                    stats = jnp.stack(
                        [
                            sums[0] / denom,
                            sums[1] / denom,
                            sums[2],
                            (sums[3] > 0).astype(jnp.float32),
                        ]
                    )
                    return (new_reps, new_mom), stats

                (replicas, momentum), stats = jax.lax.scan(
                    body, (replicas, momentum), (batches, update_mask)
                )
                if raw_stats:
                    return replicas, momentum, {"round_sums": stats}
                live = stats[:, 3]
                n_live = jnp.maximum(jnp.sum(live), 1.0)
                metrics = {
                    "loss": jnp.sum(stats[:, 0]) / n_live,
                    "accuracy": jnp.sum(stats[:, 1]) / n_live,
                    "n_valid": jnp.sum(stats[:, 2]),
                    "rounds_live": jnp.sum(live),
                }
                return replicas, momentum, metrics

            return megabatch_fn

        megabatch_fn = make_megabatch_fn(self._span is not None)

        # Donate the replica/momentum buffers: the engine updates them in
        # place on device (no copy per mega-batch). CPU XLA cannot donate —
        # skip there to avoid a warning per compile.
        donate = (0, 1) if jax.default_backend() in ("tpu", "gpu") else ()

        def merge_fn(replicas, alphas, global_model, prev_global, gamma):
            # under shard_map ``replicas``/``alphas`` are this shard's
            # slices; normalized_merge completes the weighted sum with a
            # psum over the replica axis and broadcasts locally
            new_global = asgd.normalized_merge(
                replicas, alphas, global_model, prev_global, gamma,
                axis_name=axis,
            )
            R_local = jax.tree_util.tree_leaves(replicas)[0].shape[0]
            new_replicas = tu.tree_broadcast_replicas(new_global, R_local)
            return new_global, new_replicas

        if axis is None:
            # Built once per trainer and NEVER rebuilt on resize: R enters
            # these programs only through leaf shapes, so jax.jit's own
            # cache keys them per population size — a resize back to a
            # previously-seen R recompiles nothing (DESIGN.md §6).
            self._round = jax.jit(round_body, static_argnames=("transforms",))
            self._megabatch = jax.jit(
                megabatch_fn,
                static_argnames=("transforms",),
                donate_argnums=donate,
            )
            self._merge = jax.jit(merge_fn, static_argnames=("gamma",))
            self._norms = jax.jit(lambda r: tu.tree_l2_norm_per_replica(r))
        else:
            # the traced bodies are mesh-independent; shard_map binds them
            # to self.mesh per shard count, cached across resizes
            self._bodies = (round_body, megabatch_fn, merge_fn, donate)
            self._install_sharded_executors()
        self._eval = jax.jit(loss_fn)

        def finite_rows(tree):
            """(R,) bool: replica i's leaves are all finite. Read-only — the
            non-finite guard's detection pass never perturbs the numerics of
            a healthy mega-batch (golden bit-identity)."""
            parts = [
                jnp.all(
                    jnp.isfinite(l.astype(jnp.float32)),
                    axis=tuple(range(1, l.ndim)),
                )
                for l in jax.tree_util.tree_leaves(tree)
            ]
            return jnp.all(jnp.stack(parts, 0), axis=0)

        self._finite_rows = jax.jit(finite_rows)

        if self._span is not None:
            # host-span momentum term: the exact f32 arithmetic of
            # normalized_merge's global-momentum step, applied to the
            # exchange-summed merged tree (every process computes it
            # identically from replicated inputs)
            def span_momentum(merged, g, gp, gamma):
                f32 = jnp.float32
                return tu.tree_map(
                    lambda m, a, b: (
                        m.astype(f32) + gamma * (a.astype(f32) - b.astype(f32))
                    ).astype(m.dtype),
                    merged, g, gp,
                )

            self._span_momentum = jax.jit(
                span_momentum, static_argnames=("gamma",)
            )

    def _install_sharded_executors(self):
        """Bind (or re-bind, after a resize) the engine entry points to the
        current ``self.mesh``, reusing previously built executors for a
        shard count seen before — their jit caches then key the new R only
        by leaf shapes, so revisiting a population shape recompiles
        nothing (DESIGN.md §6)."""
        key = int(self.mesh.shape[REPLICA_AXIS])
        execs = self._exec_cache.get(key)
        if execs is None:
            execs = self._build_sharded_executors(*self._bodies)
            self._exec_cache[key] = execs
        self._round, self._megabatch, self._merge, self._norms = execs
        if self._span is not None:
            partial = self._span_exec_cache.get(key)
            if partial is None:
                mesh, s0 = self.mesh, replica_spec(0)
                # local share of the Alg.-2 weighted sum: psum over the
                # *local* mesh only; the exchange completes it (host span)
                partial = jax.jit(
                    shard_map(
                        lambda r, a: asgd.normalized_merge(
                            r, a, None, None, 0.0, axis_name=REPLICA_AXIS
                        ),
                        mesh=mesh,
                        in_specs=(s0, s0),
                        out_specs=P(),
                        check_rep=False,
                    )
                )
                self._span_exec_cache[key] = partial
            self._span_partial = partial

    def _build_sharded_executors(self, round_body, megabatch_fn, merge_fn,
                                 donate):
        """shard_map the engine entry points over the 1-D replica mesh.

        The traced bodies are the *same* functions the vmap placement jits —
        only the leading R dim they see shrinks to this shard's replica
        slice, and the reductions gated on the axis name become real
        collectives. RoundTransforms cannot ride through shard_map as a jit
        static argument, so the stable per-trainer object is closed over
        instead (same jit-cache behavior; the wrappers assert call sites
        keep passing the identical object). Returns the executor tuple
        ``(round, megabatch, merge, norms)``; the wrappers carry their
        underlying jitted callable as ``_jit`` for cache introspection.
        """
        transforms = self._transforms
        mesh = self.mesh
        s0, s1 = replica_spec(0), replica_spec(1)
        timer = self._shard_timer

        jit_round = jax.jit(
            shard_map(
                lambda r, m, b, lr, mask: round_body(
                    r, m, b, lr, mask, transforms
                ),
                mesh=mesh,
                # state/batch leaves are (R, ...): the replica dim leads
                in_specs=(s0, s0, s0, s0, s0),
                # per-replica metric vectors gather back to (R,)
                out_specs=(s0, s0, s0),
                check_rep=False,
            )
        )

        def timed_megabatch(r, m, b, lr, mask):
            """Per-shard window markers (DESIGN.md §8): the start callback
            depends only on an input leaf so it schedules at program entry;
            the end callback depends on the reduced metrics so it fires
            after the scan. Numerically inert — traced in only when a
            measured speed model will consume the windows."""
            if timer is not None:
                idx = jax.lax.axis_index(REPLICA_AXIS)
                jax.debug.callback(  # jaxlint: disable=JL006 — ShardTimer window-open marker, the measured-speed observation path (DESIGN.md §8)
                    lambda s, _dep: timer.mark_start(s), idx, mask[0, 0]
                )
            out_r, out_m, metrics = megabatch_fn(r, m, b, lr, mask, transforms)
            if timer is not None:
                jax.debug.callback(  # jaxlint: disable=JL006 — ShardTimer window-close marker, paired with mark_start above
                    lambda s, _dep: timer.mark_end(s), idx, metrics["loss"]
                )
            return out_r, out_m, metrics

        jit_megabatch = jax.jit(
            shard_map(
                timed_megabatch,
                mesh=mesh,
                # stacked batches/mask are (n_rounds, R, ...): dim 1 shards
                in_specs=(s0, s0, s1, s0, s1),
                # the psum-ed scalar metrics are replicated on every shard
                out_specs=(s0, s0, P()),
                check_rep=False,
            ),
            donate_argnums=donate,
        )

        def _round(replicas, momentum, batch, lr_vec, update_mask, transforms):
            assert transforms is self._transforms
            return jit_round(replicas, momentum, batch, lr_vec, update_mask)

        def _megabatch(replicas, momentum, batches, lr_vec, update_mask,
                       transforms):
            assert transforms is self._transforms
            return jit_megabatch(
                replicas, momentum, batches, lr_vec, update_mask
            )

        _round._jit = jit_round
        _megabatch._jit = jit_megabatch

        @functools.partial(jax.jit, static_argnames=("gamma",))
        def merge_sharded(replicas, alphas, global_model, prev_global, gamma):
            # per-shard weighted partials -> psum inside normalized_merge;
            # every shard holds the replicated new global (out_spec P()) and
            # its (R_local, ...) broadcast, reassembled to the full replica
            # tree. globals/prev ride in replicated; None pytrees are empty
            # and match the P() prefix spec trivially.
            return shard_map(
                functools.partial(merge_fn, gamma=gamma),
                mesh=mesh,
                in_specs=(s0, s0, P(), P()),
                out_specs=(P(), s0),
                check_rep=False,
            )(replicas, alphas, global_model, prev_global)

        norms = jax.jit(
            shard_map(
                tu.tree_l2_norm_per_replica,
                mesh=mesh,
                in_specs=(s0,),
                out_specs=s0,
                check_rep=False,
            )
        )
        return _round, _megabatch, merge_sharded, norms

    def compile_cache_size(self) -> int:
        """Total compiled-variant count across every engine executor built
        so far (all placements, all cached shard counts). The DESIGN.md §6
        zero-recompile contract is testable through this number: a resize
        back to a previously-seen population shape, followed by a
        mega-batch whose round count lands in a previously-seen pow2
        bucket, must leave it unchanged."""

        def size(fn):
            inner = getattr(fn, "_jit", fn)
            cache_size = getattr(inner, "_cache_size", None)
            return int(cache_size()) if cache_size is not None else 0

        fns = [self._eval]
        if self._exec_cache:
            for execs in self._exec_cache.values():
                fns.extend(execs)
        else:
            fns.extend([self._round, self._megabatch, self._merge,
                        self._norms])
        return sum(size(f) for f in fns)

    # ------------------------------------------------------------------
    # jitted tensor math exposed to Algorithm.merge implementations
    # ------------------------------------------------------------------
    def merge_models(self, replicas, alphas, global_model, prev_global, gamma):
        """Normalized merge (Alg. 2 tensor math, jitted): returns
        (new_global, replicas reset to it). gamma=0 / None globals skip the
        global-momentum term — a plain weighted average.

        Host span: ``alphas`` is the *global* (R,) weight vector while
        ``replicas`` holds only the local rows; the weighted sum completes
        across processes through the exchange (``_merge_spanning``)."""
        if self._span is not None:
            return self._merge_spanning(
                replicas, alphas, global_model, prev_global, gamma
            )
        return self._merge(
            replicas, jnp.asarray(alphas, jnp.float32),
            global_model, prev_global, gamma,
        )

    def _merge_spanning(self, replicas, alphas, global_model, prev_global,
                        gamma):
        """Algorithm 2's merge across processes (DESIGN.md §10).

        Each process computes its local share of the weighted sum on
        device (same f32 arithmetic as the in-mesh psum path — the only
        cross-process difference is float reassociation), then the file
        exchange sums the partials. The contributed alpha mass rides along:
        when a peer died mid-mega-batch its partial is simply absent, and
        scaling the sum by ``expected/contributed`` mass is exactly the
        crash semantics of ``remove_replicas`` — the dead replicas' merge
        weight redistributes proportionally over the survivors.
        """
        span = self._span
        lo, hi = span.local_bounds()
        a = np.asarray(alphas, np.float64)
        a_local = jnp.asarray(a[lo:hi], jnp.float32)
        part = self._span_partial(replicas, a_local)
        payload = {
            "partial": tu.tree_map(np.asarray, part),
            "mass": np.float64(a[lo:hi].sum()),
        }
        total, contributors = span.allreduce_sum("merge", payload)
        merged_np = total["partial"]
        if len(contributors) < len(span.active_processes()):
            expected = float(a.sum())
            contributed = float(total["mass"])
            if contributed <= 0.0:
                raise FloatingPointError(
                    "every process holding nonzero merge weight died "
                    "mid-mega-batch; nothing to merge"
                )
            scale = np.float32(expected / contributed)
            merged_np = tu.tree_map(
                lambda l: (l * scale).astype(l.dtype), merged_np
            )
        merged = tu.tree_map(jnp.asarray, merged_np)
        if (
            global_model is not None and prev_global is not None
            and gamma != 0.0
        ):
            merged = self._span_momentum(
                merged, global_model, prev_global, gamma=float(gamma)
            )
        new_replicas = tu.tree_broadcast_replicas(merged, hi - lo)
        new_replicas, _, merged, _ = self._place_state(
            new_replicas, None, merged, None
        )
        return merged, new_replicas

    def replica_norms(self, replicas):
        """Per-replica L2 norms (feeds Alg. 2's perturbation condition).
        Host span: local norms are bit-exact per replica (no cross-replica
        reduction), so an allgather reassembles the global (R,) vector; a
        dead peer's rows read 0 — its merge weight is redistributed at the
        merge anyway."""
        if self._span is None:
            return self._norms(replicas)
        span = self._span
        local = np.asarray(self._norms(replicas), np.float64)
        gathered = span.allgather("norms", local)
        out = np.zeros(self.cfg.n_replicas, np.float64)
        for pid, arr in gathered.items():
            plo, phi = span.bounds_of(pid)
            out[plo:phi] = np.asarray(arr, np.float64)
        return out

    # ------------------------------------------------------------------
    # state init
    # ------------------------------------------------------------------
    def init_state(self) -> ElasticState:
        R = self.cfg.n_replicas
        rng = jax.random.PRNGKey(self.seed)
        params = self.model.init(rng)
        # host span: device trees hold only this process's replica block;
        # the host-side vectors (b, lr) always stay global
        replicas = tu.tree_broadcast_replicas(params, self._mesh_width())
        momentum = init_momentum(replicas, self.sgd)
        extras = self.algo.init_state_extras(
            self.cfg, params, self.keep_global_copies
        )
        b = np.asarray(extras.b, np.float64)
        lr = self.base_lr * b / self.cfg.b_max  # linear-scaling rule
        return ElasticState(
            replicas=replicas,
            global_model=extras.global_model,
            prev_global=extras.prev_global,
            momentum=momentum,
            b=b,
            lr=lr,
        )

    # ------------------------------------------------------------------
    # elastic membership: resize R between mega-batches (DESIGN.md §6)
    # ------------------------------------------------------------------
    def resize(self, state: ElasticState, new_R: int) -> ElasticState:
        """Change the replica count between mega-batches.

        The elasticity the paper's title promises beyond adaptive batch
        sizes: workers joining or leaving mid-run. Resizing is a
        re-plan / re-shard / carry-state barrier:

        * **merge first** — every *current* replica (including the ones
          about to leave) contributes a final normalized merge (weights
          ``b_i / sum(b)``, Algorithm 2 line 3 — between mega-batches the
          update counts are spent, so batch sizes are the availability
          signal), executed on the *old* executors before any re-shard.
          Leaving replicas' updates are therefore never dropped.
        * **carry state** — under the default ``resize_policy='merge'``
          the new population restarts from the merged global; under
          ``'preserve'`` (CROSSBOW) survivors keep their own diverged
          parameters and only joiners clone the merged global. Survivors
          keep their momentum buffers; joiners start with zero momentum.
          The global-momentum pair restarts (``prev_global := merged``) so
          Algorithm 2's momentum term never mixes pre/post-resize
          populations. Speed EMAs / simulated factors carry for survivors;
          joiners start at the homogeneous prior. Batch sizes and lrs
          resize through ``algo.resize_b`` (Algorithm 1 then resumes from
          them at the new R on the next ``adapt``).
        * **re-plan** — the scheduler adopts the new config; survivor
          virtual clocks carry, joiners enter at the barrier time.
        * **re-shard** — under ``placement='sharded'`` the replica mesh is
          re-drawn from the trainer's device pool and the state trees are
          device_put onto it. Executors (and their jit caches) are reused
          per shard count, and the vmap jits are never rebuilt at all, so
          a resize back to a previously-seen population shape recompiles
          nothing (``compile_cache_size``).

        Resolves through ``algo.resolve_n_replicas`` first (``single``
        turns any schedule into a no-op); ``resize_policy='fixed'`` raises.
        Returns the state to continue from — like ``run_megabatch``, treat
        the input state as consumed.
        """
        new_R = int(self.algo.resolve_n_replicas(int(new_R)))
        R = self.cfg.n_replicas
        if new_R == R:
            return state
        if self._span is not None:
            raise ValueError(
                "a host-span trainer changes membership at process grain "
                "(heartbeat-driven fleet events); generic resize() is "
                "unsupported (DESIGN.md §10)"
            )
        if new_R < 1:
            raise ValueError(f"cannot resize to {new_R} replicas")
        policy = getattr(self.algo, "resize_policy", "merge")
        if policy == "fixed":
            raise ValueError(
                f"algorithm {self.algo.name!r} pins its replica membership "
                f"(resize_policy='fixed'); cannot resize {R} -> {new_R}"
            )
        # a prefetched plan was made for the old R: revoke it and roll the
        # cursors back *before* any membership mutation (DESIGN.md §8). The
        # new_R == R early return above deliberately keeps the prefetch —
        # a constant schedule stays bit-identical to the unscheduled run.
        self.invalidate_prefetch()

        # ---- final normalized merge over the outgoing population ----
        alphas = np.asarray(state.b, np.float64)
        alphas = alphas / alphas.sum()
        merged, _ = self.merge_models(
            state.replicas, alphas, None, None, 0.0
        )

        # ---- carry parameters / momentum to the new population ----
        keep = min(R, new_R)

        def grown(l, g, fill):
            """(R, ...) leaf -> (new_R, ...): survivors' rows + fill rows."""
            parts = [l[:keep]]
            if new_R > keep:
                extra = (
                    jnp.broadcast_to(g[None], (new_R - keep,) + g.shape)
                    if fill == "global"
                    else jnp.zeros((new_R - keep,) + l.shape[1:], l.dtype)
                )
                parts.append(extra)
            return jnp.concatenate(parts, 0) if len(parts) > 1 else parts[0]

        if policy == "preserve":
            new_replicas = tu.tree_map(
                lambda l, g: grown(l, g, "global"), state.replicas, merged
            )
        else:  # 'merge': everyone restarts from the merged global
            new_replicas = tu.tree_broadcast_replicas(merged, new_R)
        new_momentum = None
        if state.momentum is not None:
            new_momentum = tu.tree_map(
                lambda l: grown(l, None, "zeros"), state.momentum
            )
        new_global = merged if state.global_model is not None else None
        new_prev = merged if state.prev_global is not None else None

        # ---- re-plan: config, batch plan, speeds, virtual clocks ----
        new_cfg = dataclasses.replace(self.cfg, n_replicas=new_R)
        new_b, new_lr = self.algo.resize_b(
            new_cfg, state.b, state.lr, self.base_lr
        )
        self._adopt_width(new_R)

        # ---- re-shard: new replica mesh + cached executors ----
        new_replicas, new_momentum, new_global, new_prev = self._place_state(
            new_replicas, new_momentum, new_global, new_prev
        )

        return ElasticState(
            replicas=new_replicas,
            global_model=new_global,
            prev_global=new_prev,
            momentum=new_momentum,
            b=np.asarray(new_b, np.float64),
            lr=np.asarray(new_lr, np.float64),
            megabatch_idx=state.megabatch_idx,
        )

    def _adopt_width(self, new_R: int) -> None:
        """Adopt a new replica count: config, speed model, scheduler, and —
        under the sharded placement — the replica mesh + cached executors.
        The population-agnostic half of ``resize``, reused by
        ``restore_checkpoint`` when the checkpointed width differs from the
        trainer's construction width."""
        self.cfg = dataclasses.replace(self.cfg, n_replicas=new_R)
        self.speed.resize(new_R)
        self.scheduler.resize(self.cfg)
        if self.cfg.placement == "sharded":
            # host span: the local mesh covers this process's block, whose
            # width survives process-grain eviction — same mesh, same
            # executor caches, zero recompiles
            self.mesh = self._mesh_pool.mesh_for(self._mesh_width())
            self._install_sharded_executors()

    def _place_state(self, replicas, momentum, global_model, prev_global):
        """device_put the state trees onto the current replica mesh
        (identity under the vmap placement)."""
        if self.cfg.placement != "sharded":
            return replicas, momentum, global_model, prev_global
        shard0 = NamedSharding(self.mesh, replica_spec(0))
        repl = NamedSharding(self.mesh, P())
        put0 = lambda l: self._put_leaf(l, shard0)  # noqa: E731
        putr = lambda l: self._put_leaf(l, repl)  # noqa: E731
        replicas = tu.tree_map(put0, replicas)
        if momentum is not None:
            momentum = tu.tree_map(put0, momentum)
        if global_model is not None:
            global_model = tu.tree_map(putr, global_model)
        if prev_global is not None:
            prev_global = tu.tree_map(putr, prev_global)
        return replicas, momentum, global_model, prev_global

    def _put_leaf(self, l, sharding):
        """Upload one leaf. Device span: the target sharding covers
        non-addressable devices, which plain ``device_put`` rejects —
        ``make_array_from_callback`` assembles the global array from the
        (identical, host-replicated) value every process holds."""
        if self._global_put:
            arr = np.asarray(l)
            return jax.make_array_from_callback(
                arr.shape, sharding, lambda idx: arr[idx]
            )
        return jax.device_put(l, sharding)

    def remove_replicas(
        self, state: ElasticState, indices, merge_leavers: bool = True
    ) -> ElasticState:
        """Evict specific replica slots between mega-batches (DESIGN.md §7).

        ``resize`` only drops *tail* rows, so targeted eviction first
        permutes survivors to the front (every per-replica array — state
        rows, b/lr, speed factors/EMAs, virtual clocks — moves with its
        replica), then shrinks.

        ``merge_leavers`` encodes the fault semantics: a *preempted*
        replica got notice, so its updates fold into the final normalized
        merge like any graceful leaver (True); a *crashed or poisoned*
        replica must be excluded — its rows are zeroed and its merge weight
        set to 0, so Algorithm 2's normalization redistributes b_i over the
        survivors and a NaN payload can never reach the weighted sum
        (0 * NaN is NaN, hence the explicit zeroing).
        """
        R = self.cfg.n_replicas
        drop = sorted({int(i) for i in indices})
        if not drop:
            return state
        bad = [i for i in drop if i < 0 or i >= R]
        if bad:
            raise ValueError(f"replica indices {bad} out of range for R={R}")
        if len(drop) >= R:
            raise ValueError(
                f"cannot remove all {R} replicas (removal of {drop})"
            )
        if self._span is not None:
            return self._remove_replicas_spanning(state, drop, merge_leavers)
        # the permutation below moves speed factors / clocks with their
        # replica — a prefetched plan consumed them in the old order
        self.invalidate_prefetch()
        survivors = [i for i in range(R) if i not in set(drop)]
        perm = survivors + drop

        if perm != list(range(R)):
            p = jnp.asarray(perm)
            take = lambda l: jnp.take(l, p, axis=0)  # noqa: E731
            state = ElasticState(
                replicas=tu.tree_map(take, state.replicas),
                global_model=state.global_model,
                prev_global=state.prev_global,
                momentum=(
                    tu.tree_map(take, state.momentum)
                    if state.momentum is not None else None
                ),
                b=np.asarray(state.b, np.float64)[perm],
                lr=np.asarray(state.lr, np.float64)[perm],
                megabatch_idx=state.megabatch_idx,
            )
            self.speed.permute(perm)
            self.scheduler.clock.permute(perm)

        if not merge_leavers:
            keep = R - len(drop)
            mask = jnp.arange(R) < keep
            zero_tail = lambda l: jnp.where(  # noqa: E731
                mask.reshape((-1,) + (1,) * (l.ndim - 1)), l, jnp.zeros_like(l)
            )
            b = np.asarray(state.b, np.float64).copy()
            b[keep:] = 0.0
            state = dataclasses.replace(
                state, replicas=tu.tree_map(zero_tail, state.replicas), b=b
            )

        return self.resize(state, R - len(drop))

    def _remove_replicas_spanning(self, state, drop, merge_leavers):
        """Evict whole peer processes from a host-span fleet (DESIGN.md §10).

        The drop set must cover exact process blocks (the monitor emits
        process-grain events, so it always does); the local replica count
        is untouched — same mesh, same executor jit caches, zero
        recompiles. Every surviving process runs this identically:

        * final merge over survivors: the dead process can't contribute a
          partial, so the exchange's mass renormalization reproduces
          ``merge_leavers=False`` crash semantics exactly (with graceful
          leavers the peer is still exchanging and its updates fold in);
        * survivors-first renumbering is order-preserving, so each
          process's slot block stays contiguous; host-global vectors
          (b, lr, speed factors, virtual clocks) permute and shrink the
          same way the single-process path does.
        """
        span = self._span
        R = self.cfg.n_replicas
        victims = span.processes_for_slots(drop)
        self.invalidate_prefetch()

        alphas = np.asarray(state.b, np.float64).copy()
        if not merge_leavers:
            alphas[drop] = 0.0
        if alphas.sum() <= 0:
            alphas = np.ones(R, np.float64)
            if not merge_leavers:
                alphas[drop] = 0.0
        alphas = alphas / alphas.sum()
        merged, merged_replicas = self.merge_models(
            state.replicas, alphas, None, None, 0.0
        )

        dropset = set(drop)
        survivors = [i for i in range(R) if i not in dropset]
        perm = survivors + list(drop)
        if perm != list(range(R)):
            self.speed.permute(perm)
            self.scheduler.clock.permute(perm)
        new_R = R - len(drop)
        b_perm = np.asarray(state.b, np.float64)[perm]
        lr_perm = np.asarray(state.lr, np.float64)[perm]
        for pid in victims:
            span.remove_process(pid)
        self._adopt_width(new_R)
        new_cfg = self.cfg
        new_b, new_lr = self.algo.resize_b(
            new_cfg, b_perm[:new_R], lr_perm[:new_R], self.base_lr
        )

        policy = getattr(self.algo, "resize_policy", "merge")
        if policy == "merge":
            new_replicas = merged_replicas
            new_momentum = state.momentum  # survivors keep their momentum
        else:
            # 'preserve': survivors keep their own rows — which are exactly
            # the local rows this process already holds
            new_replicas = state.replicas
            new_momentum = state.momentum
        new_global = merged if state.global_model is not None else None
        new_prev = merged if state.prev_global is not None else None
        new_replicas, new_momentum, new_global, new_prev = self._place_state(
            new_replicas, new_momentum, new_global, new_prev
        )
        return ElasticState(
            replicas=new_replicas,
            global_model=new_global,
            prev_global=new_prev,
            momentum=new_momentum,
            b=np.asarray(new_b, np.float64),
            lr=np.asarray(new_lr, np.float64),
            megabatch_idx=state.megabatch_idx,
        )

    # ------------------------------------------------------------------
    # round execution engines
    # ------------------------------------------------------------------
    def _run_rounds_scan(self, state, plan, b_slots, transforms):
        """Device-resident engine: pre-stack the plan, scan all rounds.
        Host span: the plan grid is built at the global R (every process
        plans identically), but only this process's replica columns are
        uploaded and executed."""
        R = self.cfg.n_replicas
        min_rounds = _next_pow2(plan.n_rounds) if self.round_bucket else plan.n_rounds
        grid = plan.payload_grid(R, min_rounds=max(min_rounds, 1))
        batches_np, mask = self.provider.stack_plan(grid, b_slots)
        lr = np.asarray(state.lr, np.float32)
        if self._span is not None:
            sl = self._span_slice()
            batches_np = {k: v[:, sl] for k, v in batches_np.items()}
            mask = mask[:, sl]
            lr = lr[sl]
        batches = {k: jnp.asarray(v) for k, v in batches_np.items()}
        replicas, momentum, m = self._megabatch(
            state.replicas,
            state.momentum,
            batches,
            jnp.asarray(lr),
            jnp.asarray(mask),
            transforms=transforms,
        )
        # single host sync per mega-batch
        loss, acc = self._finish_metrics(m)
        return replicas, momentum, loss, acc

    def _run_rounds_legacy(self, state, plan, b_slots, transforms):
        """Original per-round host loop (escape hatch / differential oracle)."""
        R = self.cfg.n_replicas
        grid = plan.payload_grid(R)
        replicas, momentum = state.replicas, state.momentum
        losses, accs = [], []
        for row in grid:
            payloads = [p if p is not None else self.provider.empty(b_slots) for p in row]
            update_mask = jnp.asarray(
                [1.0 if p is not None else 0.0 for p in row], jnp.float32
            )
            batch = {k: jnp.asarray(v) for k, v in self.provider.stack(payloads).items()}
            lr_vec = jnp.asarray(state.lr, jnp.float32)
            replicas, momentum, m = self._round(
                replicas, momentum, batch, lr_vec, update_mask,
                transforms=transforms,
            )
            w = np.asarray(update_mask)
            if w.sum() > 0:
                losses.append(float((np.asarray(m["loss"]) * w).sum() / w.sum()))
                accs.append(float((np.asarray(m["accuracy"]) * w).sum() / w.sum()))
        loss = float(np.mean(losses)) if losses else float("nan")
        acc = float(np.mean(accs)) if accs else float("nan")
        return replicas, momentum, loss, acc

    # ------------------------------------------------------------------
    # one mega-batch
    # ------------------------------------------------------------------
    def run_megabatch(
        self, state: ElasticState, prefetch: Optional[bool] = None
    ) -> tuple[ElasticState, dict]:
        """Plan, execute, and merge one mega-batch; returns (new_state, info).

        Generic engine sequence — every step delegates to the strategy:
        ``algo.plan`` → rounds (with ``algo.round_transforms`` traced in) →
        ``algo.merge`` → ``algo.adapt`` → merge-cost accounting.

        With ``overlap`` on (and the scan engine), the pipelined variant
        runs instead (DESIGN.md §8): the mega-batch is dispatched from a
        pre-staged device-resident plan, and while the device executes, the
        host adapts b/lr and stages mega-batch N+1 (plan → fused pack into a
        double buffer → one batched upload). ``prefetch=False`` suppresses
        staging the *next* mega-batch (used for the final one); the default
        prefetches. Both variants produce bit-identical trajectories under
        the simulated speed model.

        Donation contract: with the scan engine on TPU/GPU, ``state.replicas``
        and ``state.momentum`` are DONATED to the device program — treat
        ``state`` as consumed and continue from the returned state only.
        (On CPU donation is disabled and old states stay readable.)
        """
        if self.overlap and self.engine == "scan":
            # prefetch is opt-in (run() and bench loops pass it): a bare
            # run_megabatch call must leave no dangling staged plan, so the
            # caller's live cursors (provider / clock / speed) stay exactly
            # where a sequential mega-batch would leave them
            return self._run_megabatch_overlap(state, bool(prefetch))
        # a stale prefetch (e.g. the overlap flag was flipped off between
        # calls) must not leak advanced cursors into the sequential path
        if self._staged is not None:
            self.invalidate_prefetch()
        return self._run_megabatch_sync(state)

    def _run_megabatch_sync(self, state: ElasticState) -> tuple[ElasticState, dict]:
        """Sequential mega-batch: plan → execute → merge, one after another.

        The differential oracle for the overlap pipeline (``--overlap off``):
        this path is the pre-pipeline code, byte for byte."""
        cfg = self.cfg
        R = cfg.n_replicas
        mega_samples = cfg.mega_batch * cfg.b_max
        b_slots = cfg.b_max

        def fetch(i, take):
            payload = self.provider.fetch(take, b_slots)
            return payload, self.provider.work_units(payload)

        plan = self.algo.plan(self.scheduler, state, mega_samples, fetch)

        # ---- execute lockstep rounds ----
        run_rounds = (
            self._run_rounds_legacy if self.engine == "legacy_loop"
            else self._run_rounds_scan
        )
        # measured-speed feedback (DESIGN.md §5): time the real execution of
        # the mega-batch and feed it back so the *next* plan's virtual clock
        # runs on observed relative speeds instead of simulated factors. The
        # engines sync metrics to host before returning, so the window
        # brackets actual device work.
        measure = isinstance(self.speed, MeasuredSpeedModel)
        t_start = self.speed.begin() if measure else None
        if measure and self._shard_timer is not None:
            self._shard_timer.reset(int(self.mesh.shape[REPLICA_AXIS]))
        replicas, momentum, train_loss, train_acc = run_rounds(
            state, plan, b_slots, self._transforms
        )
        if measure:
            self._observe_window(plan, R, self.speed.elapsed(t_start))

        # ---- non-finite guard (DESIGN.md §7) ----
        # A replica whose params went NaN/Inf during the rounds is healed
        # *before* the barrier so it can never poison the merged global.
        # Detection is read-only: a healthy mega-batch is bit-identical
        # with the guard on or off.
        guard_repaired: list[int] = []
        if self.guard_nonfinite:
            finite = self._global_finite_rows(replicas)
            if not finite.all():
                replicas, momentum = self._repair_nonfinite(
                    state, replicas, momentum, finite
                )
                guard_repaired = np.flatnonzero(~finite).tolist()

        # ---- merge (the barrier) + between-mega-batch adaptation ----
        outcome = self.algo.merge(self, state, plan, replicas)
        new_b, new_lr = self.algo.adapt(state, plan, cfg)
        alphas = (
            outcome.alphas if outcome.alphas is not None else np.full(R, 1.0 / R)
        )

        # merge happens at the barrier and costs virtual time on every
        # replica; the strategy decides how many merges a mega-batch incurs
        # (per-round for eager synchronous schemes, once for barrier-only).
        n_merges = self.algo.merges_per_megabatch(plan)
        self.scheduler.clock.t[:] += self.merge_cost * n_merges
        virtual_time = float(self.scheduler.clock.t.max())

        new_state = ElasticState(
            replicas=outcome.replicas,
            global_model=outcome.global_model,
            prev_global=outcome.prev_global,
            momentum=momentum,
            b=np.asarray(new_b, np.float64),
            lr=np.asarray(new_lr, np.float64),
            megabatch_idx=state.megabatch_idx + 1,
        )
        info = {
            "n_replicas": R,
            "u": plan.u.tolist(),
            "b": np.round(np.asarray(new_b), 2).tolist(),
            "lr": np.round(np.asarray(new_lr), 6).tolist(),
            "alphas": np.round(np.asarray(alphas, np.float64), 4).tolist(),
            "pert_active": bool(outcome.pert_active),
            "train_loss": train_loss,
            "train_accuracy": train_acc,
            "virtual_time": virtual_time,
            "n_rounds": plan.n_rounds,
        }
        if guard_repaired:
            info["guard_repaired"] = guard_repaired
        return new_state, info

    # ------------------------------------------------------------------
    # overlapped mega-batch pipeline (DESIGN.md §8)
    # ------------------------------------------------------------------
    def _run_megabatch_overlap(
        self, state: ElasticState, prefetch: bool
    ) -> tuple[ElasticState, dict]:
        """Pipelined mega-batch: dispatch N from the pre-staged arrays, then
        do all host work for N+1 (adapt → plan → fused pack → batched
        upload) *before* the single host sync that collects N's metrics —
        on an async backend the device is busy with N throughout.

        Host-stateful operations keep exactly the sequential path's relative
        order (… plan N → merge-cost clock bump N → plan N+1 …), and
        ``merge``/``adapt``/the guard are pure functions of (state, plan,
        device results), so trajectories are bit-identical to
        ``_run_megabatch_sync`` under the simulated speed model. Under a
        measured speed model, plan N+1 is made with factors one window stale
        — the price of the pipeline, documented in DESIGN.md §8.
        """
        cfg = self.cfg
        R = cfg.n_replicas
        staged = self._take_staged(state)
        if staged is None:
            staged = self._stage_megabatch(
                state.b, state.lr, int(state.megabatch_idx)
            )
        plan = staged.plan

        measure = isinstance(self.speed, MeasuredSpeedModel)
        t_start = self.speed.begin() if measure else None
        if measure and self._shard_timer is not None:
            self._shard_timer.reset(int(self.mesh.shape[REPLICA_AXIS]))
        replicas, momentum, m = self._megabatch(
            state.replicas,
            state.momentum,
            staged.batches,
            staged.lr_dev,
            staged.mask,
            transforms=self._transforms,
        )

        # ---- host work overlapped with the in-flight device program ----
        n_merges = self.algo.merges_per_megabatch(plan)
        self.scheduler.clock.t[:] += self.merge_cost * n_merges
        virtual_time = float(self.scheduler.clock.t.max())
        new_b, new_lr = self.algo.adapt(state, plan, cfg)
        if prefetch:
            self._staged = self._stage_megabatch(
                new_b, new_lr, int(state.megabatch_idx) + 1
            )

        # ---- collect: the single host sync of the mega-batch ----
        train_loss, train_acc = self._finish_metrics(m)
        # the staged slot's consumer is done on device -> reusable two
        # stagings from now (the other slot is next in line)
        if staged.slot_id is not None:
            self._staging.release(staged.slot_id)
        if measure:
            self._observe_window(plan, R, self.speed.elapsed(t_start))

        # ---- non-finite guard (DESIGN.md §7) ----
        guard_repaired: list[int] = []
        if self.guard_nonfinite:
            finite = self._global_finite_rows(replicas)
            if not finite.all():
                replicas, momentum = self._repair_nonfinite(
                    state, replicas, momentum, finite
                )
                guard_repaired = np.flatnonzero(~finite).tolist()

        # ---- merge (the barrier) ----
        outcome = self.algo.merge(self, state, plan, replicas)
        alphas = (
            outcome.alphas if outcome.alphas is not None else np.full(R, 1.0 / R)
        )

        new_state = ElasticState(
            replicas=outcome.replicas,
            global_model=outcome.global_model,
            prev_global=outcome.prev_global,
            momentum=momentum,
            b=np.asarray(new_b, np.float64),
            lr=np.asarray(new_lr, np.float64),
            megabatch_idx=state.megabatch_idx + 1,
        )
        info = {
            "n_replicas": R,
            "u": plan.u.tolist(),
            "b": np.round(np.asarray(new_b), 2).tolist(),
            "lr": np.round(np.asarray(new_lr), 6).tolist(),
            "alphas": np.round(np.asarray(alphas, np.float64), 4).tolist(),
            "pert_active": bool(outcome.pert_active),
            "train_loss": train_loss,
            "train_accuracy": train_acc,
            "virtual_time": virtual_time,
            "n_rounds": plan.n_rounds,
        }
        if guard_repaired:
            info["guard_repaired"] = guard_repaired
        return new_state, info

    def _finish_metrics(self, m) -> tuple[float, float]:
        """Collect a mega-batch's (loss, accuracy) from the device metrics.

        Default engines return the fully-reduced scalars. The host-span
        executor returns raw per-round sums over the *local* replicas
        (``round_sums``); the exchange completes the population sum and the
        host mirrors the in-jit normalization arithmetic in float32 — the
        only cross-process difference from the in-mesh psum path is float
        reassociation. A dead peer contributes nothing: that mega-batch's
        metrics cover the survivors."""
        if "round_sums" not in m:
            return float(m["loss"]), float(m["accuracy"])
        sums = np.asarray(m["round_sums"], np.float32)
        if self._span is not None:
            total, _ = self._span.allreduce_sum("metrics", {"sums": sums})
            sums = np.asarray(total["sums"], np.float32)
        denom = np.maximum(sums[:, 3], np.float32(1.0))
        loss_r = sums[:, 0] / denom
        acc_r = sums[:, 1] / denom
        live = (sums[:, 3] > 0).astype(np.float32)
        n_live = np.maximum(live.sum(dtype=np.float32), np.float32(1.0))
        return (
            float(loss_r.sum(dtype=np.float32) / n_live),
            float(acc_r.sum(dtype=np.float32) / n_live),
        )

    def _observe_window(self, plan, R: int, seconds: float) -> None:
        """Feed one mega-batch's measurement window to the speed model:
        per-shard callback windows when the sharded executors produced a
        complete set, else the whole host window (legacy engine, vmap
        placement, or a marker lost in flight)."""
        windows = None
        if self._shard_timer is not None:
            jax.effects_barrier()   # debug callbacks are async; flush them
            windows = self._shard_timer.take()
        if windows is not None:
            self.speed.observe_shards(
                windows, plan.per_replica_work(R), u=plan.u,
                n_rounds=plan.n_rounds,
            )
        else:
            self.speed.observe_plan(
                plan.per_replica_work(R), seconds, u=plan.u,
                n_rounds=plan.n_rounds,
            )

    def _cursor_snapshot(self) -> dict:
        """Deep copies of every host cursor a staging plan advances:
        provider stream (sample RNG + position), virtual clocks, and — for
        the simulated model, whose planning consumes jitter RNG — the speed
        state. The measured model is not snapshotted: planning does not
        mutate it, and rolling it back would clobber window observations
        made after the snapshot."""
        return {
            "provider": (
                copy.deepcopy(self.provider.state_dict())
                if hasattr(self.provider, "state_dict") else None
            ),
            "clock_t": np.asarray(self.scheduler.clock.t, np.float64).copy(),
            "speed": (
                None if isinstance(self.speed, MeasuredSpeedModel)
                else copy.deepcopy(self.speed.state_dict())
            ),
        }

    def _stage_megabatch(
        self, b: np.ndarray, lr: np.ndarray, megabatch_idx: int
    ) -> _StagedMegaBatch:
        """Plan one mega-batch and stage it onto the devices.

        Fetches lazily where the provider supports it (ids + work units
        only), packs the whole plan grid in one fused vectorized gather into
        a double-buffered host slot, and issues a single batched
        ``jax.device_put`` of {batches, mask, lr} — onto the replica mesh
        under the sharded placement, so the executor's in_specs are already
        satisfied. The cursor snapshot is taken first, making the whole
        staging revocable (``invalidate_prefetch``) and checkpoint-safe
        (``checkpoint_payload``).
        """
        cfg = self.cfg
        R = cfg.n_replicas
        b_slots = cfg.b_max
        mega_samples = cfg.mega_batch * cfg.b_max
        b = np.asarray(b, np.float64).copy()
        lr = np.asarray(lr, np.float64).copy()
        snapshot = self._cursor_snapshot()

        provider = self.provider
        if hasattr(provider, "fetch_staged"):
            def fetch(i, take):
                return provider.fetch_staged(take, b_slots)
        else:
            def fetch(i, take):
                payload = provider.fetch(take, b_slots)
                return payload, provider.work_units(payload)

        view = _PlanView(b=b, lr=lr, megabatch_idx=megabatch_idx)
        plan = self.algo.plan(self.scheduler, view, mega_samples, fetch)
        min_rounds = (
            _next_pow2(plan.n_rounds) if self.round_bucket else plan.n_rounds
        )
        grid = plan.payload_grid(R, min_rounds=max(min_rounds, 1))

        slot_id, out = None, None
        if hasattr(provider, "staging_spec"):
            spec = provider.staging_spec(len(grid), R, b_slots)
            slot_id, out = self._staging.acquire(spec)
            batches_np, mask = provider.stack_plan(grid, b_slots, out=out)
        else:
            batches_np, mask = provider.stack_plan(grid, b_slots)

        lr32 = np.asarray(lr, np.float32)
        if self._span is not None:
            # host span: upload only this process's replica columns (the
            # staging slot still packs the full global grid — its shapes
            # key the double buffer; the slices below are views)
            sl = self._span_slice()
            batches_np = {k: v[:, sl] for k, v in batches_np.items()}
            mask = mask[:, sl]
            lr32 = lr32[sl]
        if cfg.placement == "sharded":
            s1 = NamedSharding(self.mesh, replica_spec(1))
            s0 = NamedSharding(self.mesh, replica_spec(0))
            if self._global_put:
                batches = {k: self._put_leaf(v, s1) for k, v in batches_np.items()}
                mask_dev = self._put_leaf(mask, s1)
                lr_dev = self._put_leaf(lr32, s0)
            else:
                batches, mask_dev, lr_dev = jax.device_put(
                    (batches_np, mask, lr32),
                    ({k: s1 for k in batches_np}, s1, s0),
                )
        else:
            batches, mask_dev, lr_dev = jax.device_put((batches_np, mask, lr32))
        return _StagedMegaBatch(
            plan=plan, batches=batches, mask=mask_dev, lr_dev=lr_dev,
            b=b, lr=lr, megabatch_idx=int(megabatch_idx), n_replicas=R,
            slot_id=slot_id, snapshot=snapshot,
        )

    def _take_staged(self, state: ElasticState) -> Optional[_StagedMegaBatch]:
        """Consume the prefetched mega-batch if it matches ``state`` —
        same mega-batch index, population width, and b/lr vectors. Any
        mismatch (an out-of-band mutation that did not go through
        ``invalidate_prefetch``) discards it with a cursor rollback so the
        plan is simply replayed."""
        s = self._staged
        if s is None:
            return None
        self._staged = None
        if (
            s.megabatch_idx == int(state.megabatch_idx)
            and s.n_replicas == self.cfg.n_replicas
            and np.array_equal(s.b, np.asarray(state.b, np.float64))
            and np.array_equal(s.lr, np.asarray(state.lr, np.float64))
        ):
            return s
        self._discard_staged(s)
        return None

    def invalidate_prefetch(self) -> None:
        """Revoke the prefetched mega-batch (if any) and roll every host
        cursor back to the pre-staging snapshot. Called before anything
        that invalidates a staged plan — a resize, targeted eviction, fleet
        speed mutation, or checkpoint restore — so the next mega-batch
        replans from unconsumed cursors (correctness over overlap,
        DESIGN.md §8)."""
        s = self._staged
        if s is None:
            return
        self._staged = None
        self._discard_staged(s)

    def _discard_staged(self, s: _StagedMegaBatch) -> None:
        snap = s.snapshot
        if snap["provider"] is not None and hasattr(self.provider, "load_state_dict"):
            self.provider.load_state_dict(snap["provider"])
        self.scheduler.clock.t[:] = snap["clock_t"]
        if snap["speed"] is not None:
            self.speed.load_state_dict(snap["speed"])
        if s.slot_id is not None:
            self._staging.release(s.slot_id)

    def _global_finite_rows(self, replicas) -> np.ndarray:
        """(R,) bool over the *global* population. Host span: the local
        detection masks allgather so every process agrees on which rows
        need repair (and therefore issues the same repair exchanges); a
        dead peer's rows read finite — its weight is handled by eviction,
        not the guard."""
        finite_local = np.asarray(self._finite_rows(replicas), bool)
        if self._span is None:
            return finite_local
        span = self._span
        gathered = span.allgather("finite", finite_local)
        out = np.ones(self.cfg.n_replicas, bool)
        for pid, arr in gathered.items():
            plo, phi = span.bounds_of(pid)
            out[plo:phi] = np.asarray(arr, bool)
        return out

    def _repair_nonfinite(self, state, replicas, momentum, finite):
        """Re-clone non-finite replicas from a finite donor (DESIGN.md §7).

        The poisoned rows are zeroed first — a zero merge weight alone is
        not enough, ``0 * NaN`` is still NaN — then overwritten with the
        donor: the Algorithm-2 normalized merge of the *finite* rows
        (weights ``b_i`` restricted to them, so the poisoned replicas'
        weight is redistributed by the normalization). Since the donor
        carries exactly the survivors' relative weights, the algorithm's
        subsequent barrier merge over the repaired population equals the
        merge that would have excluded the poisoned rows outright. A fully
        diverged population (the sync family averages gradients *across*
        replicas each round, so one NaN reaches every row within the
        mega-batch) restarts from the last barrier global instead; an
        algorithm that keeps no global copy cannot recover and raises.
        Healed replicas continue with zeroed momentum and their b/lr
        untouched (Algorithm 1 adapts them onward as usual).

        Host span: ``finite`` is the exchange-agreed *global* mask; the
        row operations below apply its local slice, and the donor merge
        (span-aware ``merge_models``) runs on every process — identical
        global mask → identical exchange sequence.
        """
        mask = jnp.asarray(finite[self._span_slice()])

        def keep_rows(l, fill):
            m = mask.reshape((-1,) + (1,) * (l.ndim - 1))
            return jnp.where(m, l, fill)

        replicas = tu.tree_map(
            lambda l: keep_rows(l, jnp.zeros_like(l)), replicas
        )
        if finite.any():
            alphas = np.where(finite, np.asarray(state.b, np.float64), 0.0)
            donor, _ = self.merge_models(
                replicas, alphas / alphas.sum(), None, None, 0.0
            )
        elif state.global_model is not None:
            donor = state.global_model
        else:
            raise FloatingPointError(
                "all replicas diverged to non-finite values and algorithm "
                f"{self.algo.name!r} keeps no global model to restart from"
            )
        replicas = tu.tree_map(
            lambda l, g: keep_rows(
                l, jnp.broadcast_to(g[None].astype(l.dtype), l.shape)
            ),
            replicas,
            donor,
        )
        if momentum is not None:
            momentum = tu.tree_map(
                lambda l: keep_rows(l, jnp.zeros_like(l)), momentum
            )
        return replicas, momentum

    # ------------------------------------------------------------------
    # evaluation + full run
    # ------------------------------------------------------------------
    @staticmethod
    def _eval_cache_key(test_batches: list) -> tuple:
        """Content fingerprint of a test set: length plus the identities of
        the first/last payloads. List identity alone (the PR-3 cache key)
        went stale when a caller rebuilt the list object *or* mutated the
        same list in place — both now change the fingerprint. (A swap of
        only a middle element still aliases; callers doing surgical edits
        should pass a fresh list.)"""
        return (
            id(test_batches),
            len(test_batches),
            id(test_batches[0]) if test_batches else None,
            id(test_batches[-1]) if test_batches else None,
        )

    def _staged_test_batches(self, test_batches: list) -> list:
        """Stack + upload the test set once; reuse the device arrays.

        ``evaluate`` used to re-stack and re-upload every payload on every
        call — pure host overhead repeated each eval. The staged batches
        are cached by the content fingerprint above, so repeated
        evaluation of the same test set only runs the jitted loss while a
        rebuilt or mutated test set re-stages. The source list *and its
        current payloads* are kept referenced so none of the fingerprint
        ids can be recycled by new objects between calls.
        """
        key = self._eval_cache_key(test_batches)
        if self._eval_batches_key != key:
            staged = []
            for payload in test_batches:
                batch = {
                    k: jnp.asarray(v[0])
                    for k, v in self.provider.stack([payload]).items()
                }
                staged.append(batch)
            self._eval_batches = staged
            self._eval_batches_key = key
            self._eval_batches_src = (test_batches, list(test_batches))
        return self._eval_batches

    def evaluate_async(self, params: PyTree, test_batches: list):
        """Dispatch the jitted eval of every staged test batch without
        syncing; returns a zero-arg collector that blocks on the results.
        The overlap pipeline (DESIGN.md §8) dispatches at a mega-batch
        boundary and collects at the next one, so eval device work queues
        behind (and interleaves with) the next mega-batch instead of
        stalling the host between them."""
        pending = [
            self._eval(params, batch)
            for batch in self._staged_test_batches(test_batches)
        ]

        def collect() -> dict:
            tot_acc, tot_loss, tot_n = 0.0, 0.0, 0.0
            for loss, aux in pending:
                n = float(aux["n_valid"])
                tot_acc += float(aux["accuracy"]) * n
                tot_loss += float(loss) * n
                tot_n += n
            return {
                "accuracy": tot_acc / max(tot_n, 1.0),
                "loss": tot_loss / max(tot_n, 1.0),
            }

        return collect

    def evaluate(self, params: PyTree, test_batches: list) -> dict:
        return self.evaluate_async(params, test_batches)()

    def _span_gather_state(self, state: ElasticState):
        """Assemble width-complete ``(replicas, momentum)`` host trees under
        a host span: allgather every live process's local rows and lay them
        into global-``R`` numpy arrays by slot block. Rows belonging to
        already-evicted processes no longer exist (the width shrank with
        them), so the only fill needed is for peers that die *during* this
        exchange — their rows take the global model broadcast (replicas) /
        zeros (momentum), matching what a crash eviction would have merged
        away anyway.
        """
        span = self._span
        R = int(self.cfg.n_replicas)
        reps_local = tu.tree_map(np.asarray, state.replicas)
        mom_local = (
            tu.tree_map(np.asarray, state.momentum)
            if state.momentum is not None else None
        )
        gathered = span.allgather(
            "ckpt", {"replicas": reps_local, "momentum": mom_local}
        )
        have = sorted(gathered)
        g_np = (
            tu.tree_map(np.asarray, state.global_model)
            if state.global_model is not None else None
        )

        def assemble(key: str, fill_tree):
            local_tree = gathered[span.process_id][key]
            if local_tree is None:
                return None
            local_leaves, treedef = jax.tree_util.tree_flatten(local_tree)
            by_pid = {
                pid: jax.tree_util.tree_flatten(gathered[pid][key])[0]
                for pid in have
            }
            fill_leaves = (
                jax.tree_util.tree_leaves(fill_tree)
                if fill_tree is not None else None
            )
            out = []
            for i, leaf in enumerate(local_leaves):
                g = np.zeros((R,) + leaf.shape[1:], leaf.dtype)
                if fill_leaves is not None:
                    g[:] = fill_leaves[i][None]
                for pid in have:
                    lo, hi = span.bounds_of(pid)
                    g[lo:hi] = by_pid[pid][i]
                out.append(g)
            return jax.tree_util.tree_unflatten(treedef, out)

        return assemble("replicas", g_np), assemble("momentum", None)

    # ------------------------------------------------------------------
    # crash-consistent checkpointing (DESIGN.md §7)
    # ------------------------------------------------------------------
    def checkpoint_payload(self, state: ElasticState) -> tuple[dict, dict]:
        """Everything a restored run needs to continue the exact
        trajectory: ``(tensor_tree, json_metadata)`` for
        ``checkpoint.store.save``. Tensors cover the model state (replicas,
        globals, momentum), the per-replica b/lr, the scheduler's virtual
        clocks, and the speed model's arrays; metadata carries the
        mega-batch index, population width, algorithm name, the speed
        model's counters/RNG, and the data provider's stream cursor + RNG.

        Prefetch interplay (DESIGN.md §8): when a mega-batch for this exact
        ``state`` is staged but unconsumed, the *snapshot* cursors from
        before its staging plan are checkpointed instead of the live ones —
        the prefetched batch has not been trained on, so a restore must
        replay it, not skip it. (Provider stream, virtual clocks, and the
        simulated speed model roll back; a measured model's EMAs are
        observation history, not plan cursors, and stay live.)
        """
        speed_sd = self.speed.state_dict()
        provider_sd = (
            self.provider.state_dict()
            if hasattr(self.provider, "state_dict") else None
        )
        clock_t = np.asarray(self.scheduler.clock.t, np.float64)
        staged = self._staged
        if staged is not None and staged.megabatch_idx == int(state.megabatch_idx):
            snap = staged.snapshot
            if snap["provider"] is not None:
                provider_sd = snap["provider"]
            clock_t = np.asarray(snap["clock_t"], np.float64)
            if snap["speed"] is not None:
                speed_sd = snap["speed"]
        replicas_ckpt, momentum_ckpt = state.replicas, state.momentum
        if self._span is not None:
            # width-complete checkpoint (DESIGN.md §10): allgather every
            # process's rows so a single-process run can restore it. Every
            # process assembles the payload (the allgather is an exchange —
            # all must participate on the deterministic interval), but only
            # the publishing manager writes (CheckpointManager(publisher=)).
            replicas_ckpt, momentum_ckpt = self._span_gather_state(state)
        tree = {
            "replicas": replicas_ckpt,
            "momentum": momentum_ckpt,
            "global_model": state.global_model,
            "prev_global": state.prev_global,
            "b": np.asarray(state.b, np.float64),
            "lr": np.asarray(state.lr, np.float64),
            "clock_t": clock_t,
            "speed": speed_sd["arrays"],
        }
        metadata = {
            "format": 1,
            "megabatch_idx": int(state.megabatch_idx),
            "n_replicas": int(self.cfg.n_replicas),
            "algorithm": self.cfg.algorithm,
            "seed": int(self.seed),
            "has": {
                "momentum": state.momentum is not None,
                "global_model": state.global_model is not None,
                "prev_global": state.prev_global is not None,
            },
            "speed_meta": speed_sd["meta"],
        }
        if provider_sd is not None:
            metadata["provider"] = provider_sd
        return tree, metadata

    def restore_checkpoint(self, path: str) -> ElasticState:
        """Rebuild the full training state from an atomic checkpoint.

        ``path`` is one checkpoint directory or a manager directory (the
        newest complete checkpoint is taken). The trainer must be
        constructed with the same model/algorithm/config family as the
        writer — structural mismatches raise
        :class:`repro.checkpoint.store.CheckpointError` — but its
        construction-time replica count may differ: the checkpointed width
        is adopted (``_adopt_width``), exactly like a resize to it.
        """
        from repro.checkpoint import store as ckpt_store

        # any prefetched plan belongs to the pre-restore trajectory
        self.invalidate_prefetch()
        path = ckpt_store.resolve_checkpoint(path)
        meta = ckpt_store.load_metadata(path)
        if meta.get("algorithm") != self.cfg.algorithm:
            raise ckpt_store.CheckpointError(
                f"checkpoint {path} was written by algorithm "
                f"{meta.get('algorithm')!r}; this trainer runs "
                f"{self.cfg.algorithm!r}"
            )
        new_R = int(meta["n_replicas"])
        if new_R != self.cfg.n_replicas:
            if self._span is not None:
                # re-split the checkpointed global width across the live
                # processes before adopting it (raises if indivisible)
                self._span.assign_slots(new_R)
            self._adopt_width(new_R)
        speed_sd = self.speed.state_dict()
        ckpt_kind = meta.get("speed_meta", {}).get("kind")
        if ckpt_kind != speed_sd["meta"]["kind"]:
            raise ckpt_store.CheckpointError(
                f"checkpoint {path} carries a {ckpt_kind!r} speed model; "
                f"this trainer uses {speed_sd['meta']['kind']!r}"
            )
        ref = self.init_state()
        has = meta.get("has", {})
        if bool(has.get("momentum")) != (ref.momentum is not None):
            raise ckpt_store.CheckpointError(
                f"checkpoint {path} "
                f"{'has' if has.get('momentum') else 'lacks'} momentum but "
                "this trainer's SGD config disagrees"
            )
        # global/prev presence follows the *checkpoint*, not init_state:
        # algorithms without Alg.-2 global copies still publish a global
        # model from their first barrier onward (MergeOutcome.global_model)
        params_like = tu.tree_replica_slice(ref.replicas, 0)
        like_replicas, like_momentum = ref.replicas, ref.momentum
        if self._span is not None:
            # checkpoints are width-complete (global R); the local ref trees
            # only span this process's block, so rebuild global-width likes
            like_replicas = tu.tree_broadcast_replicas(params_like, new_R)
            if ref.momentum is not None:
                like_momentum = tu.tree_map(
                    lambda l: jnp.zeros((new_R,) + l.shape[1:], l.dtype),
                    ref.momentum,
                )
        like = {
            "replicas": like_replicas,
            "momentum": like_momentum,
            "global_model": params_like if has.get("global_model") else None,
            "prev_global": params_like if has.get("prev_global") else None,
            "b": np.zeros(new_R, np.float64),
            "lr": np.zeros(new_R, np.float64),
            "clock_t": np.zeros(new_R, np.float64),
            "speed": speed_sd["arrays"],
        }
        tree, _ = ckpt_store.load(path, like)
        self.scheduler.clock.t[:] = np.asarray(tree["clock_t"], np.float64)
        self.speed.load_state_dict(
            {"arrays": tree["speed"], "meta": meta["speed_meta"]}
        )
        if isinstance(self.speed, MeasuredSpeedModel):
            # the fresh process jit-compiles inside the first timed window
            self.speed.discard_next_window()
        if "provider" in meta and hasattr(self.provider, "load_state_dict"):
            self.provider.load_state_dict(meta["provider"])
        replicas_t, momentum_t = tree["replicas"], tree["momentum"]
        if self._span is not None:
            # keep only this process's slot block of the global-width rows
            sl = self._span_slice()
            replicas_t = tu.tree_map(lambda l: np.asarray(l)[sl], replicas_t)
            if momentum_t is not None:
                momentum_t = tu.tree_map(
                    lambda l: np.asarray(l)[sl], momentum_t
                )
        replicas, momentum, global_model, prev_global = self._place_state(
            replicas_t, momentum_t,
            tree["global_model"], tree["prev_global"],
        )
        return ElasticState(
            replicas=replicas,
            global_model=global_model,
            prev_global=prev_global,
            momentum=momentum,
            b=np.asarray(tree["b"], np.float64),
            lr=np.asarray(tree["lr"], np.float64),
            megabatch_idx=int(meta["megabatch_idx"]),
        )

    def _validate_resize_schedule(
        self, resize_schedule: dict
    ) -> dict[int, int]:
        """Normalize + validate a resize schedule at launch (DESIGN.md §6).

        Rejects negative mega-batch indices, entries that collide after int
        normalization (``{"3": 4, 3: 6}``), and replica targets the
        algorithm's resize_policy would refuse 40 mega-batches in — a bad
        ``--elastic-schedule`` must fail before training starts.
        """
        out: dict[int, int] = {}
        policy = getattr(self.algo, "resize_policy", "merge")
        for raw_mb, raw_R in resize_schedule.items():
            mb, target = int(raw_mb), int(raw_R)
            if mb != float(raw_mb) or target != float(raw_R):
                raise ValueError(
                    f"resize schedule entry {raw_mb!r}: {raw_R!r} is not "
                    "an integer pair"
                )
            if mb < 0:
                raise ValueError(
                    f"resize schedule has negative mega-batch index {mb}"
                )
            if mb in out:
                raise ValueError(
                    f"resize schedule defines mega-batch {mb} twice "
                    "(duplicate after normalization)"
                )
            resolved = int(self.algo.resolve_n_replicas(target))
            if resolved < 1:
                raise ValueError(
                    f"resize schedule targets {target} replicas at "
                    f"mega-batch {mb}"
                )
            if policy == "fixed" and resolved != self.cfg.n_replicas:
                raise ValueError(
                    f"algorithm {self.algo.name!r} pins its replica "
                    f"membership (resize_policy='fixed'); schedule entry "
                    f"{mb}: {target} is invalid"
                )
            out[mb] = target
        return out

    def run(
        self,
        n_megabatches: int,
        test_batches: Optional[list] = None,
        eval_every: int = 1,
        verbose: bool = False,
        resize_schedule: Optional[dict[int, int]] = None,
        fleet: Optional[Any] = None,
        checkpoint: Optional[Any] = None,
        restore_from: Optional[str] = None,
    ) -> tuple[ElasticState, MetricsLog]:
        """Train ``n_megabatches`` mega-batches.

        ``resize_schedule`` maps a 0-based mega-batch index to the replica
        count that takes effect *before* that mega-batch runs (the
        launcher's ``--elastic-schedule "0:4,20:6,40:3"``): workers join or
        leave at those boundaries via ``resize``. An entry matching the
        current R is a no-op, so a constant schedule reproduces the
        unscheduled run bit-for-bit. Schedules are validated up front.

        ``fleet`` — a ``core.fleet.FleetController``: reactive membership.
        Its ``step(trainer, state, mb)`` runs at each boundary (after any
        scheduled resize), consuming fault events and health signals.

        ``checkpoint`` — a ``checkpoint.store.CheckpointManager``: after
        every mega-batch ``maybe_save`` snapshots on its interval; the
        final in-flight write is joined before returning.

        ``restore_from`` — checkpoint path (or manager directory): resume
        from it instead of ``init_state``. Training continues at the
        checkpointed mega-batch index; metrics of earlier mega-batches
        belong to the previous process's log.
        """
        if resize_schedule is not None:
            resize_schedule = self._validate_resize_schedule(resize_schedule)
        if restore_from is not None:
            state = self.restore_checkpoint(restore_from)
        else:
            state = self.init_state()
        mlog = MetricsLog()
        overlap_active = self.overlap and self.engine == "scan"
        pending_eval = None  # (mlog record to backfill, collector)

        def emit_line(record):
            if not verbose:
                return
            log(
                f"[{self.cfg.algorithm}] mb={record['megabatch']}",
                loss=round(record["train_loss"], 4),
                acc=round(record.get("accuracy", float("nan")), 4),
                u=record["u"],
                b=record["b"],
                vt=round(record["virtual_time"], 3),
            )

        def drain_eval():
            nonlocal pending_eval
            if pending_eval is not None:
                record, collect = pending_eval
                ev = collect()
                record.update(accuracy=ev["accuracy"], test_loss=ev["loss"])
                pending_eval = None
                # the progress line for an async-eval boundary waits for the
                # backfill, so it never shows a placeholder accuracy
                emit_line(record)

        t0 = time.perf_counter()
        for mb in range(int(state.megabatch_idx), n_megabatches):
            if resize_schedule is not None and mb in resize_schedule:
                state = self.resize(state, resize_schedule[mb])
            if fleet is not None:
                state = fleet.step(self, state, mb)
            # the final mega-batch stages nothing: run() must end with every
            # host cursor consumed (no dangling prefetch in checkpoints or
            # for a caller that continues this trainer by hand)
            state, info = self.run_megabatch(
                state, prefetch=overlap_active and (mb + 1 < n_megabatches)
            )
            if checkpoint is not None:
                checkpoint.maybe_save(self, state)
            # collect the PREVIOUS boundary's async eval only now — its
            # device work ran behind this mega-batch instead of serializing
            drain_eval()
            collect = None
            if test_batches is not None and (mb + 1) % eval_every == 0:
                if overlap_active:
                    collect = self.evaluate_async(
                        state.global_model, test_batches
                    )
                else:
                    ev = self.evaluate(state.global_model, test_batches)
                    info.update(accuracy=ev["accuracy"], test_loss=ev["loss"])
            info["megabatch"] = mb + 1
            info["wall_clock"] = time.perf_counter() - t0
            mlog.append(**info)
            if collect is not None:
                # MetricsLog.append copies kv: backfill the stored record
                pending_eval = (mlog.records[-1], collect)
            else:
                emit_line(mlog.records[-1])
        drain_eval()
        if checkpoint is not None:
            checkpoint.wait()
        return state, mlog
