"""Algorithms 1 & 2 of the paper.

* ``batch_size_scaling`` — Algorithm 1 (host-side, numpy): rescale each
  replica's batch size and learning rate by its deviation from the mean
  update count.
* ``merge_weights`` / ``apply_perturbation`` — Algorithm 2's normalization
  and perturbation of the merge weights (host-side).
* ``normalized_merge`` — Algorithm 2's model update (jit-compatible jnp):
  weighted average of replicas + global-model momentum.

Host/device split: the weight *scalars* are tiny and depend on scheduler
bookkeeping (update counts), so they are computed on host; the O(|w|) tensor
math is jitted and runs sharded (the weighted reduction over the replica-
sharded leading dim lowers to the all-reduce merge of the paper's §4).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ElasticConfig
from repro.utils import tree as tu

PyTree = Any


# --------------------------------------------------------------------------
# Algorithm 1: Batch Size Scaling
# --------------------------------------------------------------------------


def batch_size_scaling(
    b: np.ndarray, lr: np.ndarray, u: np.ndarray, cfg: ElasticConfig
) -> tuple[np.ndarray, np.ndarray]:
    """One application of Algorithm 1.

    b, lr, u: per-replica batch size, learning rate, update count since the
    last merge. Returns updated (b, lr). Faster replicas (u_i > mean) get
    larger batches; slower ones smaller; lr follows the linear-scaling rule.
    """
    b = np.asarray(b, np.float64).copy()
    lr = np.asarray(lr, np.float64).copy()
    u = np.asarray(u, np.float64)
    mu = u.mean()  # line 1
    for i in range(len(b)):
        if u[i] > mu and b[i] + cfg.beta * (u[i] - mu) <= cfg.b_max:  # line 3
            new_b = b[i] + cfg.beta * (u[i] - mu)
            lr[i] = lr[i] * new_b / b[i]  # line 4
            b[i] = new_b  # line 5
        elif u[i] < mu and b[i] - cfg.beta * (mu - u[i]) >= cfg.b_min:  # line 6
            new_b = b[i] - cfg.beta * (mu - u[i])
            lr[i] = lr[i] * new_b / b[i]  # line 7
            b[i] = new_b  # line 8
    return b, lr


# --------------------------------------------------------------------------
# Algorithm 2: Normalized Model Merging
# --------------------------------------------------------------------------


def merge_weights(u: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Lines 1-6: alpha_i from update counts (if they differ) else batch sizes."""
    u = np.asarray(u, np.float64)
    b = np.asarray(b, np.float64)
    if np.all(u == u[0]):  # line 2: identical update counts
        alphas = b / b.sum()  # line 3
    else:
        alphas = u / u.sum()  # line 5
    return alphas


def apply_perturbation(
    alphas: np.ndarray,
    u: np.ndarray,
    replica_norms_per_param: np.ndarray,
    cfg: ElasticConfig,
) -> tuple[np.ndarray, bool]:
    """Lines 7-10: boost the most-updated replica when all are regularized.

    ``replica_norms_per_param`` = ||w_i||_2 / |w| for each replica.
    Returns (alphas, activated). Note the deliberate denormalization.
    """
    alphas = np.asarray(alphas, np.float64).copy()
    if len(alphas) < 2:
        return alphas, False
    if np.all(replica_norms_per_param < cfg.pert_thr):  # line 7
        r = int(np.argmax(u))  # line 8
        s = int(np.argmin(u))
        if r != s:
            alphas[r] *= 1.0 + cfg.delta  # line 9
            alphas[s] *= 1.0 - cfg.delta
            return alphas, True
    return alphas, False


def normalized_merge(
    replicas: PyTree,
    alphas,
    global_model: Optional[PyTree],
    prev_global: Optional[PyTree],
    gamma: float,
    use_kernel: Optional[bool] = None,
    axis_name: Optional[str] = None,
) -> PyTree:
    """Lines 11-12: w' = sum_i alpha_i w_i + gamma (w̄ - w̄_p).

    ``replicas`` leaves have a leading replica dim R (sharded over the
    replica mesh axis at scale). Returns the new global model w'.
    When global/prev are None (memory-lean mode for the >=398B archs, paper
    §4 "it can even be done directly on the model replicas"), the momentum
    term is skipped.

    ``use_kernel`` — route the O(|w|) tensor math through the fused
    weighted-merge Pallas kernel (kernels/weighted_merge): the R-way
    scale+add and the momentum term read every replica shard once from HBM.
    None = auto: kernel on accelerator backends, jnp on CPU (the fallback
    and differential oracle).

    ``axis_name`` — set when tracing inside the sharded replica executor
    (DESIGN.md §5): the local weighted sum over this shard's replicas
    (kernel or jnp — ``alphas`` is the local slice) is a *partial* of
    Algorithm 2's reduction, completed with a psum over the replica mesh
    axis before the momentum term; every shard then holds the replicated
    new global. This is exactly the paper §4 all-reduce merge.
    """
    alphas = jnp.asarray(alphas, jnp.float32)
    if use_kernel is None:
        use_kernel = jax.default_backend() in ("tpu", "gpu")
    momentum = not (global_model is None or prev_global is None or gamma == 0.0)
    if use_kernel:
        from repro.kernels.weighted_merge.ops import merge_pytree

        if momentum and axis_name is None:
            # single-program path: weighted sum + momentum fused in-kernel
            return merge_pytree(replicas, alphas, global_model, prev_global, gamma)
        merged = merge_pytree(replicas, alphas)
    else:
        merged = tu.tree_weighted_sum_replicas(replicas, alphas)
    if axis_name is not None:
        # per-shard partials -> the collective merge (momentum term must see
        # the complete weighted sum, so the psum sits between the two)
        merged = tu.tree_map(lambda l: jax.lax.psum(l, axis_name), merged)
    if not momentum:
        return merged
    return tu.tree_map(
        lambda m, g, gp: (
            m.astype(jnp.float32) + gamma * (g.astype(jnp.float32) - gp.astype(jnp.float32))
        ).astype(m.dtype),
        merged,
        global_model,
        prev_global,
    )


def replica_regularization(replicas: PyTree) -> np.ndarray:
    """||w_i||_2 / |w| per replica (feeds the line-7 condition)."""
    norms = tu.tree_l2_norm_per_replica(replicas)
    n_param = tu.tree_size(replicas) / norms.shape[0]
    return np.asarray(norms) / n_param
