"""Data providers: the trainer's uniform batch interface.

A provider fetches variable-size batches into fixed-slot payloads, reports
their work units (nnz / tokens — feeds the virtual clock), and stacks R
per-replica payloads into the (R, ...) device arrays of a lockstep round.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .batcher import (
    SparseBatcher,
    stack_lazy_plan,
    stack_plan_batches,
    stack_replica_batches,
)
from .sparse import LazySparseBatch, SparseBatch, SparseDataset, pack_batch
from .tokens import TokenStream, stack_plan_token_batches, stack_token_batches


def plan_update_mask(grid: list[list]) -> np.ndarray:
    """(n_rounds, R) float32 mask: 1 where a payload was dispatched."""
    return np.asarray(
        [[0.0 if p is None else 1.0 for p in row] for row in grid], np.float32
    )


@dataclass
class SparseProvider:
    batcher: SparseBatcher

    @staticmethod
    def make(ds: SparseDataset, seed: int = 0) -> "SparseProvider":
        return SparseProvider(SparseBatcher(ds, seed=seed))

    def fetch(self, take: int, b_slots: int) -> SparseBatch:
        return self.batcher.next_batch(take, b_slots)

    def fetch_staged(self, take: int, b_slots: int) -> tuple[LazySparseBatch, int]:
        """Prefetch-path fetch: same stream draw as :meth:`fetch`, but packing
        is deferred to :meth:`stack_plan`'s fused gather (DESIGN.md §8)."""
        p = self.batcher.next_batch_lazy(take, b_slots)
        return p, p.work

    def empty(self, b_slots: int) -> SparseBatch:
        return self.batcher.empty(b_slots)

    def work_units(self, payload: SparseBatch) -> int:
        return payload.total_nnz

    def stack(self, payloads: list[SparseBatch]) -> dict:
        return stack_replica_batches(payloads)

    def state_dict(self) -> dict:
        return self.batcher.state_dict()

    def load_state_dict(self, sd: dict) -> None:
        self.batcher.load_state_dict(sd)

    def staging_spec(self, n_rounds: int, n_replicas: int, b_slots: int) -> dict:
        """{field: (shape, dtype)} of the stacked plan grid, for StagingBuffers."""
        nnz, lab = self.batcher.max_nnz, self.batcher.max_labels
        g = (n_rounds, n_replicas, b_slots)
        return {
            "feat_idx": (g + (nnz,), np.int32),
            "feat_val": (g + (nnz,), np.float32),
            "feat_mask": (g + (nnz,), bool),
            "label_idx": (g + (lab,), np.int32),
            "label_mask": (g + (lab,), bool),
            "sample_mask": (g, bool),
        }

    def stack_plan(
        self, grid: list[list], b_slots: int, out: dict | None = None
    ) -> tuple[dict, np.ndarray]:
        """Whole-plan stack: (n_rounds, R, ...) arrays + (n_rounds, R) mask.

        Lazy payload grids (from :meth:`fetch_staged`) take the fused
        vectorized gather; eager grids keep the per-payload path. ``out``
        is an optional pre-zeroed staging slot to pack into.
        """
        first = next((p for row in grid for p in row if p is not None), None)
        if isinstance(first, LazySparseBatch):
            b = self.batcher
            stacked = stack_lazy_plan(
                b.ds, grid, b_slots, b.max_nnz, b.max_labels, out=out
            )
        else:
            stacked = stack_plan_batches(grid, self.empty(b_slots), out=out)
        return stacked, plan_update_mask(grid)

    def test_batches(self, ds: SparseDataset, b_slots: int, max_samples: int = 0):
        """Pack a test dataset into full-size batches for evaluation."""
        n = ds.n_samples if not max_samples else min(ds.n_samples, max_samples)
        out = []
        for s in range(0, n, b_slots):
            ids = np.arange(s, min(s + b_slots, n))
            out.append(
                pack_batch(ds, ids, b_slots, self.batcher.max_nnz, self.batcher.max_labels)
            )
        return out


@dataclass
class TokenProvider:
    stream: TokenStream
    seq_len: int

    @staticmethod
    def make(vocab_size: int, seq_len: int, seed: int = 0) -> "TokenProvider":
        return TokenProvider(TokenStream(vocab_size, seed=seed), seq_len)

    def fetch(self, take: int, b_slots: int) -> dict:
        return self.stream.batch(take, b_slots, self.seq_len)

    def fetch_staged(self, take: int, b_slots: int) -> tuple[dict, int]:
        """Token batches consume stream RNG at fetch time, so there is no
        lazy form — the staged path packs eagerly and still benefits from
        buffered stacking + the single batched upload."""
        p = self.fetch(take, b_slots)
        return p, self.work_units(p)

    def empty(self, b_slots: int) -> dict:
        return self.stream.batch(0, b_slots, self.seq_len)

    def work_units(self, payload: dict) -> int:
        return int(payload["sample_mask"].sum()) * self.seq_len

    def stack(self, payloads: list[dict]) -> dict:
        return stack_token_batches(payloads)

    def state_dict(self) -> dict:
        return self.stream.state_dict()

    def load_state_dict(self, sd: dict) -> None:
        self.stream.load_state_dict(sd)

    def staging_spec(self, n_rounds: int, n_replicas: int, b_slots: int) -> dict:
        g = (n_rounds, n_replicas, b_slots)
        return {
            "tokens": (g + (self.seq_len,), np.int32),
            "targets": (g + (self.seq_len,), np.int32),
            "sample_mask": (g, bool),
        }

    def stack_plan(
        self, grid: list[list], b_slots: int, out: dict | None = None
    ) -> tuple[dict, np.ndarray]:
        """Whole-plan stack: (n_rounds, R, ...) arrays + (n_rounds, R) mask."""
        return (
            stack_plan_token_batches(grid, self.empty(b_slots), out=out),
            plan_update_mask(grid),
        )

    def test_batches(self, n_batches: int, b_slots: int):
        return [self.fetch(b_slots, b_slots) for _ in range(n_batches)]
