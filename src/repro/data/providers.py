"""Data providers: the trainer's uniform batch interface.

A provider fetches variable-size batches into fixed-slot payloads, reports
their work units (nnz / tokens — feeds the virtual clock), and stacks R
per-replica payloads into the (R, ...) device arrays of a lockstep round.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .batcher import SparseBatcher, stack_replica_batches
from .sparse import SparseBatch, SparseDataset, pack_batch
from .tokens import TokenStream, stack_token_batches


@dataclass
class SparseProvider:
    batcher: SparseBatcher

    @staticmethod
    def make(ds: SparseDataset, seed: int = 0) -> "SparseProvider":
        return SparseProvider(SparseBatcher(ds, seed=seed))

    def fetch(self, take: int, b_slots: int) -> SparseBatch:
        return self.batcher.next_batch(take, b_slots)

    def empty(self, b_slots: int) -> SparseBatch:
        return self.batcher.empty(b_slots)

    def work_units(self, payload: SparseBatch) -> int:
        return payload.total_nnz

    def stack(self, payloads: list[SparseBatch]) -> dict:
        return stack_replica_batches(payloads)

    def test_batches(self, ds: SparseDataset, b_slots: int, max_samples: int = 0):
        """Pack a test dataset into full-size batches for evaluation."""
        n = ds.n_samples if not max_samples else min(ds.n_samples, max_samples)
        out = []
        for s in range(0, n, b_slots):
            ids = np.arange(s, min(s + b_slots, n))
            out.append(
                pack_batch(ds, ids, b_slots, self.batcher.max_nnz, self.batcher.max_labels)
            )
        return out


@dataclass
class TokenProvider:
    stream: TokenStream
    seq_len: int

    @staticmethod
    def make(vocab_size: int, seq_len: int, seed: int = 0) -> "TokenProvider":
        return TokenProvider(TokenStream(vocab_size, seed=seed), seq_len)

    def fetch(self, take: int, b_slots: int) -> dict:
        return self.stream.batch(take, b_slots, self.seq_len)

    def empty(self, b_slots: int) -> dict:
        return self.stream.batch(0, b_slots, self.seq_len)

    def work_units(self, payload: dict) -> int:
        return int(payload["sample_mask"].sum()) * self.seq_len

    def stack(self, payloads: list[dict]) -> dict:
        return stack_token_batches(payloads)

    def test_batches(self, n_batches: int, b_slots: int):
        return [self.fetch(b_slots, b_slots) for _ in range(n_batches)]
