"""Synthetic token-LM data pipeline for the assigned transformer archs.

Produces (tokens, targets, sample_mask) batches. Token streams are Zipf-
distributed with a learnable bigram structure so small models show loss
movement in smoke tests / examples. The same padded-slot + mask mechanism
used for sparse batches carries the adaptive batch size for LM training.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Markov (bigram) synthetic corpus over a vocab."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        # sparse bigram table: every token has `branch` likely successors
        self.next_tok = self.rng.integers(0, vocab_size, size=(vocab_size, branch))

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        branch = self.next_tok.shape[1]
        for t in range(1, seq_len + 1):
            # 80% follow the bigram table, 20% jump uniformly
            follow = self.rng.random(batch) < 0.8
            choice = self.next_tok[cur, self.rng.integers(0, branch, size=batch)]
            jump = self.rng.integers(0, self.vocab, size=batch)
            cur = np.where(follow, choice, jump).astype(np.int32)
            out[:, t] = cur
        return out

    def batch(self, b_valid: int, b_slots: int, seq_len: int) -> dict:
        toks = np.zeros((b_slots, seq_len + 1), np.int32)
        if b_valid:
            toks[:b_valid] = self.sample(b_valid, seq_len)
        mask = np.zeros((b_slots,), bool)
        mask[:b_valid] = True
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "sample_mask": mask,
        }

    # ---- checkpointing (DESIGN.md §7) ----
    def state_dict(self) -> dict:
        """RNG state only: the bigram table is deterministic in the seed and
        rebuilt by construction, so a restored stream continues the exact
        token sequence of the killed run."""
        return {"rng": self.rng.bit_generator.state}

    def load_state_dict(self, sd: dict) -> None:
        self.rng.bit_generator.state = sd["rng"]


def stack_token_batches(batches: list[dict]) -> dict:
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


def stack_plan_token_batches(
    grid: list[list], template: dict, out: dict | None = None
) -> dict:
    """Stack a scheduler payload grid into (n_rounds, R, ...) token arrays.

    Masked (None) slots stay all-zero — identical to an empty token batch
    (sample_mask all False)."""
    from .batcher import stack_plan_grid

    return stack_plan_grid(grid, template, out=out)
