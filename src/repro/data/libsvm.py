"""libSVM multi-label format reader/writer.

The paper stores training data "in the sparse libSVM format"; the XML
repository uses the multi-label variant::

    l1,l2,...  f1:v1 f2:v2 ...

First line may be a header ``N n_features n_classes`` (XMLRepo convention).
"""
from __future__ import annotations

import numpy as np

from .sparse import SparseDataset


def read_libsvm(path: str, n_features: int = 0, n_classes: int = 0) -> SparseDataset:
    indptr = [0]
    indices: list[int] = []
    values: list[float] = []
    label_ptr = [0]
    labels: list[int] = []
    with open(path) as f:
        first = f.readline().strip()
        toks = first.split()
        header = len(toks) == 3 and all(t.isdigit() for t in toks)
        if header:
            _, n_features, n_classes = (int(t) for t in toks)
        else:
            _parse_line(first, indices, values, labels)
            indptr.append(len(indices))
            label_ptr.append(len(labels))
        for line in f:
            line = line.strip()
            if not line:
                continue
            _parse_line(line, indices, values, labels)
            indptr.append(len(indices))
            label_ptr.append(len(labels))
    idx = np.asarray(indices, np.int32)
    lab = np.asarray(labels, np.int32)
    if not n_features:
        n_features = int(idx.max()) + 1 if len(idx) else 1
    if not n_classes:
        n_classes = int(lab.max()) + 1 if len(lab) else 1
    return SparseDataset(
        n_features=n_features,
        n_classes=n_classes,
        indptr=np.asarray(indptr, np.int64),
        indices=idx,
        values=np.asarray(values, np.float32),
        label_ptr=np.asarray(label_ptr, np.int64),
        labels=lab,
    )


def _parse_line(line: str, indices, values, labels) -> None:
    parts = line.split()
    start = 0
    if parts and ":" not in parts[0]:
        for l in parts[0].split(","):
            if l:
                labels.append(int(l))
        start = 1
    for tok in parts[start:]:
        k, v = tok.split(":")
        indices.append(int(k))
        values.append(float(v))


def write_libsvm(ds: SparseDataset, path: str, header: bool = True) -> None:
    with open(path, "w") as f:
        if header:
            f.write(f"{ds.n_samples} {ds.n_features} {ds.n_classes}\n")
        for i in range(ds.n_samples):
            idx, val, lab = ds.sample(i)
            lab_s = ",".join(str(int(l)) for l in lab)
            feat_s = " ".join(f"{int(k)}:{float(v):.6g}" for k, v in zip(idx, val))
            f.write(f"{lab_s} {feat_s}\n")
