"""Sparse sample containers.

The paper trains on libSVM-style sparse data (XML classification): each
sample is a high-dimensional sparse feature vector plus a sparse label set.
TPUs need static shapes, so batches are *padded COO*: fixed ``max_nnz``
feature slots and ``max_labels`` label slots per sample, with masks. The
per-sample non-zero count varies (this is one of the paper's two sources of
heterogeneity) and drives the virtual-clock cost model.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SparseDataset:
    """CSR-style storage of a sparse multi-label dataset (host memory)."""

    n_features: int
    n_classes: int
    indptr: np.ndarray     # (N+1,) int64
    indices: np.ndarray    # (nnz,) int32
    values: np.ndarray     # (nnz,) float32
    label_ptr: np.ndarray  # (N+1,) int64
    labels: np.ndarray     # (total_labels,) int32

    @property
    def n_samples(self) -> int:
        return len(self.indptr) - 1

    def nnz_of(self, i: int) -> int:
        return int(self.indptr[i + 1] - self.indptr[i])

    def sample(self, i: int):
        s, e = self.indptr[i], self.indptr[i + 1]
        ls, le = self.label_ptr[i], self.label_ptr[i + 1]
        return self.indices[s:e], self.values[s:e], self.labels[ls:le]

    def avg_nnz(self) -> float:
        return float(len(self.indices)) / max(1, self.n_samples)

    def avg_labels(self) -> float:
        return float(len(self.labels)) / max(1, self.n_samples)


@dataclass
class SparseBatch:
    """Padded COO batch with masks; every array is statically shaped.

    ``sample_mask`` implements the paper's *adaptive batch size*: a batch
    always has ``b_max`` slots, of which only the first ``b_i`` are valid.
    """

    feat_idx: np.ndarray     # (B, max_nnz) int32
    feat_val: np.ndarray     # (B, max_nnz) float32
    feat_mask: np.ndarray    # (B, max_nnz) bool
    label_idx: np.ndarray    # (B, max_labels) int32
    label_mask: np.ndarray   # (B, max_labels) bool
    sample_mask: np.ndarray  # (B,) bool

    @property
    def batch_slots(self) -> int:
        return self.feat_idx.shape[0]

    @property
    def n_valid(self) -> int:
        return int(self.sample_mask.sum())

    @property
    def total_nnz(self) -> int:
        return int((self.feat_mask & self.sample_mask[:, None]).sum())


@dataclass
class LazySparseBatch:
    """Deferred batch: sample ids + work units, no packed arrays yet.

    The overlap staging path (DESIGN.md §8) fetches these during planning —
    ``work`` is computed straight from the CSR ``indptr`` so the discrete-
    event scheduler can cost the dispatch without paying for ``pack_batch``'s
    per-row Python loop. The whole mega-batch is then packed in one
    vectorized gather by :func:`repro.data.batcher.stack_lazy_plan`.
    ``work`` equals the packed batch's ``total_nnz`` exactly (per-row nnz
    clipped to ``max_nnz``), so virtual-clock trajectories are bit-identical
    to the eager path.
    """

    ids: np.ndarray   # (n,) int64 sample ids, n <= b_slots
    work: int         # sum(min(nnz_i, max_nnz)) == packed total_nnz


def subset(ds: SparseDataset, ids: np.ndarray) -> SparseDataset:
    """Row subset of a dataset (rebuilds CSR)."""
    indptr = [0]
    idx_parts, val_parts, lab_parts = [], [], []
    label_ptr = [0]
    for i in ids:
        fidx, fval, lab = ds.sample(int(i))
        idx_parts.append(fidx)
        val_parts.append(fval)
        lab_parts.append(lab)
        indptr.append(indptr[-1] + len(fidx))
        label_ptr.append(label_ptr[-1] + len(lab))
    return SparseDataset(
        n_features=ds.n_features,
        n_classes=ds.n_classes,
        indptr=np.asarray(indptr, np.int64),
        indices=np.concatenate(idx_parts) if idx_parts else np.zeros(0, np.int32),
        values=np.concatenate(val_parts) if val_parts else np.zeros(0, np.float32),
        label_ptr=np.asarray(label_ptr, np.int64),
        labels=np.concatenate(lab_parts) if lab_parts else np.zeros(0, np.int32),
    )


def train_test_split(
    ds: SparseDataset, test_frac: float = 0.2, seed: int = 0
) -> tuple[SparseDataset, SparseDataset]:
    """Split one dataset (same generative structure) into train/test."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ds.n_samples)
    n_test = int(ds.n_samples * test_frac)
    return subset(ds, perm[n_test:]), subset(ds, perm[:n_test])


def pack_batch(
    ds: SparseDataset,
    sample_ids: np.ndarray,
    b_slots: int,
    max_nnz: int,
    max_labels: int,
) -> SparseBatch:
    """Pack ``sample_ids`` (may be fewer than b_slots) into a padded batch."""
    n = len(sample_ids)
    assert n <= b_slots, (n, b_slots)
    fi = np.zeros((b_slots, max_nnz), np.int32)
    fv = np.zeros((b_slots, max_nnz), np.float32)
    fm = np.zeros((b_slots, max_nnz), bool)
    li = np.zeros((b_slots, max_labels), np.int32)
    lm = np.zeros((b_slots, max_labels), bool)
    sm = np.zeros((b_slots,), bool)
    for row, sid in enumerate(sample_ids):
        idx, val, lab = ds.sample(int(sid))
        k = min(len(idx), max_nnz)
        fi[row, :k] = idx[:k]
        fv[row, :k] = val[:k]
        fm[row, :k] = True
        j = min(len(lab), max_labels)
        li[row, :j] = lab[:j]
        lm[row, :j] = True
        sm[row] = True
    return SparseBatch(fi, fv, fm, li, lm, sm)
