"""Batch stream + mega-batch accounting.

The dynamic scheduler (core/scheduler.py) pulls variable-size batches from a
``SampleStream``; a *mega-batch* is a fixed budget of samples between two
model-merging stages (paper §3.1). The stream is an infinite shuffled cursor
over the dataset (reshuffled every epoch), so batch boundaries never depend on
the number of replicas — exactly the paper's "batches are dispatched
one-by-one based on GPU availability".
"""
from __future__ import annotations

import numpy as np

from .sparse import LazySparseBatch, SparseBatch, SparseDataset, pack_batch


class SampleStream:
    """Infinite shuffled cursor over sample ids."""

    def __init__(self, n_samples: int, seed: int = 0):
        self.n = n_samples
        self.rng = np.random.default_rng(seed)
        self.order = self.rng.permutation(self.n)
        self.pos = 0
        self.epoch = 0

    def take(self, k: int) -> np.ndarray:
        out = []
        while k > 0:
            avail = self.n - self.pos
            step = min(k, avail)
            out.append(self.order[self.pos : self.pos + step])
            self.pos += step
            k -= step
            if self.pos == self.n:
                self.epoch += 1
                self.order = self.rng.permutation(self.n)
                self.pos = 0
        return np.concatenate(out)

    # ---- checkpointing (DESIGN.md §7) ----
    def state_dict(self) -> dict:
        """Cursor position + RNG state, JSON-serializable: a restored run
        replays the exact same sample sequence the killed run would have."""
        return {
            "rng": self.rng.bit_generator.state,
            "order": np.asarray(self.order).tolist(),
            "pos": int(self.pos),
            "epoch": int(self.epoch),
        }

    def load_state_dict(self, sd: dict) -> None:
        self.rng.bit_generator.state = sd["rng"]
        self.order = np.asarray(sd["order"], np.int64)
        self.pos = int(sd["pos"])
        self.epoch = int(sd["epoch"])


class SparseBatcher:
    """Packs scheduler-chosen sample ids into padded COO device batches."""

    def __init__(self, ds: SparseDataset, max_nnz: int = 0, max_labels: int = 0, seed: int = 0):
        self.ds = ds
        self.max_nnz = max_nnz or _pad_pow2(int(np.quantile(np.diff(ds.indptr), 0.98)) + 1)
        self.max_labels = max_labels or max(1, int(np.quantile(np.diff(ds.label_ptr), 0.98)) + 1)
        self.stream = SampleStream(ds.n_samples, seed)

    def next_batch(self, b_valid: int, b_slots: int) -> SparseBatch:
        ids = self.stream.take(min(b_valid, b_slots))
        return self.pack(ids, b_slots)

    def next_batch_lazy(self, b_valid: int, b_slots: int) -> LazySparseBatch:
        """Draw the same ids as :meth:`next_batch` but defer packing.

        Work units come from the CSR indptr (clipped per row to ``max_nnz``)
        so they match the eager batch's ``total_nnz`` bit-for-bit.
        """
        ids = self.stream.take(min(b_valid, b_slots))
        nnz = np.minimum(self.ds.indptr[ids + 1] - self.ds.indptr[ids], self.max_nnz)
        return LazySparseBatch(ids=np.asarray(ids, np.int64), work=int(nnz.sum()))

    def pack(self, ids: np.ndarray, b_slots: int) -> SparseBatch:
        return pack_batch(self.ds, ids, b_slots, self.max_nnz, self.max_labels)

    def empty(self, b_slots: int) -> SparseBatch:
        return pack_batch(self.ds, np.zeros((0,), np.int64), b_slots, self.max_nnz, self.max_labels)

    def state_dict(self) -> dict:
        return {"stream": self.stream.state_dict()}

    def load_state_dict(self, sd: dict) -> None:
        self.stream.load_state_dict(sd["stream"])


def _pad_pow2(x: int) -> int:
    p = 8
    while p < x:
        p *= 2
    return p


def stack_replica_batches(batches: list[SparseBatch]) -> dict:
    """Stack R per-replica SparseBatches into (R, ...) device arrays."""
    return {
        "feat_idx": np.stack([b.feat_idx for b in batches]),
        "feat_val": np.stack([b.feat_val for b in batches]),
        "feat_mask": np.stack([b.feat_mask for b in batches]),
        "label_idx": np.stack([b.label_idx for b in batches]),
        "label_mask": np.stack([b.label_mask for b in batches]),
        "sample_mask": np.stack([b.sample_mask for b in batches]),
    }


_SPARSE_FIELDS = (
    "feat_idx", "feat_val", "feat_mask", "label_idx", "label_mask", "sample_mask",
)


def stack_plan_grid(grid: list[list], template: dict, out: dict | None = None) -> dict:
    """Stack a whole mega-batch plan of dict payloads into (n_rounds, R, ...)
    arrays.

    ``grid`` is the scheduler's dense payload grid (None = masked slot);
    ``template`` fixes the per-slot shapes/dtypes. Masked slots stay
    all-zero, which is exactly an empty payload (every mask False), so the
    engine's update mask is the only thing that distinguishes them.

    ``out`` lets the overlap staging path reuse a pre-zeroed
    :class:`StagingBuffers` slot instead of allocating fresh arrays.
    """
    n_rounds, n_replicas = len(grid), len(grid[0])
    if out is None:
        out = {
            k: np.zeros((n_rounds, n_replicas) + v.shape, v.dtype)
            for k, v in template.items()
        }
    for r, row in enumerate(grid):
        for i, p in enumerate(row):
            if p is not None:
                for k in out:
                    out[k][r, i] = p[k]
    return out


def stack_plan_batches(
    grid: list[list], template: SparseBatch, out: dict | None = None
) -> dict:
    """SparseBatch view of :func:`stack_plan_grid`."""
    def as_dict(p):
        return {f: getattr(p, f) for f in _SPARSE_FIELDS}

    return stack_plan_grid(
        [[None if p is None else as_dict(p) for p in row] for row in grid],
        as_dict(template),
        out=out,
    )


def stack_lazy_plan(
    ds: SparseDataset,
    grid: list[list],
    b_slots: int,
    max_nnz: int,
    max_labels: int,
    out: dict | None = None,
) -> dict:
    """Pack a grid of :class:`LazySparseBatch` payloads in one vectorized
    gather — the fused equivalent of per-payload ``pack_batch`` followed by
    :func:`stack_plan_grid`, byte-identical to that composition.

    All (dispatch, row) destinations across the mega-batch are gathered from
    the CSR arrays at once with a padded-position index, then scattered into
    the (n_rounds, R, b_slots, ...) grid via fancy indexing. ``out`` must be
    all-zero on entry (masked slots and padding rely on it); the
    :class:`StagingBuffers` acquire path guarantees this.
    """
    n_rounds, n_replicas = len(grid), len(grid[0])
    if out is None:
        out = {
            "feat_idx": np.zeros((n_rounds, n_replicas, b_slots, max_nnz), np.int32),
            "feat_val": np.zeros((n_rounds, n_replicas, b_slots, max_nnz), np.float32),
            "feat_mask": np.zeros((n_rounds, n_replicas, b_slots, max_nnz), bool),
            "label_idx": np.zeros((n_rounds, n_replicas, b_slots, max_labels), np.int32),
            "label_mask": np.zeros((n_rounds, n_replicas, b_slots, max_labels), bool),
            "sample_mask": np.zeros((n_rounds, n_replicas, b_slots), bool),
        }
    dest_batch, dest_row, id_parts = [], [], []
    for r, row in enumerate(grid):
        for i, p in enumerate(row):
            if p is None or len(p.ids) == 0:
                continue
            n = len(p.ids)
            dest_batch.append(np.full(n, r * n_replicas + i, np.int64))
            dest_row.append(np.arange(n, dtype=np.int64))
            id_parts.append(np.asarray(p.ids, np.int64))
    if not id_parts:
        return out
    db = np.concatenate(dest_batch)
    dr = np.concatenate(dest_row)
    ids = np.concatenate(id_parts)
    # contiguous staging arrays -> reshape yields writable views of `out`
    flat = {k: v.reshape((n_rounds * n_replicas,) + v.shape[2:]) for k, v in out.items()}

    starts = ds.indptr[ids]
    counts = np.minimum(ds.indptr[ids + 1] - starts, max_nnz)
    ar = np.arange(max_nnz)
    m = ar[None, :] < counts[:, None]
    if len(ds.indices):
        pos = np.minimum(starts[:, None] + ar[None, :], len(ds.indices) - 1)
        fi = ds.indices[pos]
        fv = ds.values[pos].copy()
        fi = np.where(m, fi, np.int32(0))
        fv[~m] = np.float32(0)
        flat["feat_idx"][db, dr] = fi
        flat["feat_val"][db, dr] = fv
    flat["feat_mask"][db, dr] = m

    lstarts = ds.label_ptr[ids]
    lcounts = np.minimum(ds.label_ptr[ids + 1] - lstarts, max_labels)
    lar = np.arange(max_labels)
    lmask = lar[None, :] < lcounts[:, None]
    if len(ds.labels):
        lpos = np.minimum(lstarts[:, None] + lar[None, :], len(ds.labels) - 1)
        flat["label_idx"][db, dr] = np.where(lmask, ds.labels[lpos], np.int32(0))
    flat["label_mask"][db, dr] = lmask
    flat["sample_mask"][db, dr] = True
    return out


class StagingBuffers:
    """Two alternating pre-zeroed host staging slots for plan grids.

    The overlap pipeline (DESIGN.md §8) writes mega-batch N+1's grid into one
    slot while the device may still be reading N's arrays — which, on CPU
    backends, can zero-copy alias the other slot's host memory. Alternating
    slots plus the in-use latch below guarantee a slot is only rewritten
    after the mega-batch that consumed it has been collected.
    """

    def __init__(self):
        self._slots: list[dict | None] = [None, None]
        self._busy = [False, False]
        self._next = 0

    def acquire(self, spec: dict) -> tuple[int, dict]:
        """Return ``(slot_id, arrays)`` matching ``spec`` ({name: (shape,
        dtype)}), zero-filled. Raises if the slot is still marked in-flight —
        that would mean staging is running ahead of collection."""
        k = self._next
        if self._busy[k]:
            raise RuntimeError(
                "staging buffer slot still in flight — a prefetched "
                "mega-batch was never collected or released"
            )
        slot = self._slots[k]
        if slot is None or set(slot) != set(spec) or any(
            slot[n].shape != shape or slot[n].dtype != np.dtype(dt)
            for n, (shape, dt) in spec.items()
        ):
            slot = {n: np.zeros(shape, dt) for n, (shape, dt) in spec.items()}
            self._slots[k] = slot
        else:
            for a in slot.values():
                a[...] = 0
        self._busy[k] = True
        self._next = 1 - k
        return k, slot

    def release(self, slot_id: int) -> None:
        self._busy[slot_id] = False

    def reset(self) -> None:
        self._busy = [False, False]
        self._next = 0
