"""Batch stream + mega-batch accounting.

The dynamic scheduler (core/scheduler.py) pulls variable-size batches from a
``SampleStream``; a *mega-batch* is a fixed budget of samples between two
model-merging stages (paper §3.1). The stream is an infinite shuffled cursor
over the dataset (reshuffled every epoch), so batch boundaries never depend on
the number of replicas — exactly the paper's "batches are dispatched
one-by-one based on GPU availability".
"""
from __future__ import annotations

import numpy as np

from .sparse import SparseBatch, SparseDataset, pack_batch


class SampleStream:
    """Infinite shuffled cursor over sample ids."""

    def __init__(self, n_samples: int, seed: int = 0):
        self.n = n_samples
        self.rng = np.random.default_rng(seed)
        self.order = self.rng.permutation(self.n)
        self.pos = 0
        self.epoch = 0

    def take(self, k: int) -> np.ndarray:
        out = []
        while k > 0:
            avail = self.n - self.pos
            step = min(k, avail)
            out.append(self.order[self.pos : self.pos + step])
            self.pos += step
            k -= step
            if self.pos == self.n:
                self.epoch += 1
                self.order = self.rng.permutation(self.n)
                self.pos = 0
        return np.concatenate(out)

    # ---- checkpointing (DESIGN.md §7) ----
    def state_dict(self) -> dict:
        """Cursor position + RNG state, JSON-serializable: a restored run
        replays the exact same sample sequence the killed run would have."""
        return {
            "rng": self.rng.bit_generator.state,
            "order": np.asarray(self.order).tolist(),
            "pos": int(self.pos),
            "epoch": int(self.epoch),
        }

    def load_state_dict(self, sd: dict) -> None:
        self.rng.bit_generator.state = sd["rng"]
        self.order = np.asarray(sd["order"], np.int64)
        self.pos = int(sd["pos"])
        self.epoch = int(sd["epoch"])


class SparseBatcher:
    """Packs scheduler-chosen sample ids into padded COO device batches."""

    def __init__(self, ds: SparseDataset, max_nnz: int = 0, max_labels: int = 0, seed: int = 0):
        self.ds = ds
        self.max_nnz = max_nnz or _pad_pow2(int(np.quantile(np.diff(ds.indptr), 0.98)) + 1)
        self.max_labels = max_labels or max(1, int(np.quantile(np.diff(ds.label_ptr), 0.98)) + 1)
        self.stream = SampleStream(ds.n_samples, seed)

    def next_batch(self, b_valid: int, b_slots: int) -> SparseBatch:
        ids = self.stream.take(min(b_valid, b_slots))
        return self.pack(ids, b_slots)

    def pack(self, ids: np.ndarray, b_slots: int) -> SparseBatch:
        return pack_batch(self.ds, ids, b_slots, self.max_nnz, self.max_labels)

    def empty(self, b_slots: int) -> SparseBatch:
        return pack_batch(self.ds, np.zeros((0,), np.int64), b_slots, self.max_nnz, self.max_labels)

    def state_dict(self) -> dict:
        return {"stream": self.stream.state_dict()}

    def load_state_dict(self, sd: dict) -> None:
        self.stream.load_state_dict(sd["stream"])


def _pad_pow2(x: int) -> int:
    p = 8
    while p < x:
        p *= 2
    return p


def stack_replica_batches(batches: list[SparseBatch]) -> dict:
    """Stack R per-replica SparseBatches into (R, ...) device arrays."""
    return {
        "feat_idx": np.stack([b.feat_idx for b in batches]),
        "feat_val": np.stack([b.feat_val for b in batches]),
        "feat_mask": np.stack([b.feat_mask for b in batches]),
        "label_idx": np.stack([b.label_idx for b in batches]),
        "label_mask": np.stack([b.label_mask for b in batches]),
        "sample_mask": np.stack([b.sample_mask for b in batches]),
    }


_SPARSE_FIELDS = (
    "feat_idx", "feat_val", "feat_mask", "label_idx", "label_mask", "sample_mask",
)


def stack_plan_grid(grid: list[list], template: dict) -> dict:
    """Stack a whole mega-batch plan of dict payloads into (n_rounds, R, ...)
    arrays.

    ``grid`` is the scheduler's dense payload grid (None = masked slot);
    ``template`` fixes the per-slot shapes/dtypes. Masked slots stay
    all-zero, which is exactly an empty payload (every mask False), so the
    engine's update mask is the only thing that distinguishes them.
    """
    n_rounds, n_replicas = len(grid), len(grid[0])
    out = {
        k: np.zeros((n_rounds, n_replicas) + v.shape, v.dtype)
        for k, v in template.items()
    }
    for r, row in enumerate(grid):
        for i, p in enumerate(row):
            if p is not None:
                for k in out:
                    out[k][r, i] = p[k]
    return out


def stack_plan_batches(grid: list[list], template: SparseBatch) -> dict:
    """SparseBatch view of :func:`stack_plan_grid`."""
    def as_dict(p):
        return {f: getattr(p, f) for f in _SPARSE_FIELDS}

    return stack_plan_grid(
        [[None if p is None else as_dict(p) for p in row] for row in grid],
        as_dict(template),
    )
