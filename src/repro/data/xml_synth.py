"""Synthetic extreme multi-label (XML) dataset generator.

Mirrors the statistics of the paper's datasets (Table 1): very large sparse
feature/label spaces, power-law non-zero counts per sample, and a learnable
structure (class-prototype mixture) so accuracy curves are meaningful.

Generation model:
  * each class c has a prototype of ``proto_sz`` feature ids drawn Zipf-like
    from the feature space;
  * a sample picks a primary class, takes a noisy subset of its prototype,
    adds background-noise features, and tags ``~avg_labels`` correlated
    classes as its label set (primary class first).

The per-sample nnz is drawn from a log-normal — matching the paper's
observation that "the number of non-zero features varies significantly among
the training samples", the second source of heterogeneity.
"""
from __future__ import annotations

import numpy as np

from .sparse import SparseDataset


def make_xml_dataset(
    n_samples: int = 2048,
    n_features: int = 4096,
    n_classes: int = 512,
    avg_nnz: int = 64,
    nnz_sigma: float = 0.5,
    avg_labels: int = 3,
    proto_sz: int = 96,
    noise_frac: float = 0.2,
    seed: int = 0,
) -> SparseDataset:
    rng = np.random.default_rng(seed)

    # class prototypes: Zipf-biased feature ids
    zipf_p = 1.0 / (np.arange(1, n_features + 1) ** 0.8)
    zipf_p /= zipf_p.sum()
    protos = [
        rng.choice(n_features, size=proto_sz, replace=False, p=zipf_p)
        for _ in range(n_classes)
    ]
    # label co-occurrence: each class has a fixed set of companion classes
    companions = rng.integers(0, n_classes, size=(n_classes, max(1, avg_labels)))

    indptr = [0]
    indices: list[np.ndarray] = []
    values: list[np.ndarray] = []
    label_ptr = [0]
    labels: list[np.ndarray] = []

    for _ in range(n_samples):
        c = int(rng.integers(n_classes))
        nnz = int(np.clip(rng.lognormal(np.log(avg_nnz), nnz_sigma), 4, 4 * avg_nnz))
        n_noise = int(nnz * noise_frac)
        n_proto = nnz - n_noise
        proto_feats = rng.choice(protos[c], size=min(n_proto, proto_sz), replace=False)
        noise_feats = rng.choice(n_features, size=n_noise, p=zipf_p)
        feats = np.unique(np.concatenate([proto_feats, noise_feats])).astype(np.int32)
        vals = rng.gamma(2.0, 0.5, size=len(feats)).astype(np.float32)

        n_lab = max(1, int(rng.poisson(avg_labels)))
        lab = np.concatenate(([c], companions[c][: n_lab - 1]))
        lab = np.unique(lab).astype(np.int32)
        # keep the primary class first (used for top-1 bookkeeping)
        lab = np.concatenate(([np.int32(c)], lab[lab != c]))

        indices.append(feats)
        values.append(vals)
        indptr.append(indptr[-1] + len(feats))
        labels.append(lab)
        label_ptr.append(label_ptr[-1] + len(lab))

    return SparseDataset(
        n_features=n_features,
        n_classes=n_classes,
        indptr=np.asarray(indptr, np.int64),
        indices=np.concatenate(indices),
        values=np.concatenate(values),
        label_ptr=np.asarray(label_ptr, np.int64),
        labels=np.concatenate(labels),
    )


# Paper-scale dataset descriptors (Table 1) — used by configs/benchmarks to
# instantiate scaled-down but statistically faithful stand-ins.
AMAZON_670K = dict(n_features=135_909, n_classes=670_091, avg_nnz=76, avg_labels=5)
DELICIOUS_200K = dict(n_features=782_585, n_classes=205_443, avg_nnz=302, avg_labels=75)


def make_paper_like(which: str, scale: float = 0.01, n_samples: int = 4096, seed: int = 0):
    """A scale-factor stand-in for Amazon-670k / Delicious-200k."""
    spec = {"amazon-670k": AMAZON_670K, "delicious-200k": DELICIOUS_200K}[which]
    return make_xml_dataset(
        n_samples=n_samples,
        n_features=max(256, int(spec["n_features"] * scale)),
        n_classes=max(64, int(spec["n_classes"] * scale)),
        avg_nnz=min(spec["avg_nnz"], 128),
        avg_labels=min(spec["avg_labels"], 16),
        seed=seed,
    )
