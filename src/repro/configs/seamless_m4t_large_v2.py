"""--arch seamless-m4t-large-v2 (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["seamless-m4t-large-v2"]
