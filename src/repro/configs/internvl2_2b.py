"""--arch internvl2-2b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["internvl2-2b"]
