"""--arch stablelm-1.6b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["stablelm-1.6b"]
