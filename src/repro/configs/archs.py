"""The 10 assigned architectures (exact specs from the assignment table) +
the paper's own XML-MLP workload configs.

Every entry cites its source. ``ARCHS[name]`` is the full production config;
``ARCHS[name].reduced()`` is the CPU smoke variant. Per-arch modules
(src/repro/configs/<id>.py) re-export these for --arch selection.
"""
from __future__ import annotations

from .base import ModelConfig

ARCHS: dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# -- hybrid: Mamba+attention 1:7 interleave, MoE every 2nd layer ------------
JAMBA_1_5_LARGE = _register(ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_period=8,      # 1 attention layer per 8 (1:7 mamba:attn interleave)
    attn_offset=4,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    replica_axis="pod",  # 398B: replica = a full pod (FSDP+EP inside)
    expert_parallel=True,
    fsdp=True,
    source="[arXiv:2403.19887]",
))

# -- audio enc-dec: transformer backbone only; conformer frontend stubbed ---
SEAMLESS_M4T_LARGE_V2 = _register(ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,          # decoder layers
    encoder_layers=24,    # text/unit encoder over stub audio embeddings
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    frontend_len=1152,    # precomputed speech frame embeddings (stub)
    frontend_dim=1024,
    source="[arXiv:2308.11596]",
))

# -- dense small llama2 ------------------------------------------------------
TINYLLAMA_1_1B = _register(ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    source="[arXiv:2401.02385]",
))

# -- moe: 128 experts top-2 with parallel dense residual branch -------------
ARCTIC_480B = _register(ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    dense_residual_ff=4864,
    replica_axis="pod",
    expert_parallel=True,
    fsdp=True,
    source="[hf:Snowflake/snowflake-arctic-base]",
))

# -- dense (MHA: kv == heads) -------------------------------------------------
STABLELM_1_6B = _register(ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    source="[hf:stabilityai/stablelm-2-1_6b]",
))

# -- vlm: InternViT frontend stubbed; InternLM2 backbone ---------------------
INTERNVL2_2B = _register(ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_len=256,     # 448px tile -> 256 patch embeddings after pixel shuffle
    frontend_dim=1024,    # InternViT-300M width, projected to d_model
    source="[arXiv:2404.16821]",
))

# -- ssm: attention-free Mamba2 / SSD ----------------------------------------
MAMBA2_780M = _register(ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,               # attn-free, no separate FFN (Mamba2 block only)
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
    source="[arXiv:2405.21060]",
))

# -- dense small llama3 -------------------------------------------------------
LLAMA3_2_1B = _register(ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    source="[hf:meta-llama/Llama-3.2-1B]",
))

# -- fine-grained MoE (Moonlight) ---------------------------------------------
MOONSHOT_V1_16B_A3B = _register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    n_dense_layers=1,     # moonlight: first layer dense
    source="[hf:moonshotai/Moonlight-16B-A3B]",
))

# -- trillion-param MoE (paper-table scale) -----------------------------------
KIMI_K2_1T_A32B = _register(ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    n_dense_layers=1,
    replica_axis="pod",
    expert_parallel=True,
    fsdp=True,
    source="[arXiv:2501.kimi2]",
))


# -- the paper's own workloads (XML MLP over sparse data) --------------------
XML_WORKLOADS = {
    "xml-amazon-670k": dict(dataset="amazon-670k", hidden=128),
    "xml-delicious-200k": dict(dataset="delicious-200k", hidden=128),
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]


ARCH_IDS = list(ARCHS.keys())
