"""--arch moonshot-v1-16b-a3b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["moonshot-v1-16b-a3b"]
