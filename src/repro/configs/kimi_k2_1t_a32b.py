"""--arch kimi-k2-1t-a32b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["kimi-k2-1t-a32b"]
