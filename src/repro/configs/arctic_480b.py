"""--arch arctic-480b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["arctic-480b"]
