"""--arch jamba-1.5-large-398b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["jamba-1.5-large-398b"]
