"""Configuration system.

Three dataclasses compose the full experiment description:

* ``ModelConfig``   — architecture (one per assigned arch + the paper's MLP)
* ``ElasticConfig`` — the paper's Adaptive SGD hyperparameters (Alg. 1 + 2)
* ``RunConfig``     — batch/seq/step/lr bundle for a run

``INPUT_SHAPES`` holds the four assigned (seq_len, global_batch, mode)
combinations used by the dry-run and roofline harness.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

# --------------------------------------------------------------------------
# Model architecture
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | encdec | vlm | audio | xml_mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # ---- MoE ----
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    n_dense_layers: int = 0     # first k layers use dense FFN (kimi-style)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dense_residual_ff: int = 0    # width of the parallel dense FFN
    router_aux_coef: float = 0.01
    moe_dispatch: str = "global"  # 'global' (baseline) | 'sharded' (§Perf)
    moe_combine_dtype: str = "f32"  # 'f32' (baseline) | 'bf16' (§Perf iter 2)
    moe_decode_gather: bool = False  # decode-time expert-gather FFN (§Perf)

    # ---- Pallas kernel routing (TPU; interpret-mode validated on CPU) ----
    use_flash_kernel: bool = False   # attention via kernels/flash_attention
    use_ssd_kernel: bool = False     # mamba2 SSD via kernels/ssd_scan
    use_gmm_kernel: bool = False     # MoE expert FFN via kernels/moe_gmm

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0        # hybrid: attention layer where (i % attn_period == attn_offset)
    attn_offset: int = 0

    # ---- attention ----
    head_dim: int = 0           # 0 => d_model // n_heads
    rope_theta: float = 10000.0
    sliding_window: int = 0     # 0 => full attention
    long_context_window: int = 8192  # window used for long_500k on full-attn archs

    # ---- encoder-decoder / frontends ----
    encoder_layers: int = 0     # >0 => enc-dec; n_layers counts decoder layers
    frontend: Optional[str] = None      # None | 'audio' | 'vision'
    frontend_len: int = 0       # number of precomputed frame/patch embeddings
    frontend_dim: int = 0       # embedding dim produced by the (stub) frontend

    # ---- numerics ----
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # 'full' | 'dots' (save matmul outputs, §Perf)
    logits_softcap: float = 0.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ---- sharding policy ----
    replica_axis: str = "data"  # 'data' (small archs) | 'pod' (huge archs)
    expert_parallel: bool = False  # shard experts over the data axis
    fsdp: bool = False             # shard non-expert params over the data axis

    # source citation for the assigned-arch table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for the sequence-mixing sublayer of layer i."""
        if self.arch_type == "ssm":
            return "ssm"
        if self.arch_type == "hybrid":
            return "attn" if (i % self.attn_period == self.attn_offset) else "ssm"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'dense' | 'moe' for the channel-mixing sublayer of layer i."""
        if self.n_experts == 0 or i < self.n_dense_layers:
            return "dense"
        return "moe" if (i % self.moe_every == self.moe_offset) else "dense"

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_dense_layers=min(self.n_dense_layers, 1),
            dense_residual_ff=min(self.dense_residual_ff, 512),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 32) if self.ssm_head_dim else 0,
            ssm_chunk=64,
            attn_period=min(self.attn_period, 2) if self.attn_period else 0,
            attn_offset=min(self.attn_offset, 1),
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            frontend_dim=min(self.frontend_dim, 256) if self.frontend_dim else 0,
            long_context_window=256,
            sliding_window=min(self.sliding_window, 128) if self.sliding_window else 0,
            dtype="float32",
        )


# --------------------------------------------------------------------------
# Adaptive SGD / elastic averaging hyperparameters (paper Alg. 1 + 2)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ElasticConfig:
    algorithm: str = "adaptive"  # any key in the core/algorithms registry
                                 # (built-ins: adaptive | elastic | sync |
                                 #  crossbow | single | delayed_sync)
    placement: str = "vmap"      # replica execution placement (DESIGN.md §5):
                                 #   'vmap'    — all replicas on one device,
                                 #               vectorized over the leading R
                                 #               dim (the differential oracle)
                                 #   'sharded' — R laid out over a 1-D
                                 #               'replica' device mesh via
                                 #               shard_map; merges/metrics are
                                 #               cross-device collectives
    n_replicas: int = 4
    mega_batch: int = 100        # batches between merges (paper default 100)
    b_max: int = 256             # max per-replica batch size (slots)
    b_min: int = 32              # paper: b_max / 8
    beta: float = 16.0           # paper: b_min / 2
    pert_thr: float = 0.10       # perturbation threshold (Alg. 2)
    delta: float = 0.10          # perturbation factor (Alg. 2)
    gamma: float = 0.90          # global-model momentum (Alg. 2)
    replica_axis: str = "data"
    # CROSSBOW-only: correction rate of local replica toward global average
    crossbow_correction: float = 0.1

    @staticmethod
    def from_bmax(b_max: int, **kw) -> "ElasticConfig":
        """Paper's default derivation: b_min = b_max/8, beta = b_min/2."""
        b_min = max(1, b_max // 8)
        return ElasticConfig(b_max=b_max, b_min=b_min, beta=b_min / 2, **kw)


@dataclass(frozen=True)
class RunConfig:
    seq_len: int = 4096
    global_batch: int = 256
    lr: float = 0.05
    steps: int = 100
    seed: int = 0
    mode: str = "train"  # train | prefill | decode
    warmup_megabatches: int = 0


# --------------------------------------------------------------------------
# Assigned input shapes (dry-run / roofline grid)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
