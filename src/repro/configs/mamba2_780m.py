"""--arch mamba2-780m (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["mamba2-780m"]
