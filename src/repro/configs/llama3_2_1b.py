"""--arch llama3.2-1b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["llama3.2-1b"]
