"""--arch tinyllama-1.1b (see archs.py for the cited spec)."""
from .archs import ARCHS

CONFIG = ARCHS["tinyllama-1.1b"]
