"""Partition rules: map every parameter / batch / cache leaf to a
PartitionSpec for the production mesh.

Two replica granularities (DESIGN.md §4):
  * replica_axis='data'  (small/mid archs): the elastic-replica dim R is
    sharded over `data`; tensor-parallel over `model`; no FSDP.
  * replica_axis='pod'   (jamba/arctic/kimi): R is sharded over `pod`
    (multi-pod only); within a replica params are FSDP/expert-parallel over
    `data` + TP over `model`.

Rules are *first-fit with divisibility*: each leaf has an ordered candidate
list of specs; the first whose sharded dims divide evenly is used (e.g.
GQA kv=8 heads cannot split over model=16 → the kv projection falls back to
FSDP-only, exactly like Megatron replicated-KV TP groups).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

#: mesh-axis name of the elastic-replica dimension under the 1-D replica
#: mesh used by the trainer's ``placement='sharded'`` mode (DESIGN.md §5).
REPLICA_AXIS = "replica"


def replica_mesh_size(n_replicas: int, n_devices: int) -> int:
    """Largest device count <= n_devices that divides ``n_replicas`` (each
    shard must own the same number of replicas for the collective merge to
    be a plain psum of equal-size partials)."""
    return next(d for d in range(min(n_replicas, n_devices), 0, -1)
                if n_replicas % d == 0)


def global_replica_devices() -> list:
    """All devices across every ``jax.distributed``-attached process, in a
    deterministic fleet order: sorted by ``(process_index, id)`` so each
    process's devices form one contiguous block and every process derives
    the identical list. This is the device list a multi-host (device-span)
    replica mesh is built from — slot block *p* of the replica dimension
    lands on process *p*'s accelerators.

    In a single-process run this is just ``jax.devices()`` reordered, so
    it is safe to call unconditionally.
    """
    return sorted(
        jax.devices(), key=lambda d: (d.process_index, d.id)
    )


def replica_mesh(n_replicas: int, devices=None) -> Mesh:
    """1-D ``(replica,)`` mesh for the sharded replica executor.

    On one device this degenerates to a size-1 mesh — the shard_map path
    still runs, with every collective a no-op, which is what the
    single-process parity tests exercise. Pass
    ``devices=global_replica_devices()`` after ``jax.distributed``
    initialization to span the mesh across processes (the jitted round
    body is SPMD already; only the device list changes).
    """
    devices = list(jax.devices() if devices is None else devices)
    n = replica_mesh_size(n_replicas, len(devices))
    return Mesh(np.asarray(devices[:n]), (REPLICA_AXIS,))


class ReplicaMeshPool:
    """Device pool for an *elastic* replica population (DESIGN.md §6).

    A membership change (``ElasticTrainer.resize``) may need a replica mesh
    of a different shard count — e.g. 4 replicas over 4 devices shrinking
    to 2 replicas over 2. The pool owns the candidate device list and hands
    out one mesh per shard count, returning the **same Mesh object** every
    time a count recurs: the trainer keys its shard_map executor cache by
    that mesh, so a resize back to a previously-seen population shape
    rebuilds no executors and triggers no recompilation (the §6
    zero-recompile contract). Shard counts are picked by
    ``replica_mesh_size`` — the largest device count dividing R — so every
    shard always owns an equal replica slice.

    Multi-host (device span): construct with
    ``ReplicaMeshPool(global_replica_devices())`` so every process builds
    meshes over the identical cross-process device list — required for the
    SPMD executors to agree on layout.
    """

    def __init__(self, devices=None):
        self.devices = list(jax.devices() if devices is None else devices)
        self._meshes: dict[int, Mesh] = {}

    def mesh_for(self, n_replicas: int) -> Mesh:
        n = replica_mesh_size(n_replicas, len(self.devices))
        mesh = self._meshes.get(n)
        if mesh is None:
            mesh = Mesh(np.asarray(self.devices[:n]), (REPLICA_AXIS,))
            self._meshes[n] = mesh
        return mesh

    def adopt(self, mesh: Mesh) -> None:
        """Seed the pool with an externally built mesh (e.g. the trainer's
        user-provided one) so that shard count reuses it verbatim."""
        self._meshes[int(mesh.shape[REPLICA_AXIS])] = mesh


def replica_spec(replica_dim: int = 0) -> P:
    """PartitionSpec sharding dimension ``replica_dim`` over REPLICA_AXIS.

    ``replica_dim=0`` fits state leaves (R, ...); ``replica_dim=1`` fits the
    scan engine's whole-plan batches (n_rounds, R, ...). Trailing dims stay
    unsharded (shard_map pads missing spec entries with None), so one spec
    serves every leaf of a pytree as a prefix spec.
    """
    return P(*([None] * replica_dim + [REPLICA_AXIS]))


def axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def first_fit(shape, candidates, mesh: Mesh) -> P:
    """First candidate spec whose sharded dims are all divisible."""
    for spec in candidates:
        ok = True
        for dim, ax in zip(shape, spec):
            if ax is None:
                continue
            if dim % axis_size(mesh, ax) != 0:
                ok = False
                break
        if ok:
            return P(*spec)
    return P()


class MeshAxes:
    """Resolved mesh-axis roles for one (cfg, mesh) pair."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.mesh = mesh
        self.tp = "model"
        multi_pod = "pod" in mesh.shape
        if cfg.replica_axis == "pod":
            self.replica = "pod" if multi_pod else None
            self.fsdp = "data" if cfg.fsdp else None
            self.ep = "data" if cfg.expert_parallel else None
            self.batch = "data"
        else:
            # elastic replicas over data (x pod in multi-pod mode)
            self.replica = ("pod", "data") if multi_pod else "data"
            self.fsdp = None
            self.ep = None
            self.batch = None

    @property
    def n_replicas(self) -> int:
        return axis_size(self.mesh, self.replica)

    def activation_rules(self) -> dict:
        """Logical-axis mapping consumed by sharding.annotate (training)."""
        return {
            "replica": self.replica,
            "batch": self.batch,
            "heads": self.tp,
            "ff": self.tp,
            "experts": self.ep if self.ep else self.tp,
        }

    def serve_rules(self) -> dict:
        """Serving has no replica dim: batch spans (pod?, data)."""
        multi_pod = "pod" in self.mesh.shape
        return {
            "replica": None,
            "batch": ("pod", "data") if multi_pod else "data",
            "heads": self.tp,
            "ff": self.tp,
            "experts": self.ep if self.ep else self.tp,
        }


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------


def _leaf_spec(path: tuple, shape: tuple, ax: MeshAxes, mesh: Mesh) -> P:
    keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = keys[-1]
    in_blocks = any(k.startswith("pos") for k in keys) or "layers" in keys
    # stacked scan groups carry a leading (G,) dim
    eff = shape[1:] if in_blocks else shape
    tp, fsdp, ep = ax.tp, ax.fsdp, ax.ep
    # expert-parallel and FSDP may share the same mesh axis ('data'); a
    # single PartitionSpec cannot repeat an axis, so experts win and the
    # expert weights' non-expert dims fall back to TP-only.
    fsdp_e = None if (ep is not None and ep == fsdp) else fsdp

    def fit(cands):
        spec = first_fit(eff, cands, mesh)
        return P(*((None,) + tuple(spec))) if in_blocks else spec

    if name == "table" or name == "lm_head":
        return fit([(tp, fsdp), (None, tp), (fsdp, None), ()])
    if name == "router":
        return fit([(fsdp, None), ()])
    if name in ("wq", "wk", "wv") and len(eff) == 3:
        return fit([(fsdp, tp, None), (fsdp, None, None), ()])
    if name == "wo" and len(eff) == 3:
        if "ffn" in keys:  # MoE expert out: (E, F, D)
            return fit([(ep, tp, fsdp_e), (ep, tp, None), (None, tp, None), ()])
        return fit([(tp, None, fsdp), (None, None, fsdp), ()])  # attn out
    if name in ("wi", "wg") and len(eff) == 3:  # MoE expert in: (E, D, F)
        return fit([(ep, fsdp_e, tp), (ep, None, tp), (None, None, tp), ()])
    if name in ("wi", "wg") and len(eff) == 2:  # dense MLP in: (D, F)
        return fit([(fsdp, tp), (None, tp), ()])
    if name == "wo" and len(eff) == 2:  # dense MLP out: (F, D)
        return fit([(tp, fsdp), (tp, None), ()])
    if name == "in_proj":
        return fit([(fsdp, tp), (None, tp), ()])
    if name == "out_proj":
        return fit([(tp, fsdp), (tp, None), ()])
    if name == "conv_w":
        return fit([(None, tp), ()])
    if name == "conv_b":
        return fit([(tp,), ()])
    if name in ("A_log", "D", "dt_bias"):
        return fit([(tp,), ()])
    if name == "frontend_proj":
        return fit([(None, tp), ()])
    # norms, biases, everything else: replicated
    return fit([()])


def param_specs(cfg: ModelConfig, params: PyTree, mesh: Mesh, with_replica_dim: bool = False) -> PyTree:
    """PartitionSpec tree for params (optionally with leading replica dim)."""
    ax = MeshAxes(cfg, mesh)

    def spec(path, leaf):
        s = _leaf_spec(path, leaf.shape if not with_replica_dim else leaf.shape[1:], ax, mesh)
        if with_replica_dim:
            return P(*((ax.replica,) + tuple(s)))
        return s

    return jax.tree_util.tree_map_with_path(spec, params)


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, batch: PyTree, mesh: Mesh) -> PyTree:
    """Batch leaves have layout (R, B, ...)."""
    ax = MeshAxes(cfg, mesh)

    def spec(path, leaf):
        extra = (None,) * (leaf.ndim - 2)
        return P(ax.replica, ax.batch, *extra)

    return jax.tree_util.tree_map_with_path(spec, batch)


def serve_specs(cfg: ModelConfig, tree: PyTree, mesh: Mesh) -> PyTree:
    """Serving has no replica dim: batch over (pod?, data), TP over model.

    Cache leaves: (B, S, Hkv, hd) / (B, K, C) / (B, H, P, N) — batch-shard
    first dim when divisible, then try TP on the head-ish dim.
    """
    multi_pod = "pod" in mesh.shape
    bat = ("pod", "data") if multi_pod else "data"
    tp = "model"

    def spec(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape = leaf.shape
        if keys and keys[-1] == "cur_len":
            return P()
        # scanned block caches carry a leading (n_groups,) dim
        grouped = any(k.startswith("pos") for k in keys)
        eff = shape[1:] if grouped else shape
        cands = []
        if len(eff) == 4:  # kv cache or ssm state (B, S, Hkv, hd)/(B,H,P,N)
            cands = [
                (bat, None, tp, None),
                (bat, None, None, None),
                (None, None, tp, None),
                (None, tp, None, None),
            ]
        elif len(eff) == 3:  # conv cache / frontend embeds (B, K, C)
            cands = [(bat, None, tp), (bat, None, None), (None, None, tp)]
        elif len(eff) == 2:  # tokens (B, S)
            cands = [(bat, None), (None, None)]
        elif len(eff) == 1:
            cands = [(bat,), (None,)]
        s = first_fit(eff, cands + [()], mesh)
        if grouped:
            return P(*((None,) + tuple(s)))
        return s

    return jax.tree_util.tree_map_with_path(spec, tree)


def to_named(tree_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
