"""Logical-axis sharding annotations.

Model code annotates activations with *logical* axis names; a sharding
context (installed by the launcher) maps them to mesh axes. Without a
context everything is a no-op, so the same model code runs in single-device
tests and under GSPMD.

Logical axes used across the zoo:
  replica   — elastic worker dim (paper's per-GPU model replicas)
  batch     — per-replica sample dim
  seq       — sequence dim
  embed     — d_model
  heads/kv_heads — attention heads
  ff        — MLP hidden
  vocab     — embedding/vocab rows
  experts   — MoE expert dim

Placement note (DESIGN.md §5): these annotations drive the *GSPMD* path
(launch/steps.py under a production mesh), where the compiler partitions a
single program. The trainer's ``placement='sharded'`` replica executor is
the *manual* path — shard_map already fixes every leaf's layout via the
replica-axis specs in sharding/rules.py, so no sharding context is
installed there and ``shard()`` stays a no-op inside its traced bodies;
``replica_rules()`` below is the mapping the GSPMD entry points use when
only the replica dim is laid out. That separation is also what keeps
elastic membership (DESIGN.md §6) simple: when ``ElasticTrainer.resize``
swaps the replica mesh between mega-batches there is no installed context
to invalidate — only the trainer's own executor cache keys on the mesh. A
GSPMD entry point using ``sharding_context`` with ``replica_rules()`` must
instead re-enter the context with the new mesh after a resize.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None, "rules": {}}


def set_context(mesh: Optional[Mesh], rules: Optional[dict]) -> None:
    _CTX["mesh"] = mesh
    _CTX["rules"] = dict(rules or {})


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: dict):
    old = (_CTX["mesh"], _CTX["rules"])
    set_context(mesh, rules)
    try:
        yield
    finally:
        set_context(*old)


def replica_rules() -> dict:
    """Logical-axis mapping for a replica-only (1-D) mesh: the elastic
    replica dim shards over REPLICA_AXIS, everything else is replicated.
    The GSPMD counterpart of the trainer's shard_map specs."""
    from repro.sharding.rules import REPLICA_AXIS

    return {"replica": REPLICA_AXIS, "batch": None, "heads": None,
            "ff": None, "experts": None}


def logical_to_spec(axes: tuple, rules: Optional[dict] = None) -> P:
    rules = _CTX["rules"] if rules is None else rules
    out = []
    for a in axes:
        if a is None:
            out.append(None)
            continue
        m = rules.get(a)
        out.append(m)  # may be None, a mesh axis name, or a tuple of them
    return P(*out)


def logical_axis_size(name: str) -> int:
    """Mesh extent of the logical axis ``name`` under the current context
    (1 when no mesh / unmapped). Used by shard-local MoE dispatch to pick
    its group count."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return 1
    ax = _CTX["rules"].get(name)
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        out = 1
        for a in ax:
            out *= int(mesh.shape[a])
        return out
    return int(mesh.shape[ax])


def shard(x: jax.Array, *axes) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o mesh).

    Safe under vmap: if the (traced) rank doesn't match the requested spec
    rank, the constraint is skipped rather than corrupting the program.
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    if x.ndim == len(axes) - 1 and axes[0] == "replica":
        axes = axes[1:]  # serving paths carry no replica dim
    if x.ndim != len(axes):
        return x
    spec = logical_to_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
