"""Checkpointing: crash-consistent npz store + async CheckpointManager.

Two layers (DESIGN.md §7):

* ``save``/``load`` — the dependency-free single-checkpoint format: one
  ``tensors.npz`` for the pytree leaves, one ``meta.json`` for metadata and
  treedef paths. **Atomic publish**: both files are written into a hidden
  temp sibling directory which is then ``os.replace``-d into place, so a
  reader (or a restart after SIGKILL) either sees a complete checkpoint or
  none at all — never ``meta.json`` next to a torn ``tensors.npz``. Load
  failures raise :class:`CheckpointError` with the failing path/key instead
  of a bare ``KeyError``/``FileNotFoundError``.
* :class:`CheckpointManager` — periodic async snapshots of a running
  trainer: every K mega-batches the state is materialized to host
  synchronously (crash consistency: the snapshot is immutable before the
  trainer mutates anything) and written by a background thread, with at
  most one write in flight and bounded retention of published checkpoints.

Production notes: on a real pod each host writes its addressable shards;
here (single host) that degenerates to a full save.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zipfile
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any
SEP = "/"

#: directory-name prefix of one published checkpoint (suffix = mega-batch
#: index); everything else inside a manager directory is ignored by
#: ``latest_checkpoint`` (in-flight ``.tmp-*`` dirs, stray files).
CKPT_PREFIX = "ckpt-"


class CheckpointError(Exception):
    """A checkpoint could not be read: missing directory/file, a torn or
    corrupt tensors archive, or a tree key absent from the store. The
    message always names the offending path (and key, where applicable) so
    a restore failure is actionable from the log alone."""


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# dtypes numpy's npz cannot round-trip (ml_dtypes extensions) are stored as
# same-width unsigned-int views with the true dtype recorded in metadata.
_SAFE_KINDS = "fiub?c"


def save(path: str, tree: PyTree, metadata: Optional[dict] = None) -> None:
    """Write one checkpoint directory atomically.

    Both files are staged in a ``.tmp-*`` sibling and published with
    ``os.replace`` — a crash mid-write leaves at most a stale temp dir
    (cleaned opportunistically by :class:`CheckpointManager`), never a
    directory with one good and one torn file. Overwriting an existing
    ``path`` moves the old version aside first, so a crash during an
    overwrite still leaves one complete checkpoint on disk.
    """
    path = os.path.abspath(path)
    parent = os.path.dirname(path)
    os.makedirs(parent, exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    enc = {}
    for k, arr in flat.items():
        if arr.dtype.kind not in _SAFE_KINDS:
            dtypes[k] = str(arr.dtype)
            enc[k] = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[
                arr.dtype.itemsize
            ])
        else:
            enc[k] = arr
    meta = dict(metadata or {})
    meta["_keys"] = sorted(flat.keys())
    meta["_dtypes"] = dtypes

    tmp = tempfile.mkdtemp(prefix=".tmp-" + os.path.basename(path) + "-",
                           dir=parent)
    try:
        np.savez(os.path.join(tmp, "tensors.npz"), **enc)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1, default=float)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(path):
            # os.replace cannot clobber a non-empty dir: retire the old
            # version first (it stays complete until the new one publishes)
            old = tempfile.mkdtemp(prefix=".tmp-old-", dir=parent)
            os.replace(path, os.path.join(old, "prev"))
            os.replace(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shape/dtype checked).

    Raises :class:`CheckpointError` when the checkpoint directory or either
    of its files is missing, the tensors archive is corrupt (torn write
    from a pre-atomic producer), or a leaf of ``like`` has no stored
    tensor. Shape mismatches still raise ``ValueError`` — the checkpoint
    itself is fine, the receiving tree is wrong.
    """
    meta = load_metadata(path)
    tensor_path = os.path.join(path, "tensors.npz")
    try:
        data = np.load(tensor_path)
    except FileNotFoundError:
        raise CheckpointError(
            f"checkpoint {path} has no tensors.npz"
        ) from None
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointError(
            f"checkpoint tensors are corrupt (torn write?): {tensor_path}: {e}"
        ) from e
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    stored_dtypes = meta.get("_dtypes", {})
    leaves = []
    for p, leaf in paths:
        key = SEP.join(_key_str(x) for x in p)
        try:
            arr = data[key]
        except KeyError:
            raise CheckpointError(
                f"checkpoint {path} is missing tensor {key!r} "
                f"(stored keys: {len(meta.get('_keys', []))})"
            ) from None
        if key in stored_dtypes:
            arr = arr.view(np.dtype(stored_dtypes[key]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def load_metadata(path: str) -> dict:
    meta_path = os.path.join(path, "meta.json")
    try:
        with open(meta_path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointError(
            f"no checkpoint at {path} (missing {meta_path})"
        ) from None
    except json.JSONDecodeError as e:
        raise CheckpointError(
            f"checkpoint metadata is corrupt: {meta_path}: {e}"
        ) from e


# --------------------------------------------------------------------------
# manager: periodic async snapshots with retention
# --------------------------------------------------------------------------


def checkpoint_index(name: str) -> Optional[int]:
    """Mega-batch index of a published checkpoint dir name, else None."""
    if not name.startswith(CKPT_PREFIX):
        return None
    try:
        return int(name[len(CKPT_PREFIX):])
    except ValueError:
        return None


def latest_checkpoint(directory: str) -> Optional[str]:
    """Path of the newest *complete* checkpoint under ``directory``.

    Atomic publish means a listed ``ckpt-*`` dir is complete iff its
    ``meta.json`` exists (both files land in one rename); in-flight
    ``.tmp-*`` staging dirs are never candidates. Returns None when the
    directory is missing or holds no checkpoint.
    """
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return None
    best, best_idx = None, -1
    for name in names:
        idx = checkpoint_index(name)
        if idx is None or idx <= best_idx:
            continue
        if os.path.isfile(os.path.join(directory, name, "meta.json")):
            best, best_idx = os.path.join(directory, name), idx
    return best


def resolve_checkpoint(path: str) -> str:
    """Accept either one checkpoint dir or a manager directory (-> latest)."""
    if os.path.isfile(os.path.join(path, "meta.json")):
        return path
    latest = latest_checkpoint(path)
    if latest is None:
        raise CheckpointError(f"no checkpoint found under {path}")
    return latest


class CheckpointManager:
    """Periodic crash-consistent snapshots of a running ``ElasticTrainer``.

    ``maybe_save(trainer, state)`` is called once per mega-batch (the
    trainer's ``run`` loop does this when a manager is passed); every
    ``every``-th mega-batch it

    1. **snapshots synchronously** — ``trainer.checkpoint_payload(state)``
       is materialized to host numpy *before* returning, so the copy can
       never observe a later mega-batch half-applied (the trainer mutates
       scheduler clocks / speed EMAs in place);
    2. **writes asynchronously** — a single background thread runs the
       atomic :func:`save` + retention sweep while training continues. At
       most one write is in flight (a new snapshot first joins the
       previous write, bounding host memory to two snapshots);
    3. **retains boundedly** — after each publish, all but the newest
       ``retain`` checkpoints (and any stale ``.tmp-*`` staging dirs) are
       deleted. The just-published checkpoint is never a deletion
       candidate, so the directory always holds at least one complete
       checkpoint once the first publish lands.

    A writer-thread failure is re-raised on the next ``maybe_save``/
    ``wait`` call — checkpointing errors must fail the run, not vanish
    into a daemon thread.

    Multi-host single-writer rule (DESIGN.md §10): every process builds
    the payload — under a host span ``checkpoint_payload`` contains a
    collective allgather, so all processes must call it on the identical
    interval — but only the manager constructed with ``publisher=True``
    (process 0 by convention) writes bytes to disk.
    """

    def __init__(self, directory: str, every: int = 1, retain: int = 3,
                 async_write: bool = True, publisher: bool = True):
        if every < 1:
            raise ValueError(f"checkpoint interval must be >= 1, got {every}")
        if retain < 1:
            raise ValueError(f"checkpoint retention must be >= 1, got {retain}")
        self.directory = os.path.abspath(directory)
        self.every = int(every)
        self.retain = int(retain)
        self.async_write = bool(async_write)
        self.publisher = bool(publisher)
        self._thread: Optional[threading.Thread] = None
        # guards _error only: it is the one attribute both the writer
        # thread and the host thread touch (everything else is host-only)
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._last_saved: Optional[int] = None

    # ---- saving ----
    def step_path(self, megabatch_idx: int) -> str:
        return os.path.join(self.directory, f"{CKPT_PREFIX}{megabatch_idx:06d}")

    def maybe_save(self, trainer, state, force: bool = False) -> Optional[str]:
        """Snapshot ``state`` if it sits on the checkpoint interval.

        Returns the (future) checkpoint path when a save was scheduled,
        else None. ``state.megabatch_idx`` keys the interval — the trainer
        calls this after each mega-batch, so index k means "k mega-batches
        completed"."""
        idx = int(state.megabatch_idx)
        if not force and (idx % self.every != 0 or idx == self._last_saved
                          or idx == 0):
            return None
        self._reraise()
        tree, meta = trainer.checkpoint_payload(state)
        # recorded before the publisher gate so repeat calls at the same
        # index dedupe identically on every process (exchange lockstep)
        self._last_saved = idx
        if not self.publisher:
            # non-publishing process: the payload call above kept us in
            # exchange lockstep with the writer; nothing touches disk
            return None
        # host-materialize NOW: np.array copies device buffers and the
        # trainer's mutable host arrays (b/lr/clock) alike, so the write
        # job owns an immutable snapshot
        snapshot = jax.tree_util.tree_map(lambda l: np.array(l), tree)
        path = self.step_path(idx)
        if self.async_write:
            self.wait()           # <= one write in flight
            self._thread = threading.Thread(
                target=self._write_job, args=(path, snapshot, meta),
                name="checkpoint-writer", daemon=True,
            )
            self._thread.start()
        else:
            self._write_job(path, snapshot, meta)
            self._reraise()
        return path

    def _write_job(self, path: str, snapshot, meta: dict) -> None:
        try:
            save(path, snapshot, metadata=meta)
            self._sweep_retention(keep_path=path)
        except BaseException as e:  # surfaced on the next host-thread call
            with self._lock:
                self._error = e

    def wait(self) -> None:
        """Block until the in-flight write (if any) has published."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._reraise()

    def _reraise(self) -> None:
        # check-and-clear must be atomic: two callers racing through a bare
        # `if self._error` could both claim (or double-raise) one failure
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise CheckpointError(f"background checkpoint write failed: {err}") from err

    def _sweep_retention(self, keep_path: str) -> None:
        entries = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith(".tmp-") and full != keep_path:
                shutil.rmtree(full, ignore_errors=True)  # stale staging dir
                continue
            idx = checkpoint_index(name)
            if idx is not None and full != keep_path:
                entries.append((idx, full))
        entries.sort(reverse=True)
        for _, full in entries[self.retain - 1:]:  # keep_path counts as one
            shutil.rmtree(full, ignore_errors=True)

    # ---- restoring ----
    def latest(self) -> Optional[str]:
        return latest_checkpoint(self.directory)

    def restore(self, trainer, path: Optional[str] = None):
        """Restore an ``ElasticState`` into ``trainer`` from ``path`` (or
        the newest checkpoint under this manager's directory)."""
        if path is None:
            path = self.latest()
            if path is None:
                raise CheckpointError(
                    f"no checkpoint found under {self.directory}"
                )
        return trainer.restore_checkpoint(path)
