"""Checkpointing: flat-path npz store for arbitrary pytrees + host metadata.

Production notes: on a real pod each host writes its addressable shards
(`save_sharded`); here (single host) that degenerates to a full save. The
format is dependency-free: one .npz for tensors, one .json for metadata and
treedef paths.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# dtypes numpy's npz cannot round-trip (ml_dtypes extensions) are stored as
# same-width unsigned-int views with the true dtype recorded in metadata.
_SAFE_KINDS = "fiub?c"


def save(path: str, tree: PyTree, metadata: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    dtypes = {}
    enc = {}
    for k, arr in flat.items():
        if arr.dtype.kind not in _SAFE_KINDS:
            dtypes[k] = str(arr.dtype)
            enc[k] = arr.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[
                arr.dtype.itemsize
            ])
        else:
            enc[k] = arr
    np.savez(os.path.join(path, "tensors.npz"), **enc)
    meta = dict(metadata or {})
    meta["_keys"] = sorted(flat.keys())
    meta["_dtypes"] = dtypes
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1, default=float)


def load(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    data = np.load(os.path.join(path, "tensors.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    stored_dtypes = meta.get("_dtypes", {})
    leaves = []
    for p, leaf in paths:
        key = SEP.join(_key_str(x) for x in p)
        arr = data[key]
        if key in stored_dtypes:
            arr = arr.view(np.dtype(stored_dtypes[key]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def load_metadata(path: str) -> dict:
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)
