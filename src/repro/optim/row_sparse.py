"""Row-sparse gradient representation for embedding-style parameters.

The XML input layer touches only the ~B*K embedding rows gathered by a
batch, so its gradient is row-sparse: ``RowSparseGrad`` carries the touched
``rows`` and the per-slot row gradients ``vals`` as an *unreduced* padded
COO — duplicates allowed (two nnz slots hitting the same row stay two
entries; scatter-add reduces them), static shapes everywhere so the value
survives ``vmap`` over replicas and ``jax.lax.scan`` over rounds. Slots
whose row id is >= ``n_rows`` are padding sentinels: JAX drops out-of-bound
scatter updates, so they vanish without a select.

This is the device-side half of the paper's sparsity story (DESIGN.md §3):
the backward produces O(B*K*H) values instead of a dense (NF, H) gradient,
and the optimizer (optim/sgd.py) scatters only the touched rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class RowSparseGrad:
    """Gradient of a (..., n_rows, H) parameter, touched rows only.

    rows: (..., S) int32 — row ids; >= n_rows marks a padded/masked slot.
    vals: (..., S, H)    — per-slot row gradient (unreduced; duplicates add).
    n_rows: static int   — the dense row count NF.

    Leading dims (replica, scan, ...) broadcast with the parameter's.
    """

    rows: jax.Array
    vals: jax.Array
    n_rows: int

    def tree_flatten(self):
        return (self.rows, self.vals), self.n_rows

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def densify(self) -> jax.Array:
        """Scatter-add into a dense (..., n_rows, H) f32 array (the oracle
        form; also used for cross-replica gradient averaging in sync)."""

        def one(rows, vals):
            h = vals.shape[-1]
            return (
                jnp.zeros((self.n_rows, h), jnp.float32)
                .at[rows]
                .add(vals.astype(jnp.float32))
            )

        fn = one
        for _ in range(self.rows.ndim - 1):
            fn = jax.vmap(fn)
        return fn(self.rows, self.vals)


def is_row_sparse(x) -> bool:
    return isinstance(x, RowSparseGrad)


def densify_tree(grads: PyTree) -> PyTree:
    """Replace every RowSparseGrad leaf with its dense scatter-add."""
    return jax.tree_util.tree_map(
        lambda g: g.densify() if is_row_sparse(g) else g,
        grads,
        is_leaf=is_row_sparse,
    )


def first_occurrence(rows: jax.Array, n_rows: int) -> jax.Array:
    """(S,) f32: 1.0 at the first slot of each distinct in-bounds row id.

    Per-row-once weights for the lazy weight-decay/momentum terms: with
    duplicates, gather-modify-scatter would apply a per-row term once per
    *slot*; multiplying by this mask applies it once per *row*. Sentinel
    (out-of-bounds) slots get 0.
    """
    order = jnp.argsort(rows)
    sorted_rows = rows[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_rows[1:] != sorted_rows[:-1]]
    )
    first = jnp.zeros(rows.shape, jnp.float32).at[order].set(
        first_sorted.astype(jnp.float32)
    )
    return first * (rows < n_rows)
