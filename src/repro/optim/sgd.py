"""SGD optimizer family used by the paper's local updates.

The paper's workers run plain mini-batch SGD locally (momentum lives at the
*global model* level inside Algorithm 2, not in the local update). We still
provide optional local momentum and weight decay for the production LM
configs. The API mirrors optax (init/update) but is replica-aware: the
learning rate may be a vector of shape (R,) broadcast against leaves with a
leading replica dimension — this is how the paper's *per-GPU learning rate*
(linear-scaling rule, Alg. 1 lines 4/7) is expressed on an SPMD machine.

Row-sparse gradients: a grad leaf may be a ``RowSparseGrad``
(optim/row_sparse.py) for a (..., NF, H) parameter; ``sgd_update`` then
scatters only the touched rows — O(S*H) instead of O(NF*H) — preserving
masked-lockstep and the per-replica lr broadcast. Semantics (DESIGN.md §3):

* plain SGD (momentum=0, weight_decay=0) is bit-comparable to densifying
  the gradient and running the dense update;
* weight decay is applied *lazily*: touched rows decay (exactly once per
  row, duplicates handled), untouched rows are not decayed that step;
* momentum is *lazy*: touched rows get the exact dense rule
  ``m' = mu*m + g``, untouched rows keep their momentum unchanged (dense
  SGD would decay it by ``mu`` and keep drifting the parameter);
* grad_clip densifies sparse leaves first (the global norm needs the
  duplicate-reduced gradient), so clipped configs pay the dense cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim.row_sparse import (
    RowSparseGrad,
    densify_tree,
    first_occurrence,
    is_row_sparse,
)

PyTree = Any


@dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.0
    nesterov: bool = False
    weight_decay: float = 0.0
    grad_clip: float = 0.0  # 0 = off; global-norm clip per replica


def init_momentum(params: PyTree, cfg: SGDConfig) -> Optional[PyTree]:
    if cfg.momentum == 0.0:
        return None
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def _broadcast_lr(lr, leaf):
    """lr may be scalar or (R,) matching the leaf's leading replica dim."""
    lr = jnp.asarray(lr, jnp.float32)
    if lr.ndim == 0:
        return lr
    return lr.reshape((-1,) + (1,) * (leaf.ndim - 1))


def clip_by_global_norm(grads: PyTree, max_norm: float, replica_dim: bool) -> PyTree:
    if max_norm <= 0.0:
        return grads
    leaves = jax.tree_util.tree_leaves(grads)
    if replica_dim:
        sq = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
            for l in leaves
        )
        norm = jnp.sqrt(sq)  # (R,)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree_util.tree_map(
            lambda l: (l.astype(jnp.float32) * scale.reshape((-1,) + (1,) * (l.ndim - 1))).astype(l.dtype),
            grads,
        )
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), grads)


# --------------------------------------------------------------------------
# per-leaf update rules
# --------------------------------------------------------------------------


def _dense_leaf_update(p, g, m, lr, cfg: SGDConfig, update_mask):
    """The original dense rule: wd -> momentum -> masked step."""
    if cfg.weight_decay:
        g = g + cfg.weight_decay * p.astype(g.dtype)
    new_m = None
    if m is not None:
        new_m = cfg.momentum * m + g.astype(m.dtype)
        g = g + cfg.momentum * new_m if cfg.nesterov else new_m
    lr_b = _broadcast_lr(lr, p)
    delta = lr_b * g.astype(jnp.float32)
    if update_mask is not None:
        delta = delta * update_mask.reshape((-1,) + (1,) * (p.ndim - 1))
    new_p = (p.astype(jnp.float32) - delta).astype(p.dtype)
    if new_m is not None and update_mask is not None:
        # frozen replicas must not accumulate momentum either
        new_m = jnp.where(
            update_mask.reshape((-1,) + (1,) * (new_m.ndim - 1)) > 0, new_m, m
        )
    return new_p, new_m


def _sparse_leaf_update(p, g: RowSparseGrad, m, lr, cfg: SGDConfig,
                        update_mask, replica_dim: bool):
    """Scatter-only update for a RowSparseGrad leaf (see module docstring).

    Out-of-bounds sentinel rows are dropped by the scatters; gathers at
    those slots clamp, but every gathered term is weighted by the
    ``first_occurrence`` mask, which is 0 there.
    """
    n_rows = g.n_rows
    lr_arr = jnp.asarray(lr, jnp.float32)

    def one(p1, rows, vals, m1, lr1, mk):
        vals = vals.astype(jnp.float32)
        first = None
        if cfg.weight_decay or m1 is not None:
            first = first_occurrence(rows, n_rows)[:, None]
        if cfg.weight_decay:  # lazy decay: touched rows, exactly once per row
            vals = vals + cfg.weight_decay * first * p1[rows].astype(jnp.float32)
        if m1 is not None:
            m32 = m1.astype(jnp.float32)
            # touched rows: m' = mu*m + sum(vals); mk=0 adds 0 (frozen)
            m_new = m32.at[rows].add(
                mk * ((cfg.momentum - 1.0) * first * m32[rows] + vals)
            )
            if cfg.nesterov:
                slot_delta = vals + cfg.momentum * first * m_new[rows]
            else:
                slot_delta = first * m_new[rows]
            new_m1 = m_new.astype(m1.dtype)
        else:
            slot_delta, new_m1 = vals, None
        new_p1 = p1.at[rows].add((-(lr1 * mk) * slot_delta).astype(p1.dtype))
        return new_p1, new_m1

    if not replica_dim:
        return one(p, g.rows, g.vals, m, lr_arr, 1.0)

    mask_arr = (
        jnp.ones(p.shape[0], jnp.float32)
        if update_mask is None
        else jnp.asarray(update_mask, jnp.float32)
    )
    lr_ax = 0 if lr_arr.ndim else None
    if m is None:
        mapped = jax.vmap(
            lambda p1, r1, v1, l1, k1: one(p1, r1, v1, None, l1, k1),
            in_axes=(0, 0, 0, lr_ax, 0),
        )
        new_p, _ = mapped(p, g.rows, g.vals, lr_arr, mask_arr)
        return new_p, None
    return jax.vmap(one, in_axes=(0, 0, 0, 0, lr_ax, 0))(
        p, g.rows, g.vals, m, lr_arr, mask_arr
    )


def sgd_update(
    params: PyTree,
    grads: PyTree,
    lr,
    cfg: SGDConfig = SGDConfig(),
    momentum_state: Optional[PyTree] = None,
    update_mask=None,
    replica_dim: bool = False,
):
    """One SGD step.

    ``update_mask`` — optional (R,) 0/1 vector implementing the *masked
    lockstep round*: replicas whose virtual clock has passed the mega-batch
    horizon keep their parameters unchanged (see core/scheduler.py).
    ``grads`` leaves may be RowSparseGrad (see module docstring).
    Returns (new_params, new_momentum_state).
    """
    if cfg.grad_clip > 0.0:
        grads = densify_tree(grads)  # clip norm needs the reduced gradient
        grads = clip_by_global_norm(grads, cfg.grad_clip, replica_dim)

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = (
        treedef.flatten_up_to(momentum_state)
        if momentum_state is not None
        else [None] * len(p_leaves)
    )
    if update_mask is not None:
        update_mask = jnp.asarray(update_mask, jnp.float32)

    new_p, new_m = [], []
    for p, g, m in zip(p_leaves, g_leaves, m_leaves):
        if is_row_sparse(g):
            np_, nm_ = _sparse_leaf_update(
                p, g, m, lr, cfg, update_mask, replica_dim
            )
        else:
            np_, nm_ = _dense_leaf_update(p, g, m, lr, cfg, update_mask)
        new_p.append(np_)
        new_m.append(nm_)

    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_momentum = (
        jax.tree_util.tree_unflatten(treedef, new_m)
        if momentum_state is not None
        else None
    )
    return new_params, new_momentum
