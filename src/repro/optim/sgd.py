"""SGD optimizer family used by the paper's local updates.

The paper's workers run plain mini-batch SGD locally (momentum lives at the
*global model* level inside Algorithm 2, not in the local update). We still
provide optional local momentum and weight decay for the production LM
configs. The API mirrors optax (init/update) but is replica-aware: the
learning rate may be a vector of shape (R,) broadcast against leaves with a
leading replica dimension — this is how the paper's *per-GPU learning rate*
(linear-scaling rule, Alg. 1 lines 4/7) is expressed on an SPMD machine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.0
    nesterov: bool = False
    weight_decay: float = 0.0
    grad_clip: float = 0.0  # 0 = off; global-norm clip per replica


def init_momentum(params: PyTree, cfg: SGDConfig) -> Optional[PyTree]:
    if cfg.momentum == 0.0:
        return None
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def _broadcast_lr(lr, leaf):
    """lr may be scalar or (R,) matching the leaf's leading replica dim."""
    lr = jnp.asarray(lr, jnp.float32)
    if lr.ndim == 0:
        return lr
    return lr.reshape((-1,) + (1,) * (leaf.ndim - 1))


def clip_by_global_norm(grads: PyTree, max_norm: float, replica_dim: bool) -> PyTree:
    if max_norm <= 0.0:
        return grads
    leaves = jax.tree_util.tree_leaves(grads)
    if replica_dim:
        sq = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
            for l in leaves
        )
        norm = jnp.sqrt(sq)  # (R,)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree_util.tree_map(
            lambda l: (l.astype(jnp.float32) * scale.reshape((-1,) + (1,) * (l.ndim - 1))).astype(l.dtype),
            grads,
        )
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), grads)


def sgd_update(
    params: PyTree,
    grads: PyTree,
    lr,
    cfg: SGDConfig = SGDConfig(),
    momentum_state: Optional[PyTree] = None,
    update_mask=None,
    replica_dim: bool = False,
):
    """One SGD step.

    ``update_mask`` — optional (R,) 0/1 vector implementing the *masked
    lockstep round*: replicas whose virtual clock has passed the mega-batch
    horizon keep their parameters unchanged (see core/scheduler.py).
    Returns (new_params, new_momentum_state).
    """
    grads = clip_by_global_norm(grads, cfg.grad_clip, replica_dim)

    if cfg.weight_decay:
        grads = jax.tree_util.tree_map(
            lambda g, p: g + cfg.weight_decay * p.astype(g.dtype), grads, params
        )

    new_m = None
    if momentum_state is not None:
        new_m = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g.astype(m.dtype), momentum_state, grads
        )
        if cfg.nesterov:
            grads = jax.tree_util.tree_map(
                lambda g, m: g + cfg.momentum * m, grads, new_m
            )
        else:
            grads = new_m

    def step(p, g):
        lr_b = _broadcast_lr(lr, p)
        delta = lr_b * g.astype(jnp.float32)
        if update_mask is not None:
            delta = delta * update_mask.reshape((-1,) + (1,) * (p.ndim - 1))
        return (p.astype(jnp.float32) - delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(step, params, grads)
    if new_m is not None and update_mask is not None:
        # frozen replicas must not accumulate momentum either
        new_m = jax.tree_util.tree_map(
            lambda nm, om: jnp.where(
                update_mask.reshape((-1,) + (1,) * (nm.ndim - 1)) > 0, nm, om
            ),
            new_m,
            momentum_state,
        )
    return new_params, new_m
