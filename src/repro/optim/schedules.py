"""Learning-rate schedules: the linear-scaling rule and warmup.

The paper (following Goyal et al. [19]) couples batch size and learning rate
linearly: when Algorithm 1 rescales ``b_i -> b_i'`` it applies
``lr_i <- lr_i * b_i'/b_i``. Warmup addresses the instability of large
initial rates. Both are host-side scalar functions (they feed the per-replica
lr vector passed into sgd_update).
"""
from __future__ import annotations

import numpy as np


def linear_scaled_lr(base_lr: float, base_batch: int, batch) -> np.ndarray:
    """lr for batch size(s) ``batch`` given a reference (base_lr, base_batch)."""
    return np.asarray(base_lr, np.float64) * np.asarray(batch, np.float64) / base_batch


def rescale_lr(lr, old_batch, new_batch) -> np.ndarray:
    """Algorithm 1 lines 4/7: lr' = lr * b'/b (elementwise)."""
    old = np.maximum(np.asarray(old_batch, np.float64), 1.0)
    return np.asarray(lr, np.float64) * np.asarray(new_batch, np.float64) / old


def warmup_factor(step: int, warmup_steps: int) -> float:
    """Linear warmup from 1/warmup to 1.0 over warmup_steps (paper's warmup)."""
    if warmup_steps <= 0 or step >= warmup_steps:
        return 1.0
    return (step + 1) / warmup_steps


def cosine_decay(step: int, total: int, floor: float = 0.1) -> float:
    if total <= 0:
        return 1.0
    t = min(step, total) / total
    return floor + (1 - floor) * 0.5 * (1 + np.cos(np.pi * t))
