"""Mamba2 (SSD — state-space duality) blocks [arXiv:2405.21060].

Chunked SSD for training/prefill (quadratic within chunks, linear across),
O(1)-state recurrent step for decode. Depthwise causal conv on the (x, B, C)
stream, gated RMSNorm output, per-head scalar A.

Layout: d_inner = expand * d_model, H = d_inner // head_dim heads,
state size N, single B/C group (G=1, broadcast over heads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ninit, rmsnorm, split_keys


def init_mamba2(
    key, d_model: int, *, expand: int, head_dim: int, state: int, conv: int, dtype
):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    d_conv_in = d_inner + 2 * state  # conv runs over [x, B, C]
    k1, k2, k3, k4 = split_keys(key, 4)
    return {
        "in_proj": ninit(
            k1, (d_model, 2 * d_inner + 2 * state + n_heads), d_model ** -0.5, dtype
        ),
        "conv_w": ninit(k2, (conv, d_conv_in), conv ** -0.5, dtype),
        "conv_b": jnp.zeros((d_conv_in,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01, jnp.float32))),
        "gate_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": ninit(k3, (d_inner, d_model), d_inner ** -0.5, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,       # (B, L, H, P)  — already dt-discretized (x * dt)
    dA: jax.Array,      # (B, L, H)     — dt * A  (negative)
    Bm: jax.Array,      # (B, L, H, N)
    Cm: jax.Array,      # (B, L, H, N)
    chunk: int,
    initial_state=None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xr = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    br = Bm.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    cr = Cm.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    a = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2).astype(jnp.float32)  # (B,H,nc,c)
    a_cs = jnp.cumsum(a, axis=-1)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(a))  # (B,H,nc,c,c)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", cr, br, L, xr)

    # 2) chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)  # (B,H,nc,c)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", br, decay_states, xr)

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])  # (B,H,nc)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final, prev_states = jax.lax.scan(
        step,
        initial_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4) inter-chunk contribution to outputs
    state_decay_out = jnp.exp(a_cs)  # (B,H,nc,c)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cr, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return out + b


def mamba2_forward(
    params: dict,
    x: jax.Array,
    *,
    head_dim: int,
    state: int,
    chunk: int,
    norm_eps: float = 1e-5,
    sample_mask=None,
    use_kernel: bool = False,
) -> jax.Array:
    """Pre-norm Mamba2 block: x + ssd(norm(x)). x: (B, L, D)."""
    b, l, d = x.shape
    h_in = rmsnorm(x, params["norm"], norm_eps)
    zxbcdt = jnp.einsum("bld,de->ble", h_in, params["in_proj"])
    n_heads = params["A_log"].shape[0]
    d_inner = n_heads * head_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    xs = xs.reshape(b, l, n_heads, head_dim)
    bm = jnp.broadcast_to(bm[:, :, None, :], (b, l, n_heads, state))
    cm = jnp.broadcast_to(cm[:, :, None, :], (b, l, n_heads, state))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    a = -jnp.exp(params["A_log"])  # (H,)
    pad = (-l) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    if use_kernel:
        from repro.kernels.ssd_scan.ops import ssd_scan

        y, _ = ssd_scan(
            xs.astype(jnp.float32) * dt[..., None], dt * a, bm, cm, chunk=chunk
        )
    else:
        y, _ = ssd_chunked(
            xs.astype(jnp.float32) * dt[..., None], dt * a, bm, cm, chunk
        )
    y = y[:, :l]
    xs = xs[:, :l]
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    y = rmsnorm(y, params["gate_norm"], norm_eps) * jax.nn.silu(z)
    return x + jnp.einsum("ble,ed->bld", y, params["out_proj"])


# --------------------------------------------------------------------------
# decode (recurrent) path
# --------------------------------------------------------------------------


def mamba2_init_cache(batch: int, params: dict, *, head_dim: int, state: int, dtype):
    n_heads = params["A_log"].shape[0]
    d_inner = n_heads * head_dim
    k = params["conv_w"].shape[0]
    return {
        "conv": jnp.zeros((batch, k - 1, d_inner + 2 * state), dtype),
        "ssm": jnp.zeros((batch, n_heads, head_dim, state), jnp.float32),
    }


def mamba2_decode_step(
    params: dict,
    x: jax.Array,           # (B, 1, D)
    cache: dict,
    *,
    head_dim: int,
    state: int,
    norm_eps: float = 1e-5,
) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    n_heads = params["A_log"].shape[0]
    d_inner = n_heads * head_dim
    h_in = rmsnorm(x, params["norm"], norm_eps)
    zxbcdt = jnp.einsum("bld,de->ble", h_in, params["in_proj"])[:, 0]  # (B, E)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)

    # rolling conv buffer
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"]  # (K, C)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w) + params["conv_b"])
    new_conv = conv_in[:, 1:]

    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    xs = xs.reshape(b, n_heads, head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt * a)  # (B,H)
    bx = jnp.einsum("bhp,bn->bhpn", xs * dt[..., None], bm.astype(jnp.float32))
    new_ssm = cache["ssm"] * da[..., None, None] + bx
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, cm.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = rmsnorm(y, params["gate_norm"], norm_eps) * jax.nn.silu(z)
    out = x + jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, {"conv": new_conv.astype(cache["conv"].dtype), "ssm": new_ssm}
