"""The typed model protocol consumed by the training engine.

A *trainable model* is the trainer-facing bundle every workload exports
(``models/xml_mlp.py``, ``models/model.py``): pure functions, no trainer
coupling. ``ElasticTrainer`` accepts a ``TrainableModel`` (or, for
backward compatibility, the legacy ``{'init': ..., 'loss_fn': ...}`` dict,
coerced via ``as_trainable_model``).

Contract:

* ``init(rng) -> params`` — build a parameter pytree.
* ``loss_fn(params, batch) -> (loss, aux)`` — aux must contain
  ``accuracy`` and ``n_valid``; differentiable (the dense-autodiff path
  runs ``jax.value_and_grad`` over it).
* ``sparse_grad_fn(params, batch) -> ((loss, aux), grads)`` — optional
  fused loss+gradient with the ``value_and_grad`` calling convention;
  grad leaves may be ``RowSparseGrad`` (DESIGN.md §3). None = the model
  has no sparse path and the trainer always uses dense autodiff.
* ``config`` — the model's own config object (opaque to the trainer).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

PyTree = Any


@dataclass(frozen=True)
class TrainableModel:
    init: Callable[[Any], PyTree]
    loss_fn: Callable[[PyTree, dict], tuple]
    sparse_grad_fn: Optional[Callable[[PyTree, dict], tuple]] = None
    config: Any = None

    # ---- legacy dict-style access (pre-protocol call sites) ----
    def __getitem__(self, key):
        val = getattr(self, key, None) if isinstance(key, str) else None
        if val is None:
            raise KeyError(key)
        return val

    def __contains__(self, key) -> bool:
        return (
            isinstance(key, str)
            and getattr(self, key, None) is not None
        )

    def get(self, key: str, default=None):
        val = getattr(self, key, None)
        return default if val is None else val


def as_trainable_model(model) -> TrainableModel:
    """Coerce the legacy model dict (or pass through a TrainableModel)."""
    if isinstance(model, TrainableModel):
        return model
    if isinstance(model, dict):
        return TrainableModel(
            init=model["init"],
            loss_fn=model["loss_fn"],
            sparse_grad_fn=model.get("sparse_grad_fn"),
            config=model.get("config"),
        )
    raise TypeError(
        f"expected TrainableModel or legacy model dict, got {type(model).__name__}"
    )
