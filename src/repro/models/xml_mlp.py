"""The paper's workload: a 3-layer MLP over sparse XML data.

Architecture (identical to the SLIDE testbed the paper adopts): sparse input
layer -> hidden ReLU layer -> softmax output over the (huge) label space,
with cross-entropy loss. The input layer is a sparse-dense matmul
(cuSPARSE SpMM in the paper; our Pallas ``spmm`` kernel on TPU — pure-jnp
gather fallback here).

Batch layout: padded COO (see data/sparse.py). The ``sample_mask`` makes the
effective batch size adaptive while shapes stay static.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class XMLMLPConfig:
    n_features: int
    n_classes: int
    hidden: int = 128
    dtype: Any = jnp.float32
    use_spmm_kernel: bool = False  # route input layer through Pallas spmm


def init_params(cfg: XMLMLPConfig, rng: jax.Array) -> dict:
    """Paper: weights ~ Normal with std scaled by layer width."""
    k1, k2 = jax.random.split(rng)
    w1 = jax.random.normal(k1, (cfg.n_features, cfg.hidden), cfg.dtype)
    w1 = w1 * (1.0 / jnp.sqrt(cfg.n_features))
    w2 = jax.random.normal(k2, (cfg.hidden, cfg.n_classes), cfg.dtype)
    w2 = w2 * (1.0 / jnp.sqrt(cfg.hidden))
    return {
        "w1": w1,
        "b1": jnp.zeros((cfg.hidden,), cfg.dtype),
        "w2": w2,
        "b2": jnp.zeros((cfg.n_classes,), cfg.dtype),
    }


def forward(cfg: XMLMLPConfig, params: dict, batch: dict) -> jax.Array:
    """Return logits (B, n_classes)."""
    if cfg.use_spmm_kernel:
        from repro.kernels.spmm import ops as spmm_ops

        h = spmm_ops.spmm(
            batch["feat_idx"], batch["feat_val"], batch["feat_mask"], params["w1"]
        )
    else:
        h = _sparse_input_ref(
            batch["feat_idx"], batch["feat_val"], batch["feat_mask"], params["w1"]
        )
    h = jax.nn.relu(h + params["b1"])
    return h @ params["w2"] + params["b2"]


def _sparse_input_ref(feat_idx, feat_val, feat_mask, w1):
    """Gather formulation of SpMM: h[b] = sum_k val[b,k] * W1[idx[b,k]]."""
    rows = w1[feat_idx]  # (B, nnz, H)
    scale = (feat_val * feat_mask).astype(w1.dtype)[..., None]
    return jnp.sum(rows * scale, axis=1)


def loss_fn(cfg: XMLMLPConfig, params: dict, batch: dict):
    """Masked multi-label softmax cross-entropy + top-1 accuracy.

    Loss per sample = mean over its true labels of -log p(label); batch loss
    is averaged over *valid* samples only (adaptive batch size).
    Returns (loss, aux) with aux = dict(accuracy, n_valid).
    """
    logits = forward(cfg, params, batch).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    lab_logp = jnp.take_along_axis(logp, batch["label_idx"], axis=-1)
    lmask = batch["label_mask"].astype(jnp.float32)
    per_sample = -jnp.sum(lab_logp * lmask, axis=-1) / jnp.maximum(
        jnp.sum(lmask, axis=-1), 1.0
    )
    smask = batch["sample_mask"].astype(jnp.float32)
    n_valid = jnp.sum(smask)
    loss = jnp.sum(per_sample * smask) / jnp.maximum(n_valid, 1.0)

    pred = jnp.argmax(logits, axis=-1)
    hit = jnp.any(
        (batch["label_idx"] == pred[:, None]) & batch["label_mask"], axis=-1
    ).astype(jnp.float32)
    acc = jnp.sum(hit * smask) / jnp.maximum(n_valid, 1.0)
    return loss, {"accuracy": acc, "n_valid": n_valid}


def make_model(cfg: XMLMLPConfig):
    """Bundle (init, loss) in the trainer's model protocol."""
    return {
        "init": lambda rng: init_params(cfg, rng),
        "loss_fn": lambda params, batch: loss_fn(cfg, params, batch),
        "config": cfg,
    }
