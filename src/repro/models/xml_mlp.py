"""The paper's workload: a 3-layer MLP over sparse XML data.

Architecture (identical to the SLIDE testbed the paper adopts): sparse input
layer -> hidden ReLU layer -> softmax output over the (huge) label space,
with cross-entropy loss. The input layer is a sparse-dense matmul
(cuSPARSE SpMM in the paper; our Pallas ``spmm`` kernel on TPU, with the
pure-jnp gather as the fallback on every other backend and the
differential oracle).

Batch layout: padded COO (see data/sparse.py). The ``sample_mask`` makes the
effective batch size adaptive while shapes stay static.

Training runs the **sparse-gradient path** (DESIGN.md §3) by default:
``loss_and_sparse_grad`` splits the loss at the input layer's output, pulls
the head cotangent ``dh`` back with ``jax.vjp``, and emits d``w1`` directly
as a RowSparseGrad — ``vals[b,k] = val[b,k]*mask[b,k] * dh[b]`` on rows
``idx[b,k]`` — so no dense (NF, H) gradient is ever materialized. The dense
autodiff path (``loss_fn`` under ``jax.value_and_grad``) is retained as the
oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.protocol import TrainableModel
from repro.optim.row_sparse import RowSparseGrad


@dataclass(frozen=True)
class XMLMLPConfig:
    n_features: int
    n_classes: int
    hidden: int = 128
    dtype: Any = jnp.float32
    # route the input layer through the Pallas spmm kernel (forward + custom
    # VJP). None = auto: kernel where it lowers natively (TPU), jnp gather
    # elsewhere (interpret-mode Pallas is validated by the kernel tests, not
    # run in training loops).
    use_spmm_kernel: Optional[bool] = None
    sparse_grads: bool = True  # expose the row-sparse d w1 path to the trainer


def _kernel_routed(cfg: XMLMLPConfig) -> bool:
    if cfg.use_spmm_kernel is None:
        return jax.default_backend() == "tpu"
    return cfg.use_spmm_kernel


def init_params(cfg: XMLMLPConfig, rng: jax.Array) -> dict:
    """Paper: weights ~ Normal with std scaled by layer width."""
    k1, k2 = jax.random.split(rng)
    w1 = jax.random.normal(k1, (cfg.n_features, cfg.hidden), cfg.dtype)
    w1 = w1 * (1.0 / jnp.sqrt(cfg.n_features))
    w2 = jax.random.normal(k2, (cfg.hidden, cfg.n_classes), cfg.dtype)
    w2 = w2 * (1.0 / jnp.sqrt(cfg.hidden))
    return {
        "w1": w1,
        "b1": jnp.zeros((cfg.hidden,), cfg.dtype),
        "w2": w2,
        "b2": jnp.zeros((cfg.n_classes,), cfg.dtype),
    }


def _input_layer(cfg: XMLMLPConfig, w1: jax.Array, batch: dict) -> jax.Array:
    """The sparse input layer: h_lin (B, hidden)."""
    if _kernel_routed(cfg):
        from repro.kernels.spmm import ops as spmm_ops

        return spmm_ops.spmm(
            batch["feat_idx"], batch["feat_val"], batch["feat_mask"], w1
        )
    return _sparse_input_ref(
        batch["feat_idx"], batch["feat_val"], batch["feat_mask"], w1
    )


def _sparse_input_ref(feat_idx, feat_val, feat_mask, w1):
    """Gather formulation of SpMM: h[b] = sum_k val[b,k] * W1[idx[b,k]]."""
    rows = w1[feat_idx]  # (B, nnz, H)
    scale = (feat_val * feat_mask).astype(w1.dtype)[..., None]
    return jnp.sum(rows * scale, axis=1)


def _head_loss(h_lin: jax.Array, rest: dict, batch: dict):
    """From the input layer's output to (loss, aux).

    Masked multi-label softmax cross-entropy + top-1 accuracy. Loss per
    sample = mean over its true labels of -log p(label); batch loss is
    averaged over *valid* samples only (adaptive batch size).
    """
    h = jax.nn.relu(h_lin + rest["b1"])
    logits = (h @ rest["w2"] + rest["b2"]).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    lab_logp = jnp.take_along_axis(logp, batch["label_idx"], axis=-1)
    lmask = batch["label_mask"].astype(jnp.float32)
    per_sample = -jnp.sum(lab_logp * lmask, axis=-1) / jnp.maximum(
        jnp.sum(lmask, axis=-1), 1.0
    )
    smask = batch["sample_mask"].astype(jnp.float32)
    n_valid = jnp.sum(smask)
    loss = jnp.sum(per_sample * smask) / jnp.maximum(n_valid, 1.0)

    pred = jnp.argmax(logits, axis=-1)
    hit = jnp.any(
        (batch["label_idx"] == pred[:, None]) & batch["label_mask"], axis=-1
    ).astype(jnp.float32)
    acc = jnp.sum(hit * smask) / jnp.maximum(n_valid, 1.0)
    return loss, {"accuracy": acc, "n_valid": n_valid}


def forward(cfg: XMLMLPConfig, params: dict, batch: dict) -> jax.Array:
    """Return logits (B, n_classes)."""
    h = jax.nn.relu(_input_layer(cfg, params["w1"], batch) + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(cfg: XMLMLPConfig, params: dict, batch: dict):
    """Dense-path loss: differentiate with jax.value_and_grad (the oracle).
    Returns (loss, aux) with aux = dict(accuracy, n_valid)."""
    rest = {k: v for k, v in params.items() if k != "w1"}
    h_lin = _input_layer(cfg, params["w1"], batch)
    return _head_loss(h_lin, rest, batch)


def loss_and_sparse_grad(cfg: XMLMLPConfig, params: dict, batch: dict):
    """Sparse-gradient step math: ((loss, aux), grads) with d w1 row-sparse.

    d w1 flows only through the input layer, whose VJP w.r.t. w1 is
    analytically ``dW[idx[b,k]] += scale[b,k] * dh[b]`` — exactly the
    RowSparseGrad layout, so we pull ``dh`` back through the head with
    jax.vjp and never build the dense (NF, H) gradient. Masked/padded nnz
    slots get the out-of-bounds sentinel row NF (scatter drops them).
    """
    rest = {k: v for k, v in params.items() if k != "w1"}
    h_lin = _input_layer(cfg, params["w1"], batch)
    loss, head_vjp, aux = jax.vjp(
        lambda h, r: _head_loss(h, r, batch), h_lin, rest, has_aux=True
    )
    dh, drest = head_vjp(jnp.ones_like(loss))

    scale = (batch["feat_val"] * batch["feat_mask"]).astype(jnp.float32)
    b, k = scale.shape
    vals = scale[..., None] * dh.astype(jnp.float32)[:, None, :]  # (B, K, H)
    rows = jnp.where(
        batch["feat_mask"], batch["feat_idx"], cfg.n_features
    ).astype(jnp.int32)
    grads = dict(drest)
    grads["w1"] = RowSparseGrad(
        rows.reshape(b * k), vals.reshape(b * k, -1), cfg.n_features
    )
    return (loss, aux), grads


def make_model(cfg: XMLMLPConfig) -> TrainableModel:
    """Bundle (init, loss[, sparse_grad]) as the trainer's TrainableModel."""
    return TrainableModel(
        init=lambda rng: init_params(cfg, rng),
        loss_fn=lambda params, batch: loss_fn(cfg, params, batch),
        sparse_grad_fn=(
            (lambda params, batch: loss_and_sparse_grad(cfg, params, batch))
            if cfg.sparse_grads else None
        ),
        config=cfg,
    )
