"""Unified causal LM covering all assigned architecture families.

One config-driven model: dense / GQA attention, MoE FFN, Mamba2 (SSD)
mixers, hybrid interleaves (Jamba), encoder-decoder (Seamless), and
VLM/audio stub frontends. Layer stacks are grouped into (prefix, periodic
blocks) so homogeneous spans run under ``lax.scan`` — compile time and HLO
size stay bounded even for 72-layer hybrids.

API (consumed by the trainer, launcher and dry-run):
    init(cfg, rng) -> params
    loss_fn(cfg, params, batch) -> (loss, aux)           # train_4k
    prefill(cfg, params, batch) -> (logits, cache)       # prefill_32k
    decode_step(cfg, params, cache, tokens) -> (logits, cache)  # decode shapes
    init_cache(cfg, batch, max_len, window) -> cache
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.protocol import TrainableModel
from repro.sharding.annotate import shard
from . import layers as L
from . import mamba2 as M
from . import moe as MOE

# --------------------------------------------------------------------------
# layer pattern -> (prefix, period) decomposition
# --------------------------------------------------------------------------


def layer_pattern(cfg: ModelConfig) -> list[tuple[str, str]]:
    return [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.n_layers)]


def find_prefix_period(pattern: list) -> tuple[int, int]:
    """Smallest (prefix, period) with pattern[prefix:] periodic."""
    n = len(pattern)
    for prefix in range(0, n):
        rest = pattern[prefix:]
        if not rest:
            return prefix, 1
        for period in (1, 2, 4, 8):
            if len(rest) % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(len(rest))):
                return prefix, period
    return n, 1  # fully unrolled fallback


# --------------------------------------------------------------------------
# per-layer init / apply
# --------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat(cfg: ModelConfig, body):
    """Apply the configured activation-checkpoint policy to a scan body.

    'full' recomputes everything (lowest live memory); 'dots' saves matmul
    outputs and recomputes only elementwise chains — §Perf iteration for
    compute-bound small archs: most of remat's recompute FLOPs are dots, so
    saving them recovers nearly remat=off compute at a fraction of the live
    memory."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(body)


def init_sublayers(cfg: ModelConfig, key, kind: str, ffn_kind: str) -> dict:
    kmix, kffn = jax.random.split(key)
    dt = _dtype(cfg)
    p: dict = {}
    if kind == "attn":
        p["mixer"] = L.init_attention(
            kmix, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dt
        )
    else:  # ssm
        p["mixer"] = M.init_mamba2(
            kmix,
            cfg.d_model,
            expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state,
            conv=cfg.ssm_conv,
            dtype=dt,
        )
    if ffn_kind == "moe":
        p["ffn"] = MOE.init_moe(
            kffn,
            cfg.d_model,
            cfg.d_ff,
            cfg.n_experts,
            dt,
            dense_residual_ff=cfg.dense_residual_ff if cfg.dense_residual else 0,
        )
    elif cfg.d_ff > 0:
        p["ffn"] = L.init_mlp(kffn, cfg.d_model, cfg.d_ff, dt)
    return p


def apply_sublayers(
    cfg: ModelConfig,
    kind: str,
    ffn_kind: str,
    params: dict,
    x: jax.Array,
    *,
    window: int = 0,
    positions=None,
    cross: Optional[tuple] = None,  # (cross_params, encoder_memory)
) -> tuple[jax.Array, jax.Array]:
    """Train/prefill path: mixer -> [cross-attn] -> ffn. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x = L.attention_layer(
            params["mixer"],
            x,
            n_rep=cfg.n_heads // cfg.n_kv_heads,
            rope_theta=cfg.rope_theta,
            window=window if window else cfg.sliding_window,
            positions=positions,
            norm_eps=cfg.norm_eps,
            use_flash=cfg.use_flash_kernel,
        )
    else:
        x = M.mamba2_forward(
            params["mixer"],
            x,
            head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state,
            chunk=cfg.ssm_chunk,
            norm_eps=cfg.norm_eps,
            use_kernel=cfg.use_ssd_kernel,
        )
    if cross is not None:
        cp, mem = cross
        k = jnp.einsum("bsd,dhk->bshk", mem, cp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", mem, cp["wv"])
        x = L.attention_layer(
            cp,
            x,
            n_rep=cfg.n_heads // cfg.n_kv_heads,
            rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
            cross_kv=(k, v),
        )
    if ffn_kind == "moe":
        x, aux = MOE.moe_layer(
            params["ffn"], x, top_k=cfg.top_k, norm_eps=cfg.norm_eps,
            dispatch=cfg.moe_dispatch, combine_dtype=cfg.moe_combine_dtype,
            use_gmm_kernel=cfg.use_gmm_kernel,
        )
    elif cfg.d_ff > 0:
        x = L.mlp_layer(params["ffn"], x, cfg.norm_eps)
    return x, aux


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    dt = _dtype(cfg)
    pattern = layer_pattern(cfg)
    prefix, period = find_prefix_period(pattern)
    n_groups = (cfg.n_layers - prefix) // period
    keys = L.split_keys(rng, 6)

    params: dict = {"embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt)}

    # prefix layers (unrolled)
    pk = L.split_keys(keys[1], max(prefix, 1))
    params["prefix"] = [
        init_sublayers(cfg, pk[i], *pattern[i]) for i in range(prefix)
    ]

    # periodic blocks (scanned): for each in-period position, stack n_groups inits
    blocks = {}
    bk = L.split_keys(keys[2], max(period, 1))
    for j in range(period):
        kind, ffn_kind = pattern[prefix + j]
        gks = jnp.stack(L.split_keys(bk[j], max(n_groups, 1)))
        blocks[f"pos{j}"] = jax.vmap(
            lambda k: init_sublayers(cfg, k, kind, ffn_kind)
        )(gks)
    params["blocks"] = blocks

    params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.ninit(
            keys[3], (cfg.vocab_size, cfg.d_model), cfg.d_model ** -0.5, dt
        )

    # encoder (enc-dec) — homogeneous attn+mlp stack, scanned
    if cfg.encoder_layers > 0:
        eks = jnp.stack(L.split_keys(keys[4], cfg.encoder_layers))
        params["encoder"] = {
            "layers": jax.vmap(lambda k: init_sublayers(cfg, k, "attn", "dense"))(eks),
            "norm": jnp.zeros((cfg.d_model,), dt),
        }
        # per-decoder-layer cross-attention
        ck = L.split_keys(keys[5], cfg.n_layers)
        params["cross"] = [
            L.init_attention(
                ck[i], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dt
            )
            for i in range(cfg.n_layers)
        ]
    if cfg.frontend is not None:
        fk = jax.random.fold_in(keys[5], 7)
        params["frontend_proj"] = L.ninit(
            fk, (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim ** -0.5, dt
        )
    return params


# --------------------------------------------------------------------------
# forward (train / prefill trunk)
# --------------------------------------------------------------------------


def _run_encoder(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over (projected) frontend embeddings."""
    x = frames.astype(_dtype(cfg)) @ params["frontend_proj"]
    enc = params["encoder"]

    def body(x, lp):
        h = L.attention_layer(
            lp["mixer"],
            x,
            n_rep=cfg.n_heads // cfg.n_kv_heads,
            rope_theta=cfg.rope_theta,
            causal=False,
            norm_eps=cfg.norm_eps,
        )
        h = L.mlp_layer(lp["ffn"], h, cfg.norm_eps)
        return h, None

    if cfg.remat:
        body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rmsnorm(x, enc["norm"], cfg.norm_eps)


def _trunk(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    *,
    memory: Optional[jax.Array] = None,
    window: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Apply prefix + scanned blocks (+ interleaved cross-attn for enc-dec)."""
    pattern = layer_pattern(cfg)
    prefix, period = find_prefix_period(pattern)
    aux_total = jnp.zeros((), jnp.float32)

    cross_all = params.get("cross")

    for i in range(prefix):
        x, aux = apply_sublayers(
            cfg, *pattern[i], params["prefix"][i], x, window=window,
            cross=(cross_all[i], memory) if cross_all is not None else None,
        )
        aux_total = aux_total + aux

    n_groups = (cfg.n_layers - prefix) // period
    if n_groups > 0:
        xs = dict(params["blocks"])
        if cross_all is not None:
            # stack per-group cross params: cross layers prefix..n arranged (g, period)
            cross_rest = cross_all[prefix:]
            for j in range(period):
                xs[f"cross_pos{j}"] = jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls), *cross_rest[j::period]
                )

        def body(carry, bp):
            x, aux_acc = carry
            for j in range(period):
                kind, ffn_kind = pattern[prefix + j]
                x, aux = apply_sublayers(
                    cfg, kind, ffn_kind, bp[f"pos{j}"], x, window=window,
                    cross=(bp[f"cross_pos{j}"], memory) if cross_all is not None else None,
                )
                aux_acc = aux_acc + aux
            return (x, aux_acc), None

        if cfg.remat:
            body = _remat(cfg, body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), xs)
    return x, aux_total


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict):
    """Token (+frontend) embeddings. Returns (x, n_vis) where the first
    n_vis positions are non-text (excluded from the loss)."""
    tok = L.embed(params["embed"], batch["tokens"])
    if cfg.frontend == "vision":
        vis = batch["patch_embeds"].astype(tok.dtype) @ params["frontend_proj"]
        return jnp.concatenate([vis, tok], axis=1), vis.shape[1]
    return tok, 0


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x, cfg.logits_softcap)
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), params["lm_head"].astype(jnp.float32)
    )
    if cfg.logits_softcap > 0:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits


def loss_fn(cfg: ModelConfig, params: dict, batch: dict):
    """Masked causal-LM cross entropy. aux = accuracy / n_valid / moe aux."""
    memory = None
    if cfg.encoder_layers > 0:
        memory = _run_encoder(cfg, params, batch["frames"])
    x, n_vis = _embed_inputs(cfg, params, batch)
    x = shard(x, "replica", "batch", "seq", None)
    x, moe_aux = _trunk(cfg, params, x, memory=memory)
    if n_vis:
        x = x[:, n_vis:]
    logits = _logits(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = batch["targets"]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]  # (B,S)
    smask = batch["sample_mask"].astype(jnp.float32)[:, None]
    n_valid = jnp.sum(smask) * tgt.shape[1]
    loss = jnp.sum(nll * smask) / jnp.maximum(n_valid, 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == tgt) * smask) / jnp.maximum(n_valid, 1.0)
    total = loss + cfg.router_aux_coef * moe_aux
    return total, {"accuracy": acc, "n_valid": jnp.sum(smask), "moe_aux": moe_aux,
                   "ce_loss": loss}


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    dt = _dtype(cfg)
    if kind == "attn":
        hd = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dt),
        }
    n_heads = (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim
    d_inner = n_heads * cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state), dt),
        "ssm": jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0) -> dict:
    """window > 0 => rolling attention buffers of that size (long_500k path)."""
    pattern = layer_pattern(cfg)
    prefix, period = find_prefix_period(pattern)
    n_groups = (cfg.n_layers - prefix) // period
    attn_len = min(max_len, window) if window else max_len
    cache: dict = {
        "prefix": [
            _layer_cache(cfg, pattern[i][0], batch, attn_len) for i in range(prefix)
        ],
        "blocks": {},
        "cur_len": jnp.zeros((), jnp.int32),
    }
    for j in range(period):
        kind, _ = pattern[prefix + j]
        one = _layer_cache(cfg, kind, batch, attn_len)
        cache["blocks"][f"pos{j}"] = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape), one
        )
    if cfg.encoder_layers > 0:
        hd = cfg.resolved_head_dim
        dt = _dtype(cfg)
        mem_len = cfg.frontend_len
        cache["cross_kv"] = [
            {
                "k": jnp.zeros((batch, mem_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, mem_len, cfg.n_kv_heads, hd), dt),
            }
            for _ in range(cfg.n_layers)
        ]
    return cache


def _decode_sublayers(
    cfg: ModelConfig,
    kind: str,
    ffn_kind: str,
    params: dict,
    x: jax.Array,
    cache: dict,
    cur_len,
    window: int,
    cross: Optional[tuple] = None,  # (cross_params, cross_kv_cache)
):
    if kind == "attn":
        x, ck, cv = L.decode_attention(
            params["mixer"],
            x,
            cache["k"],
            cache["v"],
            cur_len,
            n_rep=cfg.n_heads // cfg.n_kv_heads,
            rope_theta=cfg.rope_theta,
            window=window,
            norm_eps=cfg.norm_eps,
        )
        new_cache = {"k": ck, "v": cv}
    else:
        x, new_cache = M.mamba2_decode_step(
            params["mixer"],
            x,
            cache,
            head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state,
            norm_eps=cfg.norm_eps,
        )
    if cross is not None:
        cp, ckv = cross
        x, _, _ = L.decode_attention(
            cp, x, ckv["k"], ckv["v"], cur_len,
            n_rep=cfg.n_heads // cfg.n_kv_heads, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps, cross=True,
        )
    if ffn_kind == "moe":
        decode_dispatch = "gather" if cfg.moe_decode_gather else cfg.moe_dispatch
        x, _ = MOE.moe_layer(params["ffn"], x, top_k=cfg.top_k,
                             norm_eps=cfg.norm_eps, dispatch=decode_dispatch,
                             combine_dtype=cfg.moe_combine_dtype)
    elif cfg.d_ff > 0:
        x = L.mlp_layer(params["ffn"], x, cfg.norm_eps)
    return x, new_cache


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # (B, 1)
    window: int = 0,
) -> tuple[jax.Array, dict]:
    """One-token decode against the cache. Returns (logits (B,1,V), cache)."""
    pattern = layer_pattern(cfg)
    prefix, period = find_prefix_period(pattern)
    cur = cache["cur_len"]
    x = L.embed(params["embed"], tokens)

    new_prefix = []
    for i in range(prefix):
        x, nc = _decode_sublayers(
            cfg, *pattern[i], params["prefix"][i], x, cache["prefix"][i], cur, window,
            cross=(params["cross"][i], cache["cross_kv"][i])
            if cfg.encoder_layers > 0 else None,
        )
        new_prefix.append(nc)

    cross_rest = None
    if cfg.encoder_layers > 0 and cfg.n_layers > prefix:
        cross_params_rest = params["cross"][prefix:]
        cross_cache_rest = cache["cross_kv"][prefix:]
        cross_rest = {}
        for j in range(period):
            cross_rest[f"p{j}"] = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *cross_params_rest[j::period]
            )
            cross_rest[f"c{j}"] = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *cross_cache_rest[j::period]
            )

    def body(x, sc):
        bp, bc = sc["params"], sc["cache"]
        new_bc = {}
        for j in range(period):
            kind, ffn_kind = pattern[prefix + j]
            x, nc = _decode_sublayers(
                cfg, kind, ffn_kind, bp[f"pos{j}"], x, bc[f"pos{j}"], cur, window,
                cross=(sc["cross_p"][f"p{j}"], sc["cross_c"][f"c{j}"])
                if cross_rest is not None else None,
            )
            new_bc[f"pos{j}"] = nc
        return x, new_bc

    xs = {"params": params["blocks"], "cache": cache["blocks"]}
    if cross_rest is not None:
        xs["cross_p"] = {k: v for k, v in cross_rest.items() if k.startswith("p")}
        xs["cross_c"] = {k: v for k, v in cross_rest.items() if k.startswith("c")}
    n_groups = (cfg.n_layers - prefix) // period
    if n_groups > 0:
        x, new_blocks = jax.lax.scan(body, x, xs)
    else:
        new_blocks = cache["blocks"]

    logits = _logits(cfg, params, x)
    new_cache = dict(cache)
    new_cache["prefix"] = new_prefix
    new_cache["blocks"] = new_blocks
    new_cache["cur_len"] = cur + 1
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict):
    """Full-sequence forward returning last-position logits.

    (Prefill cache extraction is exercised via decode_step-based serving;
    for the dry-run the prefill step is the forward itself.)
    """
    memory = None
    if cfg.encoder_layers > 0:
        memory = _run_encoder(cfg, params, batch["frames"])
    x, n_vis = _embed_inputs(cfg, params, batch)
    x, _ = _trunk(cfg, params, x, memory=memory)
    return _logits(cfg, params, x[:, -1:, :])


# --------------------------------------------------------------------------
# trainer-protocol bundle
# --------------------------------------------------------------------------


def make_model(cfg: ModelConfig) -> TrainableModel:
    return TrainableModel(
        init=lambda rng: init(cfg, rng),
        loss_fn=lambda params, batch: loss_fn(cfg, params, batch),
        config=cfg,
    )
