"""Mixture-of-Experts FFN: top-k router + sort-based dispatch + grouped matmul.

TPU-native formulation: instead of the (T, E, C) one-hot dispatch einsum
(memory O(T·E·C)), tokens are *sorted by expert id* and scattered into an
(E, C, D) buffer — O(T·k) bookkeeping + a grouped matmul that maps directly
onto the MXU (and onto the Pallas ``moe_gmm`` kernel). Experts are sharded
over the `model` (and optionally `data` = expert-parallel) mesh axes; GSPMD
turns the buffer reshard into the all-to-all of classic expert parallelism.

Router load-imbalance is the LM-world analogue of the paper's sparse-nnz
variance: per-batch expert counts fluctuate, so per-replica step time
fluctuates, giving Adaptive SGD's scheduler real signal (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.annotate import logical_axis_size, shard
from .layers import ninit, rmsnorm, split_keys


def init_moe(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    dtype,
    dense_residual_ff: int = 0,
):
    kr, ki, kg, ko, kd = split_keys(key, 5)
    p = {
        "router": ninit(kr, (d_model, n_experts), d_model ** -0.5, jnp.float32),
        "wi": ninit(ki, (n_experts, d_model, d_ff), d_model ** -0.5, dtype),
        "wg": ninit(kg, (n_experts, d_model, d_ff), d_model ** -0.5, dtype),
        "wo": ninit(ko, (n_experts, d_ff, d_model), d_ff ** -0.5, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }
    if dense_residual_ff:
        k1, k2, k3 = split_keys(kd, 3)
        p["dense"] = {
            "wi": ninit(k1, (d_model, dense_residual_ff), d_model ** -0.5, dtype),
            "wg": ninit(k2, (d_model, dense_residual_ff), d_model ** -0.5, dtype),
            "wo": ninit(k3, (dense_residual_ff, d_model), dense_residual_ff ** -0.5, dtype),
        }
    return p


def _dispatch_indices(expert_ids: jax.Array, n_experts: int, capacity: int):
    """Sort-based slot assignment.

    expert_ids: (Tk,) int32. Returns (sort_idx, slots, keep) where
    ``slots[j]`` is the destination row in the (E*C) buffer for the j-th
    sorted assignment and ``keep`` masks capacity overflow.
    """
    tk = expert_ids.shape[0]
    sort_idx = jnp.argsort(expert_ids, stable=True)
    sorted_eids = expert_ids[sort_idx]
    counts = jnp.zeros((n_experts,), jnp.int32).at[expert_ids].add(1)
    starts = jnp.cumsum(counts) - counts  # first sorted position of each expert
    pos_in_expert = jnp.arange(tk, dtype=jnp.int32) - starts[sorted_eids]
    keep = pos_in_expert < capacity
    slots = sorted_eids * capacity + jnp.minimum(pos_in_expert, capacity - 1)
    return sort_idx, slots, keep


def _expert_ffn(params: dict, buf: jax.Array, use_gmm_kernel: bool) -> jax.Array:
    """Grouped SwiGLU over (E, C, D) capacity buffers."""
    if use_gmm_kernel:
        from repro.kernels.moe_gmm import ops as gmm_ops

        return gmm_ops.moe_ffn_gmm(buf, params["wi"], params["wg"], params["wo"])
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    return jnp.einsum("ecf,efd->ecd", g * u, params["wo"])


def moe_ffn(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    use_gmm_kernel: bool = False,
    dispatch: str = "global",
    force_groups: int = 0,
    combine_dtype: str = "f32",
) -> tuple[jax.Array, jax.Array]:
    """MoE FFN body (pre-norm residual added by caller).

    x: (B, S, D) normed input. Returns (out (B,S,D), aux_loss scalar).

    dispatch:
      * ``global``  — paper-era baseline: one argsort/gather/scatter over all
        T*k assignments. Under GSPMD with tokens sharded over the same axis
        as experts, the cross-shard scatter lowers to full-buffer
        all-reduces (the dominant collective in the kimi/arctic dry-runs).
      * ``sharded`` — beyond-paper optimization (EXPERIMENTS.md §Perf):
        dispatch is computed *per token shard* (vmapped over G groups
        aligned with the batch sharding), so gathers/scatters stay local
        and the only cross-shard movement is the (G, E) -> (E, G) buffer
        reshard — the canonical expert-parallel all-to-all.
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    h = x.reshape(t, d)

    logits = h.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    pe = jnp.mean(probs, axis=0)
    fe = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(pe * fe)

    groups = 1
    if dispatch == "sharded":
        groups = force_groups if force_groups else logical_axis_size("experts")
        if t % groups or b % groups:
            groups = 1  # fall back (e.g. tiny smoke shapes)

    capacity = int(max(top_k, round(t // groups * top_k * capacity_factor / e)))

    def dispatch_group(h_g, ids_g, w_g):
        """One token shard: local sort-based dispatch into (E, C, D)."""
        flat = ids_g.reshape(-1).astype(jnp.int32)          # (Tg*k,)
        sort_idx, slots, keep = _dispatch_indices(flat, e, capacity)
        token_of = (sort_idx // top_k).astype(jnp.int32)
        buf = jnp.zeros((e * capacity, d), x.dtype)
        gathered = h_g[token_of] * keep[:, None].astype(x.dtype)
        buf = buf.at[slots].set(gathered, mode="drop")
        return buf.reshape(e, capacity, d), (sort_idx, slots, keep, token_of)

    # §Perf iteration 2: the combine path in f32 doubles the HBM and
    # collective bytes of every (T*k, D) tensor and its gradients; bf16
    # halves them (top_k<=8 partial sums stay well inside bf16 range).
    acc_dt = jnp.float32 if combine_dtype == "f32" else jnp.bfloat16

    def combine_group(out_buf_g, meta, w_g):
        sort_idx, slots, keep, token_of = meta
        tg = w_g.shape[0]
        out_rows = out_buf_g.reshape(e * capacity, d)[slots]
        w_sorted = w_g.reshape(-1)[sort_idx].astype(jnp.float32)
        contrib = out_rows.astype(acc_dt) * (w_sorted * keep)[:, None].astype(acc_dt)
        return jnp.zeros((tg, d), acc_dt).at[token_of].add(contrib)

    if groups == 1:
        buf, meta = dispatch_group(h, top_ids, top_w)
        buf = shard(buf, "experts", None, None)
        out_buf = _expert_ffn(params, buf, use_gmm_kernel)
        out_buf = shard(out_buf, "experts", None, None)
        y = combine_group(out_buf, meta, top_w)
    else:
        tg = t // groups
        h_g = h.reshape(groups, tg, d)
        ids_g = top_ids.reshape(groups, tg, top_k)
        w_g = top_w.reshape(groups, tg, top_k)
        buf_g, meta = jax.vmap(dispatch_group)(h_g, ids_g, w_g)  # (G,E,C,D)
        buf_g = shard(buf_g, "experts", None, None, None)  # G-dim local to shard
        # (G, E, C, D) -> (E, G*C, D): the expert-parallel all-to-all
        buf = buf_g.transpose(1, 0, 2, 3).reshape(e, groups * capacity, d)
        buf = shard(buf, "experts", None, None)
        out_buf = _expert_ffn(params, buf, use_gmm_kernel)
        out_buf = shard(out_buf, "experts", None, None)
        # back: (E, G*C, D) -> (G, E, C, D) — reverse all-to-all
        ob_g = out_buf.reshape(e, groups, capacity, d).transpose(1, 0, 2, 3)
        ob_g = shard(ob_g, "experts", None, None, None)
        y = jax.vmap(combine_group)(ob_g, meta, w_g).reshape(t, d)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_ffn_gather(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
) -> tuple[jax.Array, jax.Array]:
    """Decode-time MoE FFN (§Perf pair 2, beyond-paper).

    For T = B*S ≪ E the capacity-buffer formulation reads ALL E experts'
    weights to serve a handful of tokens (useful fraction k/E). Here we
    *gather the k routed experts' weights per token* and compute densely:
    weight reads drop from E·(3·D·F) to T·k·(3·D·F) — a ~E/(T·k) reduction
    in the memory roofline term. Only sensible when T·k < E (decode);
    training keeps the buffer formulation (better MXU utilization).
    """
    b, s, d = x.shape
    t = b * s
    h = x.reshape(t, d)
    logits = h.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, top_k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    wi = params["wi"][top_ids]  # (T, k, D, F) — gathers only routed experts
    wg = params["wg"][top_ids]
    wo = params["wo"][top_ids]  # (T, k, F, D)
    g = jax.nn.silu(jnp.einsum("td,tkdf->tkf", h, wg))
    u = jnp.einsum("td,tkdf->tkf", h, wi)
    y = jnp.einsum("tkf,tkfd,tk->td", g * u, wo, top_w.astype(wo.dtype))
    return y.reshape(b, s, d).astype(x.dtype), jnp.zeros((), jnp.float32)


def moe_layer(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    norm_eps: float = 1e-5,
    capacity_factor: float = 1.25,
    use_gmm_kernel: bool = False,
    dispatch: str = "global",
    combine_dtype: str = "f32",
) -> tuple[jax.Array, jax.Array]:
    """Pre-norm MoE block: x + moe(norm(x)) [+ dense residual branch (arctic)]."""
    h = rmsnorm(x, params["norm"], norm_eps)
    if dispatch == "gather":
        out, aux = moe_ffn_gather(params, h, top_k=top_k)
    else:
        out, aux = moe_ffn(
            params, h, top_k=top_k, capacity_factor=capacity_factor,
            use_gmm_kernel=use_gmm_kernel, dispatch=dispatch,
            combine_dtype=combine_dtype,
        )
    if "dense" in params:
        dp = params["dense"]
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, dp["wg"]))
        u = jnp.einsum("bsd,df->bsf", h, dp["wi"])
        out = out + jnp.einsum("bsf,fd->bsd", g * u, dp["wo"])
    return x + out, aux
