"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / sliding /
blockwise-online-softmax), SwiGLU MLP, embeddings.

Everything is a pure function over explicit param dicts (no framework
module system) so the elastic trainer can vmap over the replica dim and the
launcher can assign PartitionSpecs by param-path name.

Attention paths (both grouped-query native: repeated KV heads are NEVER
materialized — q is reshaped to (B, S, Hkv, rep, hd) and contracted against
the raw KV, which keeps the KV cache un-duplicated and un-allgathered):
  * ``blockwise_attention`` — chunked online-softmax (flash-style) in pure
    jnp; memory O(S·chunk) instead of O(S²). Used for train/prefill.
    The Pallas ``flash_attention`` kernel is the TPU-optimized drop-in.
  * ``decode_attention``    — one-token query against a KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.annotate import shard

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def ninit(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gain.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype):
    kq, kk, kv, ko = split_keys(key, 4)
    s = d_model ** -0.5
    return {
        "wq": ninit(kq, (d_model, n_heads, head_dim), s, dtype),
        "wk": ninit(kk, (d_model, n_kv, head_dim), s, dtype),
        "wv": ninit(kv, (d_model, n_kv, head_dim), s, dtype),
        "wo": ninit(ko, (n_heads, head_dim, d_model), (n_heads * head_dim) ** -0.5, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    kv_seq_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax chunked attention, grouped-query native.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0.
    window > 0 = sliding-window causal attention (token i attends to
    [i-window+1, i]). Returns (B, Sq, Hq, hd).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    if sq % q_chunk:
        q_chunk = sq
    if skv % kv_chunk:
        kv_chunk = skv
    nq, nkv = sq // q_chunk, skv // kv_chunk
    scale = hd ** -0.5

    # (nq, B, Hkv, rep, qc, hd)
    qc = q.reshape(b, nq, q_chunk, hkv, rep, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, nkv, kv_chunk, hkv, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nkv, kv_chunk, hkv, hd).transpose(1, 0, 3, 2, 4)
    if kv_seq_mask is not None:
        mc = kv_seq_mask.reshape(b, nkv, kv_chunk).transpose(1, 0, 2)  # (nkv,B,kvc)
    else:
        mc = jnp.ones((nkv, b, kv_chunk), bool)

    q_pos = jnp.arange(sq).reshape(nq, q_chunk)
    kv_pos = jnp.arange(skv).reshape(nkv, kv_chunk)

    def per_q_chunk(carry, qi):
        q_i, qp = qi  # (B,Hkv,rep,qc,hd), (qc,)

        def per_kv_chunk(state, kj):
            acc, m, l = state
            k_j, v_j, kp, msk = kj  # (B,Hkv,kvc,hd), ..., (kvc,), (B,kvc)
            s = jnp.einsum(
                "bhrqd,bhkd->bhrqk",
                q_i.astype(jnp.float32),
                k_j.astype(jnp.float32),
            ) * scale
            allow = msk[:, None, None, None, :]  # (B,1,1,1,kvc)
            rel = qp[:, None] - kp[None, :]  # (qc, kvc)
            if causal:
                allow = jnp.logical_and(allow, (rel >= 0)[None, None, None])
            if window > 0:
                allow = jnp.logical_and(allow, (rel < window)[None, None, None])
            s = jnp.where(allow, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)  # fully-masked rows
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(allow, p, 0.0)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhrqk,bhkd->bhrqd", p, v_j.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((b, hkv, rep, q_chunk, hd), jnp.float32),
            jnp.full((b, hkv, rep, q_chunk), -jnp.inf),
            jnp.zeros((b, hkv, rep, q_chunk), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(per_kv_chunk, init, (kc, vc, kv_pos, mc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry, out

    _, out = jax.lax.scan(per_q_chunk, None, (qc, q_pos))  # (nq,B,Hkv,rep,qc,hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


def attention_layer(
    params: dict,
    x: jax.Array,
    *,
    n_rep: int,
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    positions: Optional[jax.Array] = None,
    kv_seq_mask: Optional[jax.Array] = None,
    norm_eps: float = 1e-5,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    cross_kv: Optional[tuple] = None,
    use_flash: bool = False,
) -> jax.Array:
    """Pre-norm attention block: x + attn(norm(x)). x: (B, S, D)."""
    b, s, _ = x.shape
    h = rmsnorm(x, params["norm"], norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
        if positions is None:
            positions = jnp.arange(s)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    else:
        k, v = cross_kv  # precomputed encoder memory (B, Senc, Hkv, hd)
        causal = False
    q = shard(q, "replica", "batch", "seq", "heads", None)
    if use_flash and kv_seq_mask is None:
        from repro.kernels.flash_attention.ops import flash_attention

        o = flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=min(q_chunk, 128), block_k=min(kv_chunk, 128),
        )
    else:
        o = blockwise_attention(
            q, k, v, causal=causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk, kv_seq_mask=kv_seq_mask,
        )
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return x + out


def decode_attention(
    params: dict,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cur_len: jax.Array,
    *,
    n_rep: int,
    rope_theta: float,
    window: int = 0,
    norm_eps: float = 1e-5,
    cross: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); cache_k/v: (B, S, Hkv, hd).

    Grouped-query native: the cache is never head-repeated. Returns
    (out, new_cache_k, new_cache_v). ``cur_len`` (scalar int) is the number
    of valid cache entries before this token. With ``window``>0 the cache is
    a rolling buffer of size S=window (position wraps).
    """
    b, _, _ = x.shape
    s_cache, hkv = cache_k.shape[1], cache_k.shape[2]
    h = rmsnorm(x, params["norm"], norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])  # (B,1,Hq,hd)
    if not cross:
        k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
        pos = jnp.full((b, 1), cur_len, jnp.int32)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
        slot = cur_len % s_cache if window > 0 else cur_len
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    hq = q.shape[2]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, q.shape[-1])  # (B,Hkv,rep,hd); Sq==1 folded out
    qg = shard(qg, "batch", None, None, None)
    scale = q.shape[-1] ** -0.5
    # accumulate in f32 via preferred_element_type — never casts the cache
    s = jnp.einsum(
        "bhrk,bshk->bhrs", qg, cache_k, preferred_element_type=jnp.float32
    ) * scale
    idx = jnp.arange(s_cache)
    if cross:
        valid = jnp.ones((s_cache,), bool)
    else:
        n_valid = jnp.minimum(cur_len + 1, s_cache)
        valid = idx < n_valid
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bhrs,bshk->bhrk", p, cache_v, preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, hq, q.shape[-1]).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return x + out, cache_k, cache_v


# --------------------------------------------------------------------------
# MLP (SwiGLU)
# --------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "wi": ninit(k1, (d_model, d_ff), d_model ** -0.5, dtype),
        "wg": ninit(k2, (d_model, d_ff), d_model ** -0.5, dtype),
        "wo": ninit(k3, (d_ff, d_model), d_ff ** -0.5, dtype),
        "norm": jnp.zeros((d_model,), dtype),
    }


def mlp_layer(params: dict, x: jax.Array, norm_eps: float = 1e-5) -> jax.Array:
    h = rmsnorm(x, params["norm"], norm_eps)
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, params["wg"]))
    u = jnp.einsum("bsd,df->bsf", h, params["wi"])
    ff = shard(g * u, "replica", "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", ff, params["wo"])
    return x + out


def mlp_apply_raw(params: dict, h: jax.Array) -> jax.Array:
    """SwiGLU body without norm/residual (used by MoE dense-residual path)."""
    g = jax.nn.silu(h @ params["wg"])
    u = h @ params["wi"]
    return (g * u) @ params["wo"]


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype):
    return {"table": ninit(key, (vocab, d_model), d_model ** -0.5, dtype)}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return params["table"][tokens]


def unembed(params: dict, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), params["table"].astype(jnp.float32))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
