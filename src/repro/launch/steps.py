"""Jittable step functions for the production launcher + dry-run.

  * ``train_round``  — one lockstep elastic round: per-replica forward/
    backward + masked SGD update (paper's local updates; plain SGD — the
    momentum of Algorithm 2 lives at the global-model level in merge_step).
  * ``merge_step``   — Algorithm 2's weighted merge across the replica dim
    (the paper's all-reduce model merging) + replica reset broadcast.
  * ``prefill_step`` / ``decode_step`` — serving paths (no replica dim).

All take/return pytrees whose leading replica dim R is sharded over the
replica mesh axis; sharding is supplied by the caller via jit shardings.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.core import adaptive_sgd as asgd
from repro.models import model as MDL
from repro.optim.sgd import SGDConfig, sgd_update
from repro.utils import tree as tu


def make_train_round(cfg: ModelConfig, sgd_cfg: SGDConfig = SGDConfig()):
    def loss_fn(params, batch):
        return MDL.loss_fn(cfg, params, batch)

    def train_round(replicas, batch, lr_vec, update_mask):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (loss, aux), grads = jax.vmap(grad_fn)(replicas, batch)
        new_replicas, _ = sgd_update(
            replicas, grads, lr_vec, sgd_cfg,
            update_mask=update_mask, replica_dim=True,
        )
        return new_replicas, {"loss": loss, "accuracy": aux["accuracy"]}

    return train_round


def make_merge_step(cfg: ModelConfig, gamma: float = 0.9, keep_global: bool = True):
    """Algorithm 2 merge. keep_global=False = paper §4 memory-lean mode
    (no w̄/w̄_p copies; required for the ≥398B archs)."""

    if keep_global:
        def merge_step(replicas, alphas, global_model, prev_global):
            new_global = asgd.normalized_merge(
                replicas, alphas, global_model, prev_global, gamma
            )
            R = jax.tree_util.tree_leaves(replicas)[0].shape[0]
            return new_global, tu.tree_broadcast_replicas(new_global, R)
    else:
        def merge_step(replicas, alphas):
            new_global = asgd.normalized_merge(replicas, alphas, None, None, 0.0)
            R = jax.tree_util.tree_leaves(replicas)[0].shape[0]
            return tu.tree_broadcast_replicas(new_global, R)

    return merge_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return MDL.prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig, window: int = 0):
    def decode_step(params, cache, tokens):
        return MDL.decode_step(cfg, params, cache, tokens, window=window)

    return decode_step
