"""Serving launcher: batched prefill + decode for any assigned architecture.

Serving path used by the decode dry-run shapes: prefill builds the KV/SSM
cache for a batch of prompts, then ``decode_step`` generates tokens
autoregressively (one token per step, cache updated in place functionally).

On CPU this runs the reduced config; on TPU the full config under the
production mesh with the serve sharding rules.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --context 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.models import model as MDL
from repro.utils.logging import log


def greedy_generate(cfg, params, prompt_tokens, gen_len: int, window: int = 0):
    """Prefill via repeated decode_step over the prompt (teacher-forced),
    then greedy generation. Returns (generated (B, gen_len), steps/s)."""
    b, prompt_len = prompt_tokens.shape
    cache = MDL.init_cache(cfg, b, prompt_len + gen_len, window)

    step = jax.jit(lambda p, c, t: MDL.decode_step(cfg, p, c, t, window=window))

    # prefill: feed prompt tokens one at a time (cache-consistent path)
    logits = None
    for i in range(prompt_len):
        logits, cache = step(params, cache, prompt_tokens[:, i : i + 1])

    out = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen_len):
        out.append(tok)
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    return jnp.concatenate(out, axis=1), gen_len / dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help=">0: rolling-buffer sliding-window decode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = MDL.init(cfg, jax.random.PRNGKey(args.seed))
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.context)),
        jnp.int32,
    )
    toks, sps = greedy_generate(cfg, params, prompts, args.gen, window=args.window)
    assert toks.shape == (args.batch, args.gen)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))
    log("serve", arch=cfg.name, batch=args.batch, context=args.context,
        generated=args.gen, decode_steps_per_s=round(sps, 2))
    return toks


if __name__ == "__main__":
    main()
