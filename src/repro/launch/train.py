"""Production training launcher.

Runs the Adaptive SGD elastic trainer (or any baseline algorithm) over
either the paper's sparse-XML workload or any assigned LM architecture.

On a real TPU fleet the same entrypoint runs under a production mesh
(``--mesh single|multi``): the trainer's (R, ...) replica leaves are sharded
over the replica mesh axis via the rules in sharding/rules.py. On CPU (CI /
smoke) it runs the reduced config on one device — identical code path,
identical algorithm semantics; only the mesh differs.

``--algorithm`` accepts anything in the core/algorithms registry — the
paper's Adaptive SGD, the baselines, and any plugin registered through the
public Algorithm API (e.g. the ABS-SGD-style ``delayed_sync``).

``--elastic-schedule`` drives the paper's other elasticity axis — workers
joining/leaving mid-run (DESIGN.md §6): a ``megabatch:R`` list resizes the
replica population at those mega-batch boundaries (re-plan, re-shard, carry
momentum) instead of forcing a restart.

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload xml \
      --algorithm adaptive --replicas 4 --megabatches 20
  PYTHONPATH=src python -m repro.launch.train --workload xml \
      --algorithm delayed_sync --replicas 4 --megabatches 20
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --algorithm adaptive --megabatches 5
  PYTHONPATH=src python -m repro.launch.train --workload xml \
      --algorithm adaptive --megabatches 60 --elastic-schedule "0:4,20:6,40:3"
  PYTHONPATH=src python -m repro.launch.train --workload xml \
      --algorithm adaptive --megabatches 30 \
      --faults "seed=7,p_crash=0.05,3:nan:0,5:join" \
      --checkpoint-dir /tmp/run1 --checkpoint-every 5
  PYTHONPATH=src python -m repro.launch.train --workload xml \
      --algorithm adaptive --megabatches 30 \
      --checkpoint-dir /tmp/run1 --restore-from /tmp/run1
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs.archs import ARCHS
from repro.configs.base import ElasticConfig
from repro.core import algorithms
from repro.core.heterogeneity import MeasuredSpeedModel, SpeedModel
from repro.core.trainer import ENGINES, PLACEMENTS, ElasticTrainer
from repro.data.providers import SparseProvider, TokenProvider
from repro.data.xml_synth import make_xml_dataset
from repro.data.sparse import train_test_split
from repro.models import model as MDL
from repro.models.xml_mlp import XMLMLPConfig, make_model as make_xml_model
from repro.optim.sgd import SGDConfig
from repro.utils.logging import log


def parse_elastic_schedule(spec: str) -> dict[int, int]:
    """``"0:4,20:6,40:3"`` -> ``{0: 4, 20: 6, 40: 3}``.

    Keys are 0-based mega-batch indices; values the replica count that
    takes effect before that mega-batch. Entries may come in any order;
    duplicates keep the last occurrence (argparse-style override).
    """
    out: dict[int, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            mb_str, r_str = part.split(":")
            mb, r = int(mb_str), int(r_str)
        except ValueError:
            raise ValueError(
                f"bad --elastic-schedule entry {part!r}; expected"
                " 'megabatch:replicas' (e.g. '0:4,20:6,40:3')"
            ) from None
        if mb < 0 or r < 1:
            raise ValueError(
                f"bad --elastic-schedule entry {part!r}: mega-batch index"
                " must be >= 0 and replica count >= 1"
            )
        out[mb] = r
    if not out:
        raise ValueError("--elastic-schedule is empty")
    return out


def build_xml_workload(args):
    ds = make_xml_dataset(
        n_samples=args.samples,
        n_features=args.features,
        n_classes=args.classes,
        avg_nnz=args.avg_nnz,
        seed=args.seed,
    )
    train, test = train_test_split(ds, test_frac=0.2, seed=args.seed)
    provider = SparseProvider.make(train, seed=args.seed)
    model = make_xml_model(
        XMLMLPConfig(n_features=ds.n_features, n_classes=ds.n_classes,
                     hidden=args.hidden)
    )
    test_batches = provider.test_batches(test, args.b_max, max_samples=2048)
    return model, provider, test_batches


def build_lm_workload(args):
    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    provider = TokenProvider.make(cfg.vocab_size, args.seq_len, seed=args.seed)
    model = MDL.make_model(cfg)
    test_batches = provider.test_batches(2, args.b_max)
    return model, provider, test_batches


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["xml", "lm"])
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU smoke)")
    ap.add_argument("--algorithm", default="adaptive",
                    choices=list(algorithms.available()),
                    help="any algorithm in the core/algorithms registry"
                         " (plugins registered via @algorithms.register"
                         " appear here automatically)")
    ap.add_argument("--engine", default="scan", choices=list(ENGINES),
                    help="mega-batch executor: device-resident scan (default)"
                         " or the per-round host loop")
    ap.add_argument("--overlap", default="on", choices=["on", "off"],
                    help="overlapped mega-batch pipeline (DESIGN.md §8):"
                         " stage mega-batch N+1 (plan + pack + upload) while"
                         " N executes, and evaluate asynchronously. 'off' is"
                         " the sequential differential oracle — bit-identical"
                         " trajectories under the simulated speed model."
                         " Only the scan engine pipelines; the legacy engine"
                         " always runs sequentially")
    ap.add_argument("--placement", default="vmap", choices=list(PLACEMENTS),
                    help="replica placement: single-device vmap (default) or"
                         " shard_map over a 1-D replica device mesh (spans"
                         " the local accelerators; on CPU CI, the virtual"
                         " devices from --xla_force_host_platform_device_count)")
    ap.add_argument("--multihost", default="auto", choices=["auto", "off"],
                    help="multi-process fleet bootstrap (DESIGN.md §10):"
                         " 'auto' spans processes when the REPRO_MH_*"
                         " environment (set by scripts/multihost_launch.py)"
                         " is present; 'off' ignores it")
    ap.add_argument("--heartbeat-interval", type=float, default=0.5,
                    help="multi-host lease renewal period (seconds)")
    ap.add_argument("--heartbeat-grace", type=float, default=3.0,
                    help="multi-host liveness deadline: a process whose"
                         " lease has not changed for this long is declared"
                         " crashed and evicted")
    ap.add_argument("--speed", default="simulated",
                    choices=["simulated", "measured"],
                    help="heterogeneity source for the scheduler's virtual"
                         " clock: simulated per-replica factors (paper Fig. 1"
                         " reproduction, deterministic) or relative speeds"
                         " measured from real round times (closes the paper"
                         " §3.1 feedback loop on live hardware)")
    ap.add_argument("--dense-grads", action="store_true",
                    help="force dense autodiff instead of the row-sparse"
                         " gradient path (the differential oracle)")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--elastic-schedule", default="",
                    help="'megabatch:R' list, e.g. '0:4,20:6,40:3': resize"
                         " the replica population at those mega-batch"
                         " boundaries (workers joining/leaving, DESIGN.md"
                         " §6). An entry at 0 overrides --replicas; the"
                         " trainer re-plans, re-shards and carries momentum"
                         " at each boundary")
    ap.add_argument("--faults", default="",
                    help="fault-injection spec (DESIGN.md §7): comma list of"
                         " injector rates (seed=7,p_crash=0.02,...) and"
                         " scripted events 'MB:kind[:replica[:duration]]'"
                         " with kind in crash|preempt|join|stall|nan, e.g."
                         " 'seed=7,3:crash:1,5:join,7:nan:0'. Runs the"
                         " trainer under a FleetController (reactive"
                         " resize + quarantine)")
    ap.add_argument("--min-replicas", type=int, default=1,
                    help="fleet floor: evictions never shrink below this")
    ap.add_argument("--max-replicas", type=int, default=0,
                    help="fleet ceiling for joins/readmissions (0 = 2x the"
                         " initial replica count)")
    ap.add_argument("--timeout-factor", type=float, default=0.0,
                    help="health detector: evict a replica whose relative"
                         " speed exceeds this multiple of the population"
                         " median (0 disables)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="enable crash-consistent async checkpointing into"
                         " this directory (atomic publish, bounded"
                         " retention)")
    ap.add_argument("--checkpoint-every", type=int, default=5,
                    help="mega-batches between checkpoints")
    ap.add_argument("--checkpoint-retain", type=int, default=3,
                    help="published checkpoints kept on disk")
    ap.add_argument("--restore-from", default="",
                    help="resume from this checkpoint (a ckpt-* directory,"
                         " or a checkpoint dir — the newest complete"
                         " checkpoint is used)")
    ap.add_argument("--megabatches", type=int, default=10)
    ap.add_argument("--mega-batch", type=int, default=20,
                    help="batches per mega-batch (paper default 100)")
    ap.add_argument("--b-max", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hetero", type=float, default=0.32,
                    help="max relative GPU speed gap (paper Fig.1: 32%%)")
    # XML synth dataset knobs
    ap.add_argument("--samples", type=int, default=8192)
    ap.add_argument("--features", type=int, default=4096)
    ap.add_argument("--classes", type=int, default=1024)
    ap.add_argument("--avg-nnz", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    mh = None
    monitor = None
    if args.multihost != "off":
        from repro.launch import multihost as mhmod

        spec = mhmod.spec_from_env()
        if spec is not None:
            if args.elastic_schedule:
                ap.error("--elastic-schedule is incompatible with a"
                         " multi-host fleet: membership is process-grained"
                         " and signal-driven (DESIGN.md §10)")
            if args.faults:
                ap.error("--faults is incompatible with a multi-host fleet:"
                         " the HeartbeatMonitor is the liveness source; the"
                         " injector stays a single-process test harness")
            if args.speed == "measured":
                ap.error("--speed measured is incompatible with a multi-host"
                         " fleet: per-replica timing only observes the local"
                         " slot block")
            if args.placement != "sharded":
                log("multihost forces --placement sharded")
                args.placement = "sharded"
            mh = mhmod.bootstrap(spec)
            log("multihost bootstrap",
                process=spec.process_id, n_processes=spec.num_processes,
                spanning=mh.spanning, fleet_dir=spec.fleet_dir or "-")
            if mh.spanning == "host":
                from repro.core.fleet import HeartbeatMonitor

                monitor = HeartbeatMonitor(
                    spec.fleet_dir, process_id=spec.process_id,
                    interval=args.heartbeat_interval,
                    grace=args.heartbeat_grace,
                )
                monitor.renew(megabatch=0)
                monitor.start()
                mh.attach_liveness(monitor)
                mh.rendezvous()

    if args.workload == "xml":
        model, provider, test_batches = build_xml_workload(args)
    else:
        model, provider, test_batches = build_lm_workload(args)

    schedule = None
    if args.elastic_schedule:
        schedule = parse_elastic_schedule(args.elastic_schedule)
        if 0 in schedule:
            args.replicas = schedule[0]  # initial membership
        log("elastic schedule",
            events={mb: schedule[mb] for mb in sorted(schedule)})

    ecfg = ElasticConfig.from_bmax(
        args.b_max,
        algorithm=args.algorithm,
        n_replicas=algorithms.get(args.algorithm).resolve_n_replicas(args.replicas),
        mega_batch=args.mega_batch,
        placement=args.placement,
    )
    if args.speed == "measured":
        speed = MeasuredSpeedModel(ecfg.n_replicas)
    else:
        speed = SpeedModel(ecfg.n_replicas, max_gap=args.hetero, seed=args.seed)
    mesh = None
    if args.placement == "sharded" and schedule is None and mh is None:
        # with an elastic schedule the trainer owns the mesh: it draws
        # per-population meshes from the full local device pool as R changes
        from repro.launch.mesh import make_replica_mesh

        mesh = make_replica_mesh(ecfg.n_replicas)
        log("replica mesh",
            devices=mesh.shape["replica"],
            replicas_per_shard=ecfg.n_replicas // mesh.shape["replica"])
    trainer = ElasticTrainer(
        model=model, provider=provider, cfg=ecfg,
        sgd=SGDConfig(), base_lr=args.lr, speed=speed, seed=args.seed,
        engine=args.engine, sparse_grads=not args.dense_grads, mesh=mesh,
        overlap=args.overlap == "on", multihost=mh,
    )
    fleet = None
    if args.faults or args.timeout_factor > 0 or monitor is not None:
        from repro.core.fleet import FleetController, parse_fault_spec

        fleet = FleetController(
            injector=parse_fault_spec(args.faults) if args.faults else None,
            monitor=monitor,
            min_replicas=args.min_replicas,
            max_replicas=args.max_replicas or 2 * ecfg.n_replicas,
            timeout_factor=args.timeout_factor,
            verbose=True,
        )
    manager = None
    if args.checkpoint_dir:
        from repro.checkpoint.store import CheckpointManager

        manager = CheckpointManager(
            args.checkpoint_dir, every=args.checkpoint_every,
            retain=args.checkpoint_retain,
            publisher=mh is None or mh.process_id == 0,
        )
    try:
        state, mlog = trainer.run(
            args.megabatches, test_batches=test_batches, verbose=True,
            resize_schedule=schedule, fleet=fleet, checkpoint=manager,
            restore_from=args.restore_from or None,
        )
    finally:
        if monitor is not None:
            monitor.stop()
    if monitor is not None:
        # completed: flip the lease to 'done' so survivors treat our exit
        # as orderly, not as a missed deadline
        monitor.renew(status="done")
    final = mlog.records[-1] if mlog.records else {}
    log("final",
        algorithm=args.algorithm,
        accuracy=round(final.get("accuracy", float("nan")), 4),
        virtual_time=round(final.get("virtual_time", float("nan")), 3))
    if fleet is not None:
        log("fleet", events=len(fleet.events),
            replicas=trainer.cfg.n_replicas)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(mlog.records, f, indent=1)
    return state, mlog


if __name__ == "__main__":
    main()
