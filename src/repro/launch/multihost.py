"""Multi-host process spanning: bootstrap, rendezvous, and the host-side
exchange that lets one elastic trainer span N processes (DESIGN.md §10).

Two spanning modes, selected by :func:`bootstrap`:

* ``'device'`` — real multi-process XLA backends (TPU/GPU pods).
  ``jax.distributed.initialize`` attaches every process to the jax
  coordination service, ``jax.devices()`` becomes the *global* device
  list, and the sharded replica executors run unchanged as SPMD programs
  over a process-spanning replica mesh
  (``sharding/rules.py::global_replica_devices``). The jax runtime
  fate-shares — any process failure terminates the whole job — so
  recovery is whole-fleet restart from the newest checkpoint
  (DESIGN.md §7), not in-place eviction.

* ``'host'`` — CPU fleets and the elastic path (the mode CI exercises).
  The CPU backend cannot execute cross-process XLA computations, and the
  coordination service's fate-sharing would kill exactly the survivors
  the elastic model exists to keep alive, so host-span processes never
  attach to ``jax.distributed``. Instead every process runs the identical
  deterministic host loop at the *global* replica count R (same seeds →
  same plans, batch-size/lr adaptation, speed model and fleet decisions),
  executes only its own contiguous block of replica slots on a
  process-local mesh, and completes the cross-process reductions — merge
  partials, metric sums, replica norms, finite masks — through the
  lease-aware file exchange below. Liveness comes from
  ``core/fleet.py::HeartbeatMonitor`` lease files: a peer whose lease
  goes stale is dropped mid-exchange (its merge weight renormalized over
  the contributors), *condemned* via a tombstone so every survivor
  converges on the same membership, and formally evicted through the
  fleet's crash path at the next mega-batch boundary.

Exchange correctness under fail-stop (why no consensus round is needed
per exchange): files land via atomic rename, so a partial write is never
visible; a peer's contribution to sequence n either was published before
it died (every survivor sees it — survivors only stop waiting after the
peer's lease has been stale for a full grace period, by which time any
pre-death rename is long visible) or was not (no survivor sees it, all
drop the peer). Membership *agreement* across survivors is handled one
level up: tombstones make the earliest staleness observation
authoritative, and ``agree_events`` allgathers the per-process fleet
proposals at each mega-batch boundary so all survivors evict the same
processes at the same boundary.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np

from repro.core.fleet import FaultEvent
from repro.utils.logging import log

ENV_NUM_PROCESSES = "REPRO_MH_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_MH_PROCESS_ID"
ENV_FLEET_DIR = "REPRO_MH_FLEET_DIR"
ENV_COORDINATOR = "REPRO_MH_COORDINATOR"
ENV_SPANNING = "REPRO_MH_SPANNING"

# kinds a process may propose about a *peer* at a boundary, in the wire
# encoding used by agree_events (join completes a monitor-side rejoin)
_EVENT_CODES = {"crash": 0, "preempt": 1, "join": 2}
_EVENT_KINDS = {v: k for k, v in _EVENT_CODES.items()}


class ProcessCondemned(RuntimeError):
    """This process was declared dead by a fleet peer (stale lease) and
    must not contribute further updates — restart to rejoin."""


@dataclass(frozen=True)
class MultihostSpec:
    """Bootstrap parameters, usually parsed from the environment
    (``REPRO_MH_*``) that ``scripts/multihost_launch.py`` exports."""

    num_processes: int
    process_id: int
    fleet_dir: str
    coordinator: Optional[str] = None
    spanning: str = "auto"          # auto | host | device

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError("num_processes must be >= 1")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id {self.process_id} out of range for "
                f"{self.num_processes} processes"
            )
        if self.spanning not in ("auto", "host", "device"):
            raise ValueError(f"unknown spanning mode {self.spanning!r}")


def spec_from_env(environ=None) -> Optional[MultihostSpec]:
    """Build a spec from ``REPRO_MH_*`` env vars; None when not launched
    under the multi-host runner."""
    env = os.environ if environ is None else environ
    if ENV_NUM_PROCESSES not in env:
        return None
    return MultihostSpec(
        num_processes=int(env[ENV_NUM_PROCESSES]),
        process_id=int(env.get(ENV_PROCESS_ID, "0")),
        fleet_dir=env[ENV_FLEET_DIR],
        coordinator=env.get(ENV_COORDINATOR) or None,
        spanning=env.get(ENV_SPANNING, "auto"),
    )


def _resolve_spanning(spec: MultihostSpec) -> str:
    if spec.spanning != "auto":
        return spec.spanning
    # CPU cannot run cross-process XLA computations; real backends can
    return "device" if jax.default_backend() in ("tpu", "gpu") else "host"


def bootstrap(spec: MultihostSpec) -> "MultihostContext":
    """Initialize this process's membership in the fleet.

    Device span: attach to the jax coordination service (global device
    visibility). Host span: just prepare the shared ``fleet_dir`` layout —
    the rendezvous barrier runs later via :meth:`MultihostContext.rendezvous`
    once the heartbeat lease is being renewed.
    """
    spanning = _resolve_spanning(spec)
    if spanning == "device":
        if spec.num_processes > 1:
            coordinator = spec.coordinator or "localhost:12321"
            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=spec.num_processes,
                process_id=spec.process_id,
            )
    else:
        for sub in ("leases", "condemned", "xchg"):
            os.makedirs(os.path.join(spec.fleet_dir, sub), exist_ok=True)
    return MultihostContext(spec=spec, spanning=spanning)


def _atomic_write(path: str, data: bytes) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _save_tree(path: str, leaves: list) -> None:
    import io

    buf = io.BytesIO()
    np.savez(buf, **{f"l{i}": np.asarray(x) for i, x in enumerate(leaves)})
    _atomic_write(path, buf.getvalue())


def _load_tree(path: str, n_leaves: int) -> list:
    with np.load(path) as z:
        return [z[f"l{i}"] for i in range(n_leaves)]


class MultihostContext:
    """One process's view of the fleet: slot bookkeeping shared by the
    trainer and fleet controller, plus (host span) the file exchange.

    Slot model (host span): the global replica axis 0..R-1 is split into
    equal contiguous blocks, one per *active* process in process-id order.
    Eviction removes whole blocks and renumbers survivors-first, which
    preserves contiguity — so a process's local device trees are always
    ``state[...][lo:hi]`` of the conceptual global state.
    """

    def __init__(self, spec: MultihostSpec, spanning: str):
        self.spec = spec
        self.spanning = spanning
        self.process_id = spec.process_id
        self.n_processes = spec.num_processes
        self.fleet_dir = spec.fleet_dir
        self._active: list[int] = list(range(spec.num_processes))
        self._counts: dict[int, int] = {}
        self._seq = 0
        self._own_files: list[str] = []
        self._liveness: Optional[Any] = None
        self.poll_interval = 0.05
        self.exchange_timeout = 300.0
        # injectable (JL105): tests drive exchange/rendezvous timeouts with
        # a fake clock instead of real 300 s waits
        self._clock = time.monotonic
        self._sleep = time.sleep

    # -- membership bookkeeping ---------------------------------------
    def attach_liveness(self, monitor) -> None:
        """Attach the HeartbeatMonitor whose leases decide whether an
        exchange keeps waiting for a silent peer."""
        self._liveness = monitor

    def active_processes(self) -> list[int]:
        return list(self._active)

    def assign_slots(self, n_replicas: int) -> None:
        n = len(self._active)
        if n_replicas % n != 0:
            raise ValueError(
                f"global replica count {n_replicas} must divide evenly over "
                f"{n} processes (contiguous equal blocks)"
            )
        self._counts = {pid: n_replicas // n for pid in self._active}

    def bounds_of(self, pid: int) -> tuple[int, int]:
        if pid not in self._counts:
            raise KeyError(f"process {pid} is not an active fleet member")
        lo = sum(self._counts[p] for p in self._active if p < pid)
        return lo, lo + self._counts[pid]

    def local_bounds(self) -> tuple[int, int]:
        return self.bounds_of(self.process_id)

    def local_count(self) -> int:
        return self._counts[self.process_id]

    def slots_of(self, pid: int) -> Optional[list[int]]:
        if pid not in self._counts:
            return None
        lo, hi = self.bounds_of(pid)
        return list(range(lo, hi))

    def processes_for_slots(self, slots) -> list[int]:
        """Resolve a drop set to whole peer processes; partial blocks or
        the local process's own block are errors — host-span membership
        changes at process grain only."""
        drop = set(int(s) for s in slots)
        victims = []
        for pid in self._active:
            block = set(self.slots_of(pid) or ())
            if not block & drop:
                continue
            if not block <= drop:
                raise ValueError(
                    f"slots {sorted(drop)} split process {pid}'s block "
                    f"{sorted(block)}; spanning eviction is per-process"
                )
            victims.append(pid)
        covered = set()
        for pid in victims:
            covered |= set(self.slots_of(pid))
        if covered != drop:
            raise ValueError(f"slots {sorted(drop - covered)} map to no process")
        if self.process_id in victims:
            raise ProcessCondemned(
                f"process {self.process_id} asked to evict itself"
            )
        return victims

    def remove_process(self, pid: int) -> None:
        if pid == self.process_id:
            raise ProcessCondemned(
                f"process {self.process_id} asked to evict itself"
            )
        self.condemn(pid)  # a removed peer must never silently rejoin
        self._active.remove(pid)
        del self._counts[pid]

    # -- tombstones ----------------------------------------------------
    def _tomb_path(self, pid: int) -> str:
        return os.path.join(self.fleet_dir, "condemned", f"p{pid}")

    def condemn(self, pid: int) -> None:
        path = self._tomb_path(pid)
        if not os.path.exists(path):
            _atomic_write(path, b"condemned\n")
        if self._liveness is not None:
            self._liveness.note_condemned(pid)

    def condemned(self) -> set[int]:
        d = os.path.join(self.fleet_dir, "condemned")
        try:
            names = os.listdir(d)
        except FileNotFoundError:
            return set()
        return {int(n[1:]) for n in names if n.startswith("p")}

    def check_condemned(self) -> None:
        if os.path.exists(self._tomb_path(self.process_id)):
            raise ProcessCondemned(
                f"process {self.process_id} was condemned by a fleet peer "
                "(heartbeat lease went stale); restart to rejoin"
            )

    # -- liveness ------------------------------------------------------
    def _peer_alive(self, pid: int) -> bool:
        if pid in self.condemned():
            return False
        if self._liveness is None:
            return True  # no monitor: rely on the exchange hard timeout
        return self._liveness.peer_fresh(pid)

    # -- the exchange --------------------------------------------------
    def _exchange(self, tag: str, leaves: list) -> dict[int, list]:
        """Publish this process's leaves for the next sequence number and
        collect every live peer's; returns {pid: leaves} including self.

        All processes execute the identical deterministic host loop, so
        they issue the same exchanges in the same order — the monotonic
        sequence counter stays in lockstep without any coordination.
        """
        self.check_condemned()
        seq = self._seq
        self._seq += 1
        d = os.path.join(self.fleet_dir, "xchg", f"s{seq:08d}-{tag}")
        os.makedirs(d, exist_ok=True)
        own = os.path.join(d, f"p{self.process_id}.npz")
        _save_tree(own, leaves)
        self._own_files.append(own)

        n_leaves = len(leaves)
        got: dict[int, list] = {self.process_id: leaves}
        expected = set(self._active) - {self.process_id} - self.condemned()
        deadline = self._clock() + self.exchange_timeout
        while expected - set(got):
            for pid in sorted(expected - set(got)):
                path = os.path.join(d, f"p{pid}.npz")
                if os.path.exists(path):
                    got[pid] = _load_tree(path, n_leaves)
            missing = expected - set(got)
            if not missing:
                break
            dropped = False
            for pid in sorted(missing):
                if not self._peer_alive(pid):
                    # fail-stop: the peer's lease is stale — had it
                    # published before dying, the rename would be visible
                    # by now (grace >> fs latency). Condemn so every
                    # survivor converges on the same contributor set.
                    self.condemn(pid)
                    expected.discard(pid)
                    log(
                        f"[multihost] exchange s{seq} {tag}: dropped "
                        f"process {pid} (stale lease)"
                    )
                    dropped = True
            if dropped:
                continue
            if self._clock() > deadline:
                raise RuntimeError(
                    f"exchange s{seq}-{tag} timed out waiting for "
                    f"processes {sorted(missing)}"
                )
            self.check_condemned()
            self._sleep(self.poll_interval)

        # retire own files old enough that every live peer has moved past
        # them (each process deletes only what it wrote — no delete races)
        while len(self._own_files) > 8:
            old = self._own_files.pop(0)
            try:
                os.remove(old)
            except OSError:
                pass
        return got

    def allreduce_sum(self, tag: str, tree) -> tuple[Any, list[int]]:
        """Element-wise sum of ``tree`` over live processes. Returns the
        summed tree and the sorted contributor process ids."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if self._active == [self.process_id]:
            return jax.tree_util.tree_unflatten(treedef, leaves), [self.process_id]
        got = self._exchange(tag, [np.asarray(x) for x in leaves])
        contributors = sorted(got)
        total = [np.asarray(x).copy() for x in got[contributors[0]]]
        for pid in contributors[1:]:
            for i, leaf in enumerate(got[pid]):
                total[i] += leaf
        return jax.tree_util.tree_unflatten(treedef, total), contributors

    def allgather(self, tag: str, tree) -> dict[int, Any]:
        """Gather ``tree`` from every live process: {pid: tree}."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if self._active == [self.process_id]:
            return {
                self.process_id: jax.tree_util.tree_unflatten(treedef, leaves)
            }
        got = self._exchange(tag, [np.asarray(x) for x in leaves])
        return {
            pid: jax.tree_util.tree_unflatten(treedef, vals)
            for pid, vals in got.items()
        }

    # -- fleet integration --------------------------------------------
    def agree_events(self, events) -> list[FaultEvent]:
        """Agree on this boundary's process-grain fleet events.

        Each process allgathers its locally-observed proposals; the union
        (deduplicated, deterministically ordered) is applied everywhere,
        so survivors whose grace periods elapse a boundary apart still
        evict identically. Runs unconditionally every boundary — it *is*
        the exchange that keeps lockstep across membership decisions.
        """
        rows = [
            (_EVENT_CODES[ev.kind], int(ev.process), int(ev.duration))
            for ev in events
            if ev.process is not None and ev.kind in _EVENT_CODES
        ]
        enc = np.asarray(rows, np.int64).reshape(len(rows), 3)
        gathered = self.allgather("fleet", enc)
        merged: dict[tuple[int, int], int] = {}
        for pid in sorted(gathered):
            for kind_c, proc, dur in np.asarray(
                gathered[pid], np.int64
            ).reshape(-1, 3):
                merged.setdefault((int(proc), int(kind_c)), int(dur))
        out = []
        for (proc, kind_c), dur in sorted(merged.items()):
            kind = _EVENT_KINDS[kind_c]
            if proc == self.process_id and kind in ("crash", "preempt"):
                # a peer has proposed evicting *us* (e.g. we flapped past
                # its grace). Silently skipping would desync the exchange
                # sequence — the fleet is about to continue without this
                # process, so stop participating now.
                raise ProcessCondemned(
                    f"process {self.process_id} evicted by fleet agreement "
                    f"({kind})"
                )
            if proc in self._active and proc != self.process_id:
                out.append(FaultEvent(kind, process=proc, duration=dur))
        return out

    def rendezvous(self, timeout: float = 180.0) -> None:
        """Startup barrier (host span): wait until every configured
        process has published a heartbeat lease. Call after the local
        lease is being renewed."""
        if self.spanning != "host" or self.n_processes == 1:
            return
        from repro.core.fleet import read_leases

        leases_dir = os.path.join(self.fleet_dir, "leases")
        deadline = self._clock() + timeout
        want = set(range(self.n_processes))
        while True:
            if want <= set(read_leases(leases_dir)):
                return
            if self._clock() > deadline:
                missing = sorted(want - set(read_leases(leases_dir)))
                raise RuntimeError(
                    f"multihost rendezvous timed out; processes {missing} "
                    f"never published a lease under {leases_dir}"
                )
            self._sleep(self.poll_interval)

    # -- device span helpers ------------------------------------------
    def global_devices(self) -> list:
        """Deterministically-ordered global device list (device span)."""
        from repro.sharding.rules import global_replica_devices

        return global_replica_devices()
