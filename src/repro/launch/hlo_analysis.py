"""Post-SPMD HLO text analyzer with while-loop trip-count roll-up.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, but our
models run layers under ``lax.scan`` — so FLOPs/collective-bytes must be
multiplied by trip counts. This module parses the post-optimization HLO
text into a computation call graph, counts per-computation dot FLOPs and
collective result bytes, extracts while trip counts from loop conditions,
and rolls everything up to the entry computation.

Used by benchmarks/roofline.py (reads the dry-run's stored HLO) and by the
dry-run itself for the per-device roofline terms.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_ONE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_ARRAY_TYPE = re.compile(r"^(\w+\[[\d,]*\]\S*)\s+(.*)$")
_OP_NAME = re.compile(r"^([\w\-]+)[(.]")


def _split_instr(line: str):
    """Parse `%name = TYPE op(...)...` robustly (tuple types may contain
    `/*index=N*/` comments). Returns (name, type_str, op, rest) or None."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    if rhs.startswith("("):  # tuple type: scan to the balanced close paren
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    tstr, rest = rhs[: i + 1], rhs[i + 1 :].lstrip()
                    break
        else:
            return None
    else:
        ma = _ARRAY_TYPE.match(rhs)
        if not ma:
            return None
        tstr, rest = ma.group(1), ma.group(2)
    mo = _OP_NAME.match(rest)
    if not mo:
        return None
    return name, tstr, mo.group(1), rest
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")


def shape_numel_bytes(type_str: str) -> tuple[int, int]:
    """Total (numel, bytes) across all array shapes in a type string."""
    numel_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_ONE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        numel_total += numel
        bytes_total += numel * _DTYPE_BYTES[dt]
    return numel_total, bytes_total


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)  # (name, type_str, op, rest)
    shapes: dict = field(default_factory=dict)  # instr name -> type str


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        # computation header: `%name (args) -> type {`  or `ENTRY %name ...{`
        if not line.startswith(" ") and s.endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$", s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if s == "}" and not line.startswith("  "):
            cur = None
            continue
        if cur is None:
            continue
        parsed = _split_instr(line)
        if parsed:
            name, tstr, op, rest = parsed
            cur.instrs.append((name, tstr, op, rest))
            cur.shapes[name] = tstr
    return comps


def _dot_flops(comp: Computation, name: str, tstr: str, rest: str) -> float:
    """FLOPs of a dot: 2 * numel(result) * contracted_dim_size."""
    out_numel, _ = shape_numel_bytes(tstr)
    # lhs operand: printed either as `dot(%name, ...)` (older jaxlib) or as
    # `dot(TYPE %name, ...)` with an inline type — prefer the inline type,
    # fall back to the shape table.
    lhs_shape = None
    m = re.search(r"dot\(\s*(?:(\w+\[[\d,]*\]\S*)\s+)?%?([\w.\-]+)", rest)
    if m:
        lhs_shape = m.group(1) or comp.shapes.get(m.group(2))
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    k = 1
    if lhs_shape and mc and mc.group(1):
        dims_m = _SHAPE_ONE.search(lhs_shape)
        if dims_m and dims_m.group(2):
            dims = [int(d) for d in dims_m.group(2).split(",")]
            for ci in mc.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * out_numel * k


_KNOWN_TRIPS = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _trip_count(comps: dict, cond_name: str, while_rest: str = "") -> int:
    """Prefer XLA's known_trip_count backend_config on the while op;
    fall back to the largest integer constant in the loop condition."""
    m = _KNOWN_TRIPS.search(while_rest)
    if m:
        return max(int(m.group(1)), 1)
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for _, _, op, rest in cond.instrs:
        if op == "constant":
            m = re.search(r"constant\((-?\d+)\)", rest)
            if m:
                best = max(best, int(m.group(1)))
    return max(best, 1)


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0  # operand+result bytes of top-level kernels
    collective_bytes: dict = field(default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_counts: dict = field(default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for c in COLLECTIVES:
            self.collective_bytes[c] += other.collective_bytes[c] * mult
            self.collective_counts[c] += other.collective_counts[c] * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# ops that move no HBM bytes themselves (views / metadata / control)
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}

_OPERAND_NAMES = re.compile(r"%([\w.\-]+)")


def _instr_bytes(comp: Computation, tstr: str, op: str, rest: str) -> float:
    """HBM traffic model: each scheduled top-level kernel reads its operands
    and writes its result (fusion-internal traffic excluded by construction).

    dynamic-update-slice executes in place on TPU (XLA aliases the base
    buffer): traffic = read update + write the updated region, NOT a full
    copy of the base operand — critical for decode steps, whose KV-cache
    updates would otherwise dominate the term spuriously. ``copy`` of loop
    carries is likewise elided by layout assignment; counted at result size
    only (conservative)."""
    if op in _NO_TRAFFIC:
        return 0.0
    if op == "dynamic-update-slice":
        m = re.search(r"dynamic-update-slice\(\s*%?[\w.\-]+,\s*%?([\w.\-]+)", rest)
        if m:
            shp = comp.shapes.get(m.group(1))
            if shp:
                _, ub = shape_numel_bytes(shp)
                return 2.0 * ub
        _, out_b = shape_numel_bytes(tstr)
        return float(out_b)
    if op == "copy":
        _, out_b = shape_numel_bytes(tstr)
        return float(out_b)
    _, out_b = shape_numel_bytes(tstr)
    total = float(out_b)
    idx = rest.find(op + "(")
    if idx >= 0:
        depth = 0
        args = ""
        for ch in rest[idx + len(op):]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        for name in _OPERAND_NAMES.findall(args):
            shp = comp.shapes.get(name)
            if shp:
                _, b = shape_numel_bytes(shp)
                total += b
    return total


def analyze(hlo: str) -> Costs:
    comps = parse_computations(hlo)
    memo: dict[str, Costs] = {}

    def cost_of(name: str, stack=()) -> Costs:
        if name in memo:
            return memo[name]
        if name in stack:  # recursion guard
            return Costs()
        comp = comps.get(name)
        c = Costs()
        if comp is None:
            return c
        for iname, tstr, op, rest in comp.instrs:
            c.hbm_bytes += _instr_bytes(comp, tstr, op, rest)
            if op == "dot":
                c.flops += _dot_flops(comp, iname, tstr, rest)
            elif op == "while":
                mb = _BODY.search(rest)
                mc = _COND.search(rest)
                trips = _trip_count(comps, mc.group(1) if mc else "", rest)
                if mb:
                    c.add(cost_of(mb.group(1), stack + (name,)), mult=trips)
            elif op in ("fusion", "call", "custom-call", "reduce", "map", "sort", "scatter", "select-and-scatter"):
                m = _CALLS.search(rest)
                if m and m.group(1) in comps:
                    # fused computations: count FLOPs/collectives of the body,
                    # but NOT its internal byte traffic (the fusion op's own
                    # operand/result bytes above are the real HBM traffic).
                    sub = cost_of(m.group(1), stack + (name,))
                    sub_nb = Costs(
                        flops=sub.flops,
                        hbm_bytes=0.0,
                        collective_bytes=dict(sub.collective_bytes),
                        collective_counts=dict(sub.collective_counts),
                    )
                    c.add(sub_nb)
            elif op == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|(?:true|false)_computation=%?([\w.\-]+))", rest):
                    names = (m.group(1) or m.group(2) or "").replace("%", "").split(",")
                    for n in names:
                        n = n.strip()
                        if n in comps:
                            c.add(cost_of(n, stack + (name,)))
            else:
                base = None
                for col in COLLECTIVES:
                    if op == col or op.startswith(col + "-start"):
                        base = col
                        break
                if base:
                    _, b = shape_numel_bytes(tstr)
                    c.collective_bytes[base] += b
                    c.collective_counts[base] += 1
        memo[name] = c
        return c

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    if m:
        entry = m.group(1)
    else:  # fall back to the last computation
        entry = list(comps)[-1] if comps else ""
    return cost_of(entry)
