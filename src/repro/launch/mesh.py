"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices; smoke tests and benchmarks see the
real single CPU device.

Target hardware: TPU v5e pods — 256 chips/pod in a 16x16 mesh
(data, model); 2 pods => (pod, data, model) = (2, 16, 16).
"""
from __future__ import annotations

import jax

# v5e hardware constants (used by the roofline analysis)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~4 links/chip on the 2D torus)
HBM_PER_CHIP = 16e9          # bytes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CPU tests (requires host-device-count >= product)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_replica_mesh(n_replicas: int, devices=None, multihost=None):
    """1-D ``(replica,)`` mesh for ``--placement sharded`` (DESIGN.md §5).

    On a real machine this spans the local accelerators; under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` it spans the
    virtual CPU devices (the multi-device CI job runs with N=8), and on a
    bare single-CPU container it degenerates to a size-1 mesh. Delegates to
    sharding.rules.replica_mesh, which picks the largest device count
    dividing ``n_replicas``.

    ``multihost`` accepts a bootstrapped
    :class:`repro.launch.multihost.MultihostContext`: under a *device*
    span the mesh is built from the jax.distributed global device list
    (DESIGN.md §10) so the SPMD executors span processes; under a *host*
    span each process meshes only its own devices and the context's file
    exchange bridges them, so local devices are used unchanged.
    """
    from repro.sharding.rules import replica_mesh

    if multihost is not None and devices is None:
        if multihost.spanning == "device":
            devices = multihost.global_devices()
    return replica_mesh(n_replicas, devices=devices)
