"""Input specs: ShapeDtypeStruct stand-ins for the dry-run and real random
batches for smoke tests — one source of truth for every model input.

Batch layouts per mode (leading replica dim R added by the caller/launcher):
  train   : tokens/targets (B, S) int32, sample_mask (B,) bool
            [+ patch_embeds (B, P, Fd) for vlm; frames (B, F, Fd) for audio]
  prefill : tokens (B, S) int32 [+ frontend embeds]
  decode  : tokens (B, 1) int32 + KV/SSM cache of seq_len context
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as MDL


def _frontend_fields(cfg: ModelConfig, b: int, as_spec: bool, rng=None) -> dict:
    out = {}
    if cfg.frontend == "vision":
        shape = (b, cfg.frontend_len, cfg.frontend_dim)
        out["patch_embeds"] = (
            jax.ShapeDtypeStruct(shape, jnp.float32)
            if as_spec
            else jax.random.normal(rng, shape, jnp.float32)
        )
    elif cfg.frontend == "audio":
        shape = (b, cfg.frontend_len, cfg.frontend_dim)
        out["frames"] = (
            jax.ShapeDtypeStruct(shape, jnp.float32)
            if as_spec
            else jax.random.normal(rng, shape, jnp.float32)
        )
    return out


def train_specs(cfg: ModelConfig, b: int, s: int) -> dict:
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "sample_mask": jax.ShapeDtypeStruct((b,), jnp.bool_),
        **_frontend_fields(cfg, b, as_spec=True),
    }


def prefill_specs(cfg: ModelConfig, b: int, s: int) -> dict:
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        **_frontend_fields(cfg, b, as_spec=True),
    }


def decode_specs(cfg: ModelConfig, b: int, s: int, window: int = 0) -> dict:
    """Decode inputs: one new token + cache covering s context slots."""
    cache = jax.eval_shape(lambda: MDL.init_cache(cfg, b, s, window))
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": cache,
    }


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """long_500k on full-attention archs uses the sliding-window carve-in."""
    if shape.name != "long_500k":
        return 0
    if cfg.arch_type in ("ssm",):
        return 0  # attention-free: native O(1) state
    return cfg.long_context_window


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        return train_specs(cfg, b, s)
    if shape.mode == "prefill":
        return prefill_specs(cfg, b, s)
    return decode_specs(cfg, b, s, decode_window(cfg, shape))


# --------------------------------------------------------------------------
# real batches (smoke tests / examples)
# --------------------------------------------------------------------------


def make_train_batch(cfg: ModelConfig, b: int, s: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(b, s + 1), dtype=np.int32)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
        "sample_mask": jnp.ones((b,), jnp.bool_),
    }
    key = jax.random.PRNGKey(seed)
    batch.update(_frontend_fields(cfg, b, as_spec=False, rng=key))
    return batch


def make_decode_inputs(cfg: ModelConfig, b: int, context: int, window: int = 0, seed: int = 0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 1), dtype=np.int32))
    cache = MDL.init_cache(cfg, b, context, window)
    cache["cur_len"] = jnp.asarray(context - 1, jnp.int32)
    return tokens, cache
