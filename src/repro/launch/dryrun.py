import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) combination: lower + compile
the appropriate step (train_round / prefill / decode, plus the Algorithm-2
merge step for train shapes) against ShapeDtypeStruct inputs on the
production mesh, print memory_analysis()/cost_analysis(), and dump the
roofline raw terms (HLO FLOPs, bytes, per-collective bytes parsed from the
post-SPMD HLO) as JSON for benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_decode_step, make_merge_step, make_prefill_step, make_train_round,
)
from repro.models import model as MDL
from repro.sharding.annotate import sharding_context
from repro.sharding.rules import (
    MeshAxes, param_specs, serve_specs, to_named, train_batch_specs,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum result bytes of every collective op in post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w]+\[[\d,]*\][^ ]*)\s+([\w\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        # normalize variants like all-reduce-start / all-gather-done
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        out[base] += _shape_bytes(m.group(1))
        counts[base] += 1
    return {"bytes": out, "counts": counts}


def _with_replica_dim(tree, r: int):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((r,) + tuple(s.shape), s.dtype), tree
    )


def _param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: MDL.init(cfg, k), jax.random.PRNGKey(0))


def lower_combo(cfg: ModelConfig, shape: InputShape, mesh, verbose: bool = True) -> dict:
    """Lower + compile every step relevant to (cfg, shape) on mesh."""
    ax = MeshAxes(cfg, mesh)
    results = {}
    with sharding_context(mesh, ax.activation_rules()):
        pshapes = _param_shapes(cfg)

        if shape.mode == "train":
            r = ax.n_replicas
            assert shape.global_batch % r == 0, (shape.global_batch, r)
            b_rep = shape.global_batch // r
            replicas = _with_replica_dim(pshapes, r)
            batch = _with_replica_dim(SP.train_specs(cfg, b_rep, shape.seq_len), r)
            rep_sharding = to_named(param_specs(cfg, replicas, mesh, with_replica_dim=True), mesh)
            batch_sharding = to_named(train_batch_specs(cfg, batch, mesh), mesh)
            from jax.sharding import NamedSharding, PartitionSpec as P

            vec = jax.ShapeDtypeStruct((r,), jnp.float32)
            vec_sh = NamedSharding(mesh, P(ax.replica))

            step = make_train_round(cfg)
            results["train"] = _lower_and_analyze(
                step,
                (replicas, batch, vec, vec),
                in_shardings=(rep_sharding, batch_sharding, vec_sh, vec_sh),
                out_shardings=(rep_sharding, None),
                mesh=mesh,
                step_name="train",
            )

            # Algorithm-2 merge (the paper's all-reduce model merging)
            keep_global = cfg.replica_axis != "pod"  # memory-lean for huge archs
            merge = make_merge_step(cfg, keep_global=keep_global)
            g_sharding = to_named(param_specs(cfg, pshapes, mesh), mesh)
            if keep_global:
                args = (replicas, vec, pshapes, pshapes)
                in_sh = (rep_sharding, vec_sh, g_sharding, g_sharding)
                out_sh = (g_sharding, rep_sharding)
            else:
                args = (replicas, vec)
                in_sh = (rep_sharding, vec_sh)
                out_sh = rep_sharding
            results["merge"] = _lower_and_analyze(
                merge, args, in_shardings=in_sh, out_shardings=out_sh,
                mesh=mesh, step_name="merge",
            )

        elif shape.mode == "prefill":
            batch = SP.prefill_specs(cfg, shape.global_batch, shape.seq_len)
            p_sh = to_named(param_specs(cfg, pshapes, mesh), mesh)
            b_sh = to_named(serve_specs(cfg, batch, mesh), mesh)
            step = make_prefill_step(cfg)
            with sharding_context(mesh, ax.serve_rules()):
                results["prefill"] = _lower_and_analyze(
                    step, (pshapes, batch), in_shardings=(p_sh, b_sh),
                    out_shardings=None, mesh=mesh, step_name="prefill",
                )

        else:  # decode
            window = SP.decode_window(cfg, shape)
            ins = SP.decode_specs(cfg, shape.global_batch, shape.seq_len, window)
            p_sh = to_named(param_specs(cfg, pshapes, mesh), mesh)
            c_sh = to_named(serve_specs(cfg, ins["cache"], mesh), mesh)
            t_sh = to_named(serve_specs(cfg, {"tokens": ins["tokens"]}, mesh), mesh)["tokens"]
            step = make_decode_step(cfg, window)
            from jax.sharding import NamedSharding, PartitionSpec as P

            multi_pod = "pod" in mesh.shape
            bat = ("pod", "data") if multi_pod else "data"
            if shape.global_batch % (2 if multi_pod else 1) or shape.global_batch % 16:
                bat = None  # long_500k B=1: logits replicated
            logits_sh = NamedSharding(mesh, P(bat, None, None))
            with sharding_context(mesh, ax.serve_rules()):
                results["decode"] = _lower_and_analyze(
                    step,
                    (pshapes, ins["cache"], ins["tokens"]),
                    in_shardings=(p_sh, c_sh, t_sh),
                    out_shardings=(logits_sh, c_sh),
                    mesh=mesh,
                    step_name="decode",
                )
    return results


HLO_ARCHIVE: dict = {"dir": None, "tag": None}  # set by main() per combo


def _archive_hlo(hlo: str, step_name: str) -> None:
    """zstd-compress the post-SPMD HLO so analysis passes can be re-run
    offline without recompiling (results/hlo/<tag>__<step>.hlo.zst)."""
    if HLO_ARCHIVE["dir"] is None:
        return
    import zstandard as zstd

    os.makedirs(HLO_ARCHIVE["dir"], exist_ok=True)
    path = os.path.join(
        HLO_ARCHIVE["dir"], f"{HLO_ARCHIVE['tag']}__{step_name}.hlo.zst"
    )
    with open(path, "wb") as f:
        f.write(zstd.ZstdCompressor(level=9).compress(hlo.encode()))


def _lower_and_analyze(fn, args, in_shardings, out_shardings, mesh,
                       step_name: str = "step") -> dict:
    from repro.launch.hlo_analysis import analyze

    t0 = time.perf_counter()
    jitted = jax.jit(fn, in_shardings=in_shardings, out_shardings=out_shardings)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.perf_counter()
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    _archive_hlo(hlo, step_name)
    rolled = analyze(hlo)  # while-trip-count-corrected per-device costs
    mem_d = {}
    if mem is not None:
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(mem, k):
                mem_d[k] = int(getattr(mem, k))
    return {
        # rolled-up (trip-count-corrected) per-device terms
        "flops": float(rolled.flops),
        "hbm_bytes": float(rolled.hbm_bytes),
        "collectives": {
            "bytes": rolled.collective_bytes,
            "counts": rolled.collective_counts,
        },
        # raw XLA numbers (while bodies counted once) for cross-check
        "xla_flops": float(cost.get("flops", 0.0)),
        "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "memory": mem_d,
        "compile_s": t1 - t0,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }


def model_flops_per_token(cfg: ModelConfig) -> float:
    """6*N(_active) analytic FLOPs per token (roofline MODEL_FLOPS term)."""
    d = cfg.d_model
    n_active = cfg.vocab_size * d  # embed+unembed counted once
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) == "attn":
            hd = cfg.resolved_head_dim
            n_active += d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        else:
            d_inner = cfg.ssm_expand * d
            n_active += d * (2 * d_inner + 2 * cfg.ssm_state + d_inner // cfg.ssm_head_dim)
            n_active += d_inner * d
        if cfg.ffn_kind(i) == "moe":
            n_active += cfg.top_k * 3 * d * cfg.d_ff
            if cfg.dense_residual:
                n_active += 3 * d * cfg.dense_residual_ff
        elif cfg.d_ff:
            n_active += 3 * d * cfg.d_ff
    for _ in range(cfg.encoder_layers):
        hd = cfg.resolved_head_dim
        n_active += d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2 + 3 * d * cfg.d_ff
    return 6.0 * n_active


def total_params(cfg: ModelConfig) -> float:
    shapes = jax.eval_shape(lambda k: MDL.init(cfg, k), jax.random.PRNGKey(0))
    return float(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)))


def reanalyze(out_dir: str, hlo_dir: str) -> None:
    """Re-run the HLO roll-up on archived HLO and patch the stored JSONs
    (no recompilation needed)."""
    import zstandard as zstd

    from repro.launch.hlo_analysis import analyze

    for fn in sorted(os.listdir(hlo_dir)):
        if not fn.endswith(".hlo.zst"):
            continue
        tag_step = fn[: -len(".hlo.zst")]
        tag, step_name = tag_step.rsplit("__", 1)
        jpath = os.path.join(out_dir, tag + ".json")
        if not os.path.exists(jpath):
            continue
        with open(os.path.join(hlo_dir, fn), "rb") as f:
            hlo = zstd.ZstdDecompressor().decompress(f.read()).decode()
        rolled = analyze(hlo)
        with open(jpath) as f:
            rec = json.load(f)
        step = rec["steps"].get(step_name)
        if step is None:
            continue
        step["flops"] = float(rolled.flops)
        step["hbm_bytes"] = float(rolled.hbm_bytes)
        step["collectives"] = {
            "bytes": rolled.collective_bytes,
            "counts": rolled.collective_counts,
        }
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[reanalyzed] {tag_step}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default="results/hlo",
                    help="archive zstd-compressed post-SPMD HLO here ('' = off)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="re-run HLO analysis from archived HLO, no compile")
    ap.add_argument("--moe-dispatch", default="",
                    choices=["", "global", "sharded"],
                    help="override cfg.moe_dispatch (perf experiments)")
    ap.add_argument("--moe-combine-dtype", default="",
                    choices=["", "f32", "bf16"],
                    help="override cfg.moe_combine_dtype (perf experiments)")
    ap.add_argument("--moe-decode-gather", action="store_true",
                    help="decode-time expert-gather FFN (perf experiments)")
    ap.add_argument("--remat", default="",
                    choices=["", "on", "off"],
                    help="override cfg.remat (perf experiments)")
    ap.add_argument("--remat-policy", default="",
                    choices=["", "full", "dots"],
                    help="override cfg.remat_policy (perf experiments)")
    ap.add_argument("--tag-suffix", default="",
                    help="suffix for result filenames (perf experiments)")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze(args.out, args.hlo_dir)
        return

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mtag = "multipod" if multi_pod else "singlepod"
        for arch in archs:
            cfg = ARCHS[arch]
            import dataclasses
            if args.moe_dispatch:
                cfg = dataclasses.replace(cfg, moe_dispatch=args.moe_dispatch)
            if args.moe_combine_dtype:
                cfg = dataclasses.replace(
                    cfg, moe_combine_dtype=args.moe_combine_dtype)
            if args.remat:
                cfg = dataclasses.replace(cfg, remat=args.remat == "on")
            if args.moe_decode_gather:
                cfg = dataclasses.replace(cfg, moe_decode_gather=True)
            if args.remat_policy:
                cfg = dataclasses.replace(cfg, remat_policy=args.remat_policy)
            for shape_name in shapes:
                shape = INPUT_SHAPES[shape_name]
                tag = f"{arch}__{shape_name}__{mtag}{args.tag_suffix}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                HLO_ARCHIVE["dir"] = args.hlo_dir or None
                HLO_ARCHIVE["tag"] = tag
                t0 = time.perf_counter()
                try:
                    res = lower_combo(cfg, shape, mesh)
                    record = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": mtag,
                        "mesh_shape": dict(mesh.shape),
                        "steps": res,
                        "model_flops_per_token": model_flops_per_token(cfg),
                        "total_params": total_params(cfg),
                        "tokens_per_step": shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1),
                        "mode": shape.mode,
                    }
                    with open(path, "w") as f:
                        json.dump(record, f, indent=1)
                    dt = time.perf_counter() - t0
                    step = next(iter(res.values()))
                    print(
                        f"[ok] {tag} compile={dt:.1f}s flops={step['flops']:.3g} "
                        f"coll={sum(step['collectives']['bytes'].values()):.3g}B"
                    )
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nAll dry-run combinations lowered and compiled successfully.")


if __name__ == "__main__":
    main()
